"""Benchmark harness — one function per paper table + framework benches.

Prints ``name,us_per_call,derived`` CSV (plus a table column).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --quick    # skip slow model bench
"""

from __future__ import annotations

import argparse
import time


def _model_step_bench():
    """Throughput of one smoke train step per arch (CPU host numbers)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import get_config, list_archs
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.models.sharding import make_policy
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.train_loop import make_train_step

    rows = []
    rng = np.random.default_rng(0)
    for arch in list_archs():
        cfg = get_config(arch, smoke=True)
        policy = make_policy(make_host_mesh(), cfg, batch=2, train=True)
        opt = OptConfig(total_steps=100, warmup_steps=1,
                        eightbit=cfg.opt_8bit)
        step, _ = make_train_step(cfg, policy, opt, donate=False)
        params = M.init_params(cfg, jax.random.key(0))
        state = init_opt_state(params, opt)
        B, T = 2, 64
        batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)),
                                       jnp.int32)}
        if cfg.frontend == "none":
            batch["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
        else:
            batch["embeds"] = jnp.asarray(
                rng.normal(0, 1, (B, T, cfg.frontend_dim)), jnp.float32)
            if cfg.rope_kind == "mrope":
                pos = np.broadcast_to(
                    np.arange(T)[None, :, None], (B, T, 3)).copy()
                batch["positions"] = jnp.asarray(pos, jnp.int32)
        # warmup + time
        out = step(params, state, batch, jnp.asarray(0, jnp.int32))
        jax.block_until_ready(out[2]["loss"])
        t0 = time.perf_counter()
        for i in range(3):
            out = step(params, state, batch, jnp.asarray(i + 1, jnp.int32))
        jax.block_until_ready(out[2]["loss"])
        dt = (time.perf_counter() - t0) / 3
        rows.append({
            "table": "framework_smoke_train",
            "name": f"{arch}:train_step_smoke",
            "us_per_call": dt * 1e6,
            "derived": f"tok/s={B*T/dt:.0f} loss={float(out[2]['loss']):.3f}",
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    from benchmarks.cipher_tables import (
        bench_hw_sw_comparison,
        bench_performance_table,
        bench_resource_table,
    )

    rows = []
    for name in ("hera-128a", "rubato-128l"):
        rows += bench_performance_table(name)     # Tables I & II
        rows += bench_resource_table(name)        # Tables III & IV
    rows += bench_hw_sw_comparison()              # §V headline comparison
    if not args.quick:
        rows += _model_step_bench()

    print("table,name,us_per_call,derived")
    for r in rows:
        print(f"{r['table']},{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
