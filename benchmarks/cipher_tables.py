"""Benchmarks reproducing the structure of the paper's Tables I–IV.

The paper measures an FPGA at 37–222 MHz against AVX2 software; this repo's
"hardware" is a TPU program validated on CPU, so:

  * Tables I/II analogues (Performance: HERA / Rubato): we measure wall-time
    per stream-key generation on THIS host for the three design points the
    paper ablates —
      D1  coupled scalar-ish baseline  (XOF serialized with rounds,
          vmap-free reference path)
      D2  + RNG decoupling             (producer/consumer split)
      D3  + vectorization/fusion       (lane-major fused Pallas kernel,
          interpret mode on CPU)
    plus derived throughput in Msps (samples/s = lanes x l per call / time).
    Wall-times are CPU-host numbers — the paper-faithful claim validated is
    the ORDERING and the mechanism attribution, not absolute MHz.
  * Tables III/IV analogues (Resource): FPGA LUT/FF/DSP/BRAM map to compiled
    HLO structural metrics: op counts, bytes accessed, peak memory, and the
    VMEM working set of the fused kernel.

Run:  PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.cipher import make_cipher
from repro.kernels.keystream.ops import keystream_kernel_apply


def _time(fn, *args, warmup=3, iters=10):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _design_points(ci):
    """Jitted callables for the paper's three design points."""
    producer = jax.jit(ci.round_constant_stream)
    consumer = jax.jit(
        lambda rc, nz: ci.keystream_from_constants(rc, nz))
    d1 = jax.jit(ci.keystream_coupled)

    def d2(ctrs):
        # producer dispatched first; on TPU it runs async with the previous
        # consumer call (RNG decoupling) — here it demonstrates the split
        consts = producer(ctrs)
        return consumer(consts["rc"], consts["noise"])

    def d3(ctrs):
        consts = producer(ctrs)
        return keystream_kernel_apply(
            ci.params, ci.key, consts["rc"], consts["noise"], interpret=True)

    return (("D1_coupled", d1), ("D2_decoupled", d2),
            ("D3_fused_kernel[interp]", d3))


def bench_performance_table(name: str, lanes: int = 256):
    """Table I (HERA) / Table II (Rubato) analogue.

    NOTE: D3 runs the Pallas kernel in interpret mode (a Python emulation of
    the TPU kernel), so its CPU wall-time is NOT the accelerator claim — the
    structural win is in the Tables III/IV analogue + the dry-run; D1 vs D2
    is a genuine host-side ablation of RNG decoupling.
    """
    ci = make_cipher(name, seed=0)
    ctrs = jnp.arange(lanes, dtype=jnp.uint32)
    rows = []
    d1 = None
    for label, fn in _design_points(ci):
        dt = _time(fn, ctrs)
        msps = lanes * ci.params.l / dt / 1e6
        us_per_key = dt / lanes * 1e6
        d1 = d1 or dt
        rows.append({
            "table": f"paper_table_{'I' if 'hera' in name else 'II'}",
            "name": f"{name}:{label}",
            "us_per_call": dt * 1e6,
            "derived": (f"throughput={msps:.1f}Msps "
                        f"us/key={us_per_key:.3f} speedup_vs_D1={d1/dt:.2f}x"),
        })
    return rows


def bench_resource_table(name: str):
    """Table III/IV analogue: compiled structural metrics per design point."""
    ci = make_cipher(name, seed=0)
    lanes = 256
    ctrs = jnp.arange(lanes, dtype=jnp.uint32)
    rows = []
    points = dict(_design_points(ci))
    for label in ("D1_coupled", "D3_fused_kernel[interp]"):
        fn = points[label]
        lowered = jax.jit(fn).lower(ctrs)
        compiled = lowered.compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jax: one dict per device
            ca = ca[0] if ca else {}
        ma = compiled.memory_analysis()
        n_ops = compiled.as_text().count(" = ")
        rows.append({
            "table": f"paper_table_{'III' if 'hera' in name else 'IV'}",
            "name": f"{name}:{label}",
            "us_per_call": 0.0,
            "derived": (f"hlo_ops={n_ops} flops={ca.get('flops', 0):.3g} "
                        f"bytes={ca.get('bytes accessed', 0):.3g} "
                        f"tmp_bytes={ma.temp_size_in_bytes}"),
        })
    return rows


def bench_hw_sw_comparison():
    """The paper's headline: accelerator vs software, HERA vs Rubato.

    Software baseline = the pure-JAX reference path (the AVX2 analogue on
    this host); accelerator = the fused lane-major kernel.  Validates the
    paper's FINDING that Rubato overtakes HERA once RNG is decoupled and
    compute is vectorized (it loses to HERA in the scalar/software regime
    because of its larger RNG demand).
    """
    rows = []
    ratios = {}
    for name in ("hera-128a", "rubato-128l"):
        ci = make_cipher(name, seed=0)
        ctrs = jnp.arange(256, dtype=jnp.uint32)
        points = dict(_design_points(ci))
        sw = _time(points["D1_coupled"], ctrs)
        hw = _time(points["D3_fused_kernel[interp]"], ctrs)
        ratios[name] = (sw, hw)
        rows.append({
            "table": "paper_sec_V_comparison",
            "name": f"{name}:sw_vs_accel",
            "us_per_call": hw * 1e6,
            "derived": f"sw_us={sw*1e6:.0f} accel_us={hw*1e6:.0f} "
                       f"speedup={sw/hw:.2f}x",
        })
    # paper finding: accelerated Rubato beats accelerated HERA on
    # per-key latency*throughput even though HERA wins in software
    hera_hw = ratios["hera-128a"][1] / 16     # per keystream element
    rub_hw = ratios["rubato-128l"][1] / 60
    rows.append({
        "table": "paper_sec_V_comparison",
        "name": "rubato_vs_hera_accelerated_per_element",
        "us_per_call": rub_hw * 1e6,
        "derived": f"hera/elem={hera_hw*1e6:.3f}us rubato/elem={rub_hw*1e6:.3f}us "
                   f"rubato_wins={rub_hw < hera_hw}",
    })
    return rows
