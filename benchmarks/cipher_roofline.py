"""Roofline for the paper's own workload: the keystream farm at pod scale.

Cell: Rubato Par-128L (and HERA Par-128a) stream-key generation for one
encrypted train_4k batch — 256x4096 tokens / l elements per block =
17,477 blocks — sharded across the 256-chip production mesh.  This is the
cipher overlaid on the train_4k input shape: the data-plane work the pod
must hide behind each training step (macro RNG-decoupling, docs/DESIGN.md T3).

    PYTHONPATH=src python -m benchmarks.cipher_roofline

Iterations (§Perf Cell C):
  C0  baseline: AES-CTR XOF (paper's choice) + rejection + rounds
  C1  threefry XOF (TPU-native counter PRF — beyond-paper)
  C2  producer/consumer split vs coupled (RNG decoupling, paper's T3)
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.cost import MUL_WEIGHT, analyze_cost
from repro.core.cipher import Cipher, make_cipher
from repro.core.params import REGISTRY, get_params
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import make_production_mesh

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256


def _cost(compiled):
    ca = compiled.cost_analysis()
    cb, _, _ = collective_bytes(compiled.as_text())
    return {"flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0), "coll": float(cb)}


def terms(c):
    tc = c["flops"] / PEAK_FLOPS
    tm = c["bytes"] / HBM_BW
    tx = c["coll"] / ICI_BW
    dom = max((("compute", tc), ("memory", tm), ("collective", tx)),
              key=lambda kv: kv[1])[0]
    return tc, tm, tx, dom


def farm_cell(name: str, xof: str, mesh, lanes: int):
    p = dataclasses.replace(get_params(name), xof=xof)
    ci = make_cipher(name, seed=0)
    ci = Cipher(p, ci.key, ci.nonce)
    spec = NamedSharding(mesh, P(("data", "model")))

    def full(ctrs):
        consts = ci.round_constant_stream(ctrs)
        return ci.keystream_from_constants(consts["rc"], consts["noise"])

    def producer(ctrs):
        return ci.round_constant_stream(ctrs)

    ctrs = jax.ShapeDtypeStruct((lanes,), jnp.uint32)
    with mesh:
        c_full = _cost(jax.jit(full, in_shardings=spec).lower(ctrs).compile())
        c_prod = _cost(jax.jit(producer, in_shardings=spec)
                       .lower(ctrs).compile())
    return c_full, c_prod


def analytic_ceiling(name: str):
    """Static roofline from the schedule walk (repro.analysis.cost),
    scaled to this file's pod constants — no compile, no XLA cost model.
    Returns (lanes/s ceiling across the mesh, CostReport)."""
    cost = analyze_cost(get_params(name))
    # u32 elementwise ops ride the vector unit at ~1 lane op per flop-slot
    compute = PEAK_FLOPS / (cost.modmul * MUL_WEIGHT + cost.modadd
                            + cost.reduce_steps + cost.shift_add)
    memory = HBM_BW / cost.bytes_per_lane
    return CHIPS * min(compute, memory), cost


def main():
    mesh = make_production_mesh()
    tokens = 256 * 4096
    for name in sorted(REGISTRY):
        l = get_params(name).l
        lanes = math.ceil(tokens / l)
        lanes = ((lanes + CHIPS - 1) // CHIPS) * CHIPS
        print(f"\n=== {name}: {lanes} keystream blocks "
              f"(train_4k data plane, 256 chips) ===")
        ceiling, cost = analytic_ceiling(name)
        print(f"  analytic: {cost.modmul} modmul/lane, "
              f"{cost.bytes_per_lane} B/lane "
              f"(intensity {cost.modmul_intensity:.4f} modmul/B) -> "
              f"ceiling {ceiling:.3e} lanes/s, "
              f"batch floor {lanes / ceiling * 1e6:.2f}us")
        for xof in ("aes", "threefry"):
            c_full, c_prod = farm_cell(name, xof, mesh, lanes)
            tc, tm, tx, dom = terms(c_full)
            ptc, ptm, _, _ = terms(c_prod)
            rng_frac = max(ptc, ptm) / max(tc, tm, 1e-30)
            print(f"  xof={xof:9s} Tc={tc*1e6:9.2f}us Tm={tm*1e6:9.2f}us "
                  f"Tx={tx*1e6:6.2f}us dom={dom:7s} "
                  f"| RNG share of dominant term: {rng_frac:.0%}")
        # train-step hiding headroom: keystream time vs internlm2 train step
        print(f"  (macro-decoupling: this hides behind any multi-second "
              f"train step -> data-plane crypto is FREE at pod scale)")


if __name__ == "__main__":
    main()
