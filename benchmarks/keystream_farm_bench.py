"""Keystream-farm bench: decoupled-batched pipeline vs coupled baseline,
per registered engine × producer × pipeline depth.

    PYTHONPATH=src python benchmarks/keystream_farm_bench.py [--quick]
    PYTHONPATH=src python benchmarks/keystream_farm_bench.py --smoke   # CI

Reproduces the paper's throughput-scaling claim in jax_pallas terms: the
headline 6x comes from keeping the round pipeline saturated — decoupling
RNG from key computation and batching many streams into one dispatch.
Measured here per cipher parameter set:

  * **coupled baseline** — the paper's D1 shape at system level: each
    session is its own single-stream `Cipher`; one serialized
    `keystream_coupled` dispatch per session per window (XOF → sampling →
    rounds pinned in order by an optimization barrier, no cross-session
    batching, no overlap).
  * **farm[<engine>|<producer>|d<depth>]** — the `KeystreamFarm` pipeline
    with each consumer engine from the `repro.core.engine` registry
    (--engines; default: the "auto" engine plus "jax"), each constants
    producer from the `repro.core.producer` registry (--producer;
    default: the preset's declared XOF stream), and each producer→
    consumer FIFO depth (--depth; default: 2, classic double buffering).
    All sessions' lanes packed into one window, producers for up to
    depth-1 windows ahead dispatched before each consume.

Reported per mode: throughput (Melem/s of Z_q keystream), per-window
p50/p99 latency, and the **producer/consumer overlap ratio** — the
fraction of the producer's own latency hidden behind the consumer,
measured as (serialized-pipeline p50 − pipelined p50) / producer-only
p50, clamped to [0, 1].  ~1.0 means the XOF/sampling phase is fully
hidden (the paper's T3 payoff); ~0 means a synchronous backend or a
producer slower than the consumer.

--schedule {normal,alternating} picks the schedule-orientation plan
(core/schedule.py) the farm consumers execute; non-smoke runs additionally
report the per-window p50/p99 delta between the two orientations for the
primary engine (both are bit-exact — the delta is pure scheduling cost).

--smoke runs a tiny sweep with no PASS/FAIL gating — the CI drift canary
(scripts/ci.sh) that keeps every engine dispatching end-to-end on the
selected schedule variant, overlap report included.

--snapshot writes benchmarks/BENCH_farm_trajectory.json: per
preset x engine x producer x (depth, matrix_depth) the per-window p50/p99
and the producer/consumer overlap ratio, plus — for matrix-streaming
presets (PASTA) — the overlap-ratio improvement of matrix_depth=2
(matrix planes prefetched one extra window ahead through the farm's
plane-split FIFO) over matrix_depth=1.  --check compares a fresh lap
against the checked-in snapshot and flags >REGRESSION_TOL p50/p99
regressions (warnings, errors under --strict — same contract as the
BENCH_schedule_analysis.json measured-drift gate: timings are
host-dependent, structure is not).  The ci.sh ``bench-gate`` stage runs
--check.
"""

import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CipherBatch,
    KeystreamFarm,
    WindowPlan,
    engine_caps,
    resolve_engine,
)
from repro.core.params import REGISTRY

# default bench presets: the paper's benchmarked pair plus the large PASTA
# set — one preset per cipher kind, every kind in the params registry
DEFAULT_PRESETS = ("hera-128a", "rubato-128l", "pasta-128l")

SNAPSHOT_SCHEMA = 1
DEFAULT_SNAPSHOT = pathlib.Path(__file__).parent / "BENCH_farm_trajectory.json"
#: relative per-window p50/p99 regression --check flags
REGRESSION_TOL = 0.20
#: small fixed workload so the snapshot lap stays CI-sized; both PASTA
#: presets ride along so the matrix-plane prefetch is covered at both t
SNAPSHOT_PRESETS = ("hera-128a", "rubato-128l", "pasta-128s", "pasta-128l")


def _percentiles(ts):
    a = np.asarray(ts) * 1e3
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def _window_plans(sessions, lanes, n_windows, start=0):
    """The bench's window schedule: all sessions' lanes in one window."""
    blocks = lanes // sessions
    for w in range(start, start + n_windows):
        sids = np.tile(np.arange(sessions, dtype=np.int64), blocks)
        ctrs = np.repeat(
            np.arange(w * blocks, (w + 1) * blocks, dtype=np.int64), sessions)
        yield WindowPlan(sids, ctrs)


def bench_coupled(batch: CipherBatch, lanes: int, n_windows: int):
    """One serialized keystream_coupled dispatch per session per window."""
    S = len(batch.sessions)
    blocks = lanes // S
    ciphers = [batch.session_cipher(i) for i in range(S)]
    fns = [jax.jit(c.keystream_coupled) for c in ciphers]
    ctrs0 = jnp.arange(blocks, dtype=jnp.uint32)
    # warmup / compile
    jax.block_until_ready([fn(ctrs0) for fn in fns])
    lat = []
    t0 = time.perf_counter()
    for w in range(n_windows):
        tw = time.perf_counter()
        ctrs = ctrs0 + jnp.uint32(w * blocks)
        outs = [fn(ctrs) for fn in fns]
        jax.block_until_ready(outs)          # window boundary: no overlap
        lat.append(time.perf_counter() - tw)
    total = time.perf_counter() - t0
    return total, lat


def bench_farm(farm: KeystreamFarm, lanes: int, n_windows: int):
    """Depth-buffered batched windows over the same session pool."""
    S = len(farm.batch.sessions)

    # warmup / compile
    for _, z in farm.run(_window_plans(S, lanes, 1)):
        jax.block_until_ready(z)
        break
    lat = []
    it = farm.run(_window_plans(S, lanes, n_windows, start=n_windows))
    t0 = time.perf_counter()
    while True:
        # time around the generator advance so per-window latency includes
        # host-side dispatch, same as the coupled baseline's accounting
        tw = time.perf_counter()
        try:
            _, z = next(it)
        except StopIteration:
            break
        jax.block_until_ready(z)
        lat.append(time.perf_counter() - tw)
    total = time.perf_counter() - t0
    return total, lat


def bench_producer_only(farm: KeystreamFarm, lanes: int, n_windows: int):
    """Per-window latency of the producer phase alone (XOF + sampling)."""
    S = len(farm.batch.sessions)
    for plan in _window_plans(S, lanes, 1):
        jax.block_until_ready(farm.produce(plan))        # warmup
    lat = []
    for plan in _window_plans(S, lanes, n_windows, start=2 * n_windows):
        tw = time.perf_counter()
        jax.block_until_ready(farm.produce(plan))
        lat.append(time.perf_counter() - tw)
    return lat


def overlap_ratio(farm: KeystreamFarm, serial_farm: KeystreamFarm,
                  lanes: int, n_windows: int):
    """Fraction of producer latency hidden behind the consumer.

    (p50 of the depth-1 serialized pipeline − p50 of the depth-d
    pipeline) / p50 of the producer alone, clamped to [0, 1] — the
    window-level measurement of the paper's T3 overlap.  A memoizing
    producer can push the serialized p50 below the pipelined one
    (negative numerator → 0.0): nothing left to hide.
    """
    p_lat = bench_producer_only(farm, lanes, n_windows)
    _, s_lat = bench_farm(serial_farm, lanes, n_windows)
    _, d_lat = bench_farm(farm, lanes, n_windows)
    p50_p, _ = _percentiles(p_lat)
    p50_s, _ = _percentiles(s_lat)
    p50_d, _ = _percentiles(d_lat)
    if p50_p <= 0:
        return 0.0
    return float(np.clip((p50_s - p50_d) / p50_p, 0.0, 1.0))


def run(name: str, lane_sweep, sessions: int, n_windows: int, reps: int,
        engines, variant: str = "normal", producers=(None,), depths=(2,)):
    """Bench one cipher: coupled baseline + one farm lap per
    (engine, producer, depth) combo.

    ``variant`` is the schedule-orientation plan (core/schedule.py) the
    farm consumers execute.  Returns (coupled_thr, {label: thr}) across
    the sweep for the gate."""
    batches = {}
    for prod in producers:
        b = CipherBatch(name, seed=0, producer=prod)
        b.add_sessions(sessions)
        batches[b.producer.name] = b
    base = next(iter(batches.values()))
    # one engine instance per name, shared across farms (same params/key
    # for every producer batch: seed=0) — no per-combo retracing
    shared = {e: base.make_engine(e, variant=variant) for e in engines}
    farms, serial_farms = {}, {}
    for plabel, b in batches.items():
        for e in engines:
            for d in depths:
                label = f"farm[{e}|{plabel}|d{d}]"
                farms[label] = KeystreamFarm(b, engine=shared[e], depth=d)
                serial_farms[label] = KeystreamFarm(b, engine=shared[e],
                                                    depth=1)
    l = base.params.l
    print(f"\n{name}  (sessions={sessions}, engines={list(engines)}, "
          f"producers={list(batches)}, depths={list(depths)}, "
          f"schedule={variant}, backend={jax.default_backend()}, "
          f"windows={n_windows})")
    print(f"  {'lanes':>6}  {'mode':28} {'Melem/s':>9} {'win p50 ms':>11} "
          f"{'win p99 ms':>11} {'overlap':>8}")
    modes = [("coupled/session", bench_coupled, base)]
    modes += [(label, bench_farm, farm) for label, farm in farms.items()]
    coupled_thr = []
    farm_thr = {label: [] for label in farms}
    for lanes in lane_sweep:
        # best-of-reps, modes interleaved within each rep so machine-load
        # drift cannot systematically favor one mode
        best = {label: (0.0, None) for label, _, _ in modes}
        for _ in range(reps):
            for label, fn, target in modes:
                total, lat = fn(target, lanes, n_windows)
                thr = n_windows * lanes * l / total / 1e6
                if thr > best[label][0]:
                    best[label] = (thr, lat)
        for label, _, _ in modes:
            thr, lat = best[label]
            p50, p99 = _percentiles(lat)
            if label in farms:
                ov = overlap_ratio(farms[label], serial_farms[label],
                                   lanes, n_windows)
                ov_s = f"{ov:8.2f}"
            else:
                ov_s = f"{'-':>8}"
            print(f"  {lanes:6d}  {label:28} {thr:9.2f} {p50:11.2f} "
                  f"{p99:11.2f} {ov_s}")
        coupled_thr.append(best["coupled/session"][0])
        for label in farms:
            farm_thr[label].append(best[label][0])
    return np.asarray(coupled_thr), {label: np.asarray(t)
                                     for label, t in farm_thr.items()}


def check(name, lane_sweep, coupled, farm, label):
    ok_beat = bool(np.all(farm >= coupled))
    # monotonic up to saturation: strictly rising (3% tolerance) until the
    # peak, flat-to-noisy after
    sat = int(np.argmax(farm))
    ok_mono = all(farm[i + 1] > farm[i] * 0.97 for i in range(sat))
    print(f"  {name}: {label} >= coupled at every lane count: "
          f"{'PASS' if ok_beat else 'FAIL'} "
          f"(min ratio {float(np.min(farm / coupled)):.2f}x)")
    print(f"  {name}: throughput monotonic up to saturation "
          f"(peak at lanes={lane_sweep[sat]}): "
          f"{'PASS' if ok_mono else 'FAIL'}")
    return ok_beat and ok_mono


def orientation_delta(name: str, engine: str, lanes: int, sessions: int,
                      n_windows: int):
    """Per-window p50/p99 delta between the two orientation plans.

    Both variants are bit-exact (Eq. 2) — this measures the *scheduling*
    cost only: on the unrolled kernel the alternating plan should be free
    (the flip is a static output relabeling); on XLA executors it may pay a
    minor-dim transpose per flipped MRMC."""
    batch = CipherBatch(name, seed=0)
    batch.add_sessions(sessions)
    stats = {}
    for variant in ("normal", "alternating"):
        farm = KeystreamFarm(batch, engine=engine, variant=variant)
        _, lat = bench_farm(farm, lanes, n_windows)
        stats[variant] = _percentiles(lat)
    (n50, n99), (a50, a99) = stats["normal"], stats["alternating"]
    d50 = (a50 - n50) / n50 * 100 if n50 else 0.0
    d99 = (a99 - n99) / n99 * 100 if n99 else 0.0
    print(f"  {name}: farm[{engine}] orientation delta @ lanes={lanes}: "
          f"p50 {n50:.2f} -> {a50:.2f} ms ({d50:+.1f}%), "
          f"p99 {n99:.2f} -> {a99:.2f} ms ({d99:+.1f}%)")


# ==========================================================================
# Trajectory snapshot (benchmarks/BENCH_farm_trajectory.json)
# ==========================================================================
def _entry_key(preset, engine, producer, depth, mdepth):
    return f"{preset}|{engine}|{producer}|d{depth}|m{mdepth}"


def build_farm_snapshot(presets=SNAPSHOT_PRESETS, sessions=2, lanes=16,
                        n_windows=4, reps=2, engines=None, depth=2):
    """One timed lap per preset x engine x producer x matrix_depth.

    matrix_depth sweeps (1, 2) on matrix-streaming presets (the plane-split
    FIFO engages at 2) and stays (1,) elsewhere; per entry the best-of-reps
    per-window p50/p99 and the overlap ratio vs a depth-1 serialized farm
    are recorded, plus the matrix-prefetch overlap improvement per
    (preset, engine) — the farm-level payoff of producing the heavy
    matrix planes ahead of the vector constants.
    """
    import json  # noqa: F401  (callers re-serialize)

    entries = {}
    improvements = {}
    for name in presets:
        batch = CipherBatch(name, seed=0)
        batch.add_sessions(sessions)
        mdepths = (1, 2) if batch.params.n_matrix_constants else (1,)
        for e in engines or default_engines():
            eng = batch.make_engine(e)
            serial = KeystreamFarm(batch, engine=eng, depth=1)
            overlaps = {}
            for md in mdepths:
                farm = KeystreamFarm(batch, engine=eng, depth=depth,
                                     matrix_depth=md)
                best = (float("inf"), float("inf"))
                for _ in range(reps):
                    _, lat = bench_farm(farm, lanes, n_windows)
                    p50, p99 = _percentiles(lat)
                    if p50 < best[0]:
                        best = (p50, p99)
                ov = overlap_ratio(farm, serial, lanes, n_windows)
                overlaps[md] = ov
                key = _entry_key(name, e, batch.producer.name, depth, md)
                entries[key] = {
                    "preset": name, "engine": e,
                    "producer": batch.producer.name,
                    "depth": depth, "matrix_depth": md,
                    "p50_ms": round(best[0], 4), "p99_ms": round(best[1], 4),
                    "overlap": round(ov, 4),
                }
            if len(mdepths) > 1:
                improvements[f"{name}|{e}"] = round(
                    overlaps[2] - overlaps[1], 4)
    return {
        "schema": SNAPSHOT_SCHEMA,
        "backend": jax.default_backend(),
        "sessions": sessions, "lanes": lanes, "windows": n_windows,
        "entries": entries,
        "matrix_overlap_improvement": improvements,
    }


def check_farm_snapshot(snapshot: dict, current: dict, strict: bool) -> list:
    """Compare a stored trajectory snapshot against a fresh lap.

    Structure (schema, entry set) must match exactly — errors.  Per-window
    p50/p99 regressions beyond REGRESSION_TOL are warnings, errors under
    --strict (timings are host-dependent; a clean CI host must still
    pass) — the same contract as the analysis snapshot's measured-drift
    gate.  Returns (level, message) pairs, level in {"error", "warning"}.
    """
    if snapshot.get("schema") != SNAPSHOT_SCHEMA:
        return [("error", f"snapshot schema {snapshot.get('schema')} != "
                 f"{SNAPSHOT_SCHEMA}; regenerate with --snapshot")]
    problems = []
    for key, snap in sorted(snapshot.get("entries", {}).items()):
        cur = current["entries"].get(key)
        if cur is None:
            problems.append(("error", f"{key}: entry vanished from the "
                             "current sweep (preset/engine/producer or "
                             "depth wiring drifted)"))
            continue
        for field in ("p50_ms", "p99_ms"):
            was, now = snap[field], cur[field]
            if was <= 0:
                continue
            reg = (now - was) / was
            if reg > REGRESSION_TOL:
                level = "error" if strict else "warning"
                problems.append(
                    (level, f"{key}: {field} regressed {reg * 100:.0f}% "
                     f"(snapshot {was:.3f} ms, now {now:.3f} ms)"))
    for key in sorted(current.get("entries", {})):
        if key not in snapshot.get("entries", {}):
            problems.append(("error", f"{key}: new entry missing from the "
                             "snapshot; regenerate with --snapshot"))
    for key, was in sorted(
            snapshot.get("matrix_overlap_improvement", {}).items()):
        now = current.get("matrix_overlap_improvement", {}).get(key)
        if now is None:
            problems.append(("error", f"{key}: matrix overlap improvement "
                             "no longer measured"))
        elif was > 0 and now <= 0:
            problems.append(("warning", f"{key}: matrix_depth=2 overlap "
                             f"improvement went non-positive "
                             f"({was:+.3f} -> {now:+.3f})"))
    return problems


def default_engines():
    """The primary (auto) engine plus 'jax' — the engines worth timing on
    this backend.  --engines all adds every *available* registered engine
    except interpret-mode Pallas (a correctness tool: seconds per window)."""
    primary = resolve_engine("auto")
    return list(dict.fromkeys([primary, "jax"]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--windows", type=int, default=8)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--lanes", type=int, nargs="*", default=None,
                    help="lane sweep (each a multiple of --sessions)")
    ap.add_argument("--engines", nargs="*", default=None,
                    help="farm consumer engines to sweep (default: auto + "
                         "jax; 'all' = every available non-interpret "
                         "engine)")
    ap.add_argument("--producer", nargs="*", default=None,
                    help="constants producers to sweep (repro.core.producer"
                         " names; default: the preset's declared XOF "
                         "stream)")
    ap.add_argument("--depth", type=int, nargs="*", default=None,
                    help="farm pipeline depths to sweep (default: 2 = "
                         "double buffering)")
    ap.add_argument("--schedule", choices=["normal", "alternating"],
                    default="normal",
                    help="schedule-orientation plan the farm consumers "
                         "execute (core/schedule.py; bit-exact either way)")
    ap.add_argument("--presets", nargs="*", default=None,
                    choices=sorted(REGISTRY),
                    help="cipher presets to bench (default: one per "
                         f"cipher kind: {', '.join(DEFAULT_PRESETS)})")
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for smoke runs")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI canary: 2 sessions, 16 lanes, no "
                         "PASS/FAIL gate")
    ap.add_argument("--snapshot", action="store_true",
                    help="write the trajectory snapshot "
                         "(benchmarks/BENCH_farm_trajectory.json)")
    ap.add_argument("--check", action="store_true",
                    help="compare a fresh lap against the checked-in "
                         "trajectory snapshot; exit 1 on structural drift")
    ap.add_argument("--strict", action="store_true",
                    help="with --check: >20%% p50/p99 regression is an "
                         "error, not a warning")
    ap.add_argument("--snapshot-path", type=pathlib.Path,
                    default=DEFAULT_SNAPSHOT, metavar="PATH")
    args = ap.parse_args()

    if args.snapshot or args.check:
        import json

        current = build_farm_snapshot(engines=args.engines or None)
        if args.snapshot:
            args.snapshot_path.write_text(
                json.dumps(current, indent=1, sort_keys=True) + "\n")
            print(f"wrote {args.snapshot_path}")
            for key, imp in sorted(
                    current["matrix_overlap_improvement"].items()):
                print(f"  matrix-prefetch overlap improvement {key}: "
                      f"{imp:+.3f}")
            return 0
        if not args.snapshot_path.exists():
            print(f"snapshot {args.snapshot_path} missing; run --snapshot",
                  file=sys.stderr)
            return 1
        snapshot = json.loads(args.snapshot_path.read_text())
        problems = check_farm_snapshot(snapshot, current, strict=args.strict)
        for level, msg in problems:
            print(f"[{level}] {msg}")
        errors = [m for level, m in problems if level == "error"]
        print(f"farm trajectory check: {len(errors)} error(s), "
              f"{len(problems) - len(errors)} warning(s)")
        return 0 if not errors else 1
    if args.smoke:
        args.sessions, args.windows, args.reps = 2, 4, 1
        args.lanes = args.lanes or [16]
    # floor of 64 lanes: below ~8 blocks/session the windows are degenerate
    # (dispatch overhead dominates both modes and the comparison is noise)
    sweep = args.lanes or ([64, 256] if args.quick
                           else [64, 256, 1024])
    sweep = [s for s in sweep if s % args.sessions == 0] or [args.sessions]

    engines = args.engines
    if engines == ["all"]:
        engines = [n for n, c in engine_caps().items()
                   if c.available and n != "pallas-interpret"]
    elif not engines:
        engines = default_engines()
    producers = args.producer or [None]
    depths = args.depth or [2]
    # gate on the auto engine when it's in the sweep (with --engines all
    # the list is alphabetical — position 0 is not the primary)
    auto = resolve_engine("auto")
    primary_engine = auto if auto in engines else engines[0]

    ok = True
    for name in (args.presets or DEFAULT_PRESETS):
        coupled, farm = run(name, sweep, args.sessions, args.windows,
                            args.reps, engines, variant=args.schedule,
                            producers=producers, depths=depths)
        # the gate rides on the primary engine's first (producer, depth)
        primary = next(label for label in farm
                       if label.startswith(f"farm[{primary_engine}|"))
        if not args.smoke:
            ok &= check(name, sweep, coupled, farm[primary], primary)
            orientation_delta(name, primary_engine, sweep[-1],
                              args.sessions, args.windows)
    if args.smoke:
        print(f"\nsmoke lap complete (schedule={args.schedule}, no gating; "
              "overlap column reported above)")
        return 0
    print(f"\noverall: {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
