"""Serving-plane load bench: replayable open-loop traffic against the
asyncio TCP front end (`repro.serve.server`).

    PYTHONPATH=src python benchmarks/serve_load_bench.py [--smoke]
    PYTHONPATH=src python benchmarks/serve_load_bench.py --snapshot
    PYTHONPATH=src python benchmarks/serve_load_bench.py --smoke --check

Each lap boots a real :class:`~repro.serve.server.ServePlane` on loopback
and drives it with N concurrent :class:`~repro.serve.server.ServeClient`
connections generating the serving plane's three load dimensions:

  * **Poisson arrivals** — per-client exponential inter-arrival sleeps
    (open-loop: submits pipeline, they do not wait for earlier replies),
    so windows fill from asynchronous bursts the way real traffic fills
    them rather than in lock-step;
  * **session churn** — every ``churn`` requests a client live-rotates
    its session mid-stream (`rotate` op: pending old-nonce lanes
    materialize first), and halfway through the lap it opens a second
    session, so the tenant's window packer sees a shifting session mix;
  * **hot-key skew** — clients map onto tenants through a Zipf draw, so
    one hot tenant takes most of the traffic while cold tenants exercise
    the LRU registry's long tail.

Reported per preset: sustained req/s, client-observed p50/p99 reply
latency, and the server's scheduler counters (windows served, deadline
fires, shed).  Requests are ``keystream`` submits of 1..4 blocks — the
transciphering feed shape — so the lap times the scheduler and the farm,
not client-side crypto.

--snapshot writes benchmarks/BENCH_serve_trajectory.json: one entry per
preset with req/s and p50/p99 for the fixed smoke-sized profile.
--check replays the same profile and flags entry drift (errors) and
>REGRESSION_TOL slowdowns — req/s drops and p50/p99 growth — as
warnings, errors under --strict: the same contract as the farm
trajectory gate (timings are host-dependent, structure is not).  The
ci.sh ``serve-gate`` stage runs --smoke --check.
"""

import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import argparse
import asyncio
import dataclasses
import time

import numpy as np

SNAPSHOT_SCHEMA = 1
DEFAULT_SNAPSHOT = (pathlib.Path(__file__).parent
                    / "BENCH_serve_trajectory.json")
#: relative req/s / p50 / p99 regression --check flags
REGRESSION_TOL = 0.20
#: the cheapest preset plus the matrix-streaming large set — the two
#: serving points the acceptance gate names
SNAPSHOT_PRESETS = ("hera-80", "pasta-128l")


@dataclasses.dataclass(frozen=True)
class LoadProfile:
    """One replayable traffic shape (the snapshot pins the smoke shape)."""

    clients: int = 8
    tenants: int = 3          # Zipf-skewed assignment across these
    requests: int = 12        # per client
    window: int = 16
    deadline_ms: float = 10.0
    max_pending_lanes: int = 256
    mean_gap_ms: float = 2.0  # Poisson inter-arrival mean per client
    churn: int = 5            # rotate the session every N requests
    seed: int = 0
    reps: int = 2             # snapshot laps: keep the best-of-reps by p50


SMOKE = LoadProfile()
FULL = LoadProfile(clients=16, tenants=5, requests=40, window=32,
                   mean_gap_ms=1.0)


def _zipf_tenant(rng, n_tenants: int) -> str:
    """Hot-key skew: tenant 0 takes the bulk of the clients."""
    return f"t{min(int(rng.zipf(1.8)) - 1, n_tenants - 1)}"


async def _client_load(client, sessions: list, profile: LoadProfile, rng,
                       latencies: list, counters: dict) -> None:
    """One connection's open-loop lap: Poisson-spaced pipelined keystream
    submits with mid-stream rotation churn and a session switch.

    ``sessions`` are pre-opened (two per client) so the timed lap never
    grows a tenant's session pool — pool growth retraces the farm
    producer, and a compile inside the lap would swamp the scheduling
    latencies this bench exists to measure.  Rotation (same pool size,
    fresh nonce) stays inside the lap: it is cheap and IS the churn under
    test."""
    active = sessions[:1]
    inflight = []

    async def one(session_id: int, blocks: int):
        t0 = time.perf_counter()
        r = await client.call({
            "op": "submit", "tenant": client.tenant, "session": session_id,
            "hhe_op": "keystream", "blocks": blocks,
        })
        if r.get("ok"):
            latencies.append(time.perf_counter() - t0)
            counters["ok"] += 1
        elif r.get("shed"):
            counters["shed"] += 1
        else:
            counters["failed"] += 1

    for i in range(profile.requests):
        if i and i % profile.churn == 0:
            # live rotation under load — wait for in-flight submits on
            # this session first so the old-nonce lanes all land
            await asyncio.gather(*inflight)
            inflight.clear()
            await client.rotate(active[-1])
            counters["rotations"] += 1
        if i == profile.requests // 2 and len(active) == 1:
            active.append(sessions[1])     # session churn: switch streams
        blocks = int(rng.integers(1, 5))
        inflight.append(asyncio.get_running_loop().create_task(
            one(active[-1], blocks)))
        await asyncio.sleep(float(rng.exponential(
            profile.mean_gap_ms / 1e3)))
    await asyncio.gather(*inflight)


async def _run_lap(preset: str, profile: LoadProfile) -> dict:
    from repro.serve.server import ServeClient, ServePlane
    from repro.serve.tenants import TenantRegistry

    registry = TenantRegistry(
        preset, capacity=profile.tenants, window=profile.window,
        deadline_s=profile.deadline_ms / 1e3,
        max_pending_lanes=profile.max_pending_lanes, overload="shed",
        seed=profile.seed)
    plane = ServePlane(registry, port=0, tick_s=0.002)
    host, port = await plane.start()

    rng = np.random.default_rng(profile.seed)
    clients = [
        ServeClient(host, port, _zipf_tenant(rng, profile.tenants))
        for _ in range(profile.clients)
    ]
    try:
        for c in clients:
            await c.connect()
        # pre-open every session FIRST (each tenant's pool reaches its
        # final size), then one awaited submit per distinct tenant
        # compiles its farm programs — so the timed lap never traces
        sessions = [[await c.open_session(), await c.open_session()]
                    for c in clients]
        warmed = set()
        for c, sess in zip(clients, sessions):
            if c.tenant in warmed:
                continue
            warmed.add(c.tenant)
            r = await c.call({"op": "submit", "tenant": c.tenant,
                              "session": sess[0], "hhe_op": "keystream",
                              "blocks": profile.window})
            assert r.get("ok"), f"warmup submit failed: {r}"

        latencies: list = []
        counters = {"ok": 0, "shed": 0, "failed": 0, "rotations": 0}
        t0 = time.perf_counter()
        await asyncio.gather(*[
            _client_load(c, sess, profile,
                         np.random.default_rng(profile.seed + 1 + i),
                         latencies, counters)
            for i, (c, sess) in enumerate(zip(clients, sessions))
        ])
        wall = time.perf_counter() - t0
        stats = await clients[0].stats(tenant_scoped=False)
    finally:
        for c in clients:
            await c.close()
        await plane.stop()

    if counters["failed"]:
        raise RuntimeError(
            f"{counters['failed']} submits failed outright — the plane "
            "must serve or shed, never error, under this profile")
    lat = np.asarray(latencies) * 1e3
    per_tenant = stats["per_tenant"]
    return {
        "preset": preset,
        "clients": profile.clients,
        "tenants_live": stats["tenants"],
        "requests_ok": counters["ok"],
        "shed": counters["shed"],
        "rotations": counters["rotations"],
        "req_s": round(counters["ok"] / wall, 2),
        "p50_ms": round(float(np.percentile(lat, 50)), 3),
        "p99_ms": round(float(np.percentile(lat, 99)), 3),
        "windows_served": sum(t["windows_served"]
                              for t in per_tenant.values()),
        "deadline_fires": sum(t["deadline_fires"]
                              for t in per_tenant.values()),
        "fill_fires": sum(t["fill_fires"] for t in per_tenant.values()),
    }


def run_lap(preset: str, profile: LoadProfile) -> dict:
    return asyncio.run(_run_lap(preset, profile))


def _print_lap(r: dict) -> None:
    print(f"  {r['preset']:<12} {r['req_s']:>8.1f} req/s  "
          f"p50 {r['p50_ms']:>7.2f} ms  p99 {r['p99_ms']:>7.2f} ms  "
          f"ok={r['requests_ok']} shed={r['shed']} "
          f"rot={r['rotations']} windows={r['windows_served']} "
          f"(fill={r['fill_fires']}, deadline={r['deadline_fires']})")


# ==========================================================================
# Trajectory snapshot (benchmarks/BENCH_serve_trajectory.json)
# ==========================================================================
def build_serve_snapshot(presets=SNAPSHOT_PRESETS,
                         profile: LoadProfile = SMOKE) -> dict:
    entries = {}
    for preset in presets:
        # best-of-reps by p50: queueing latency under open-loop load is
        # the most noise-amplified metric; the floor is the stable signal
        # (same reasoning as the farm bench's best-of-reps)
        best = None
        for _ in range(max(1, profile.reps)):
            r = run_lap(preset, profile)
            if best is None or r["p50_ms"] < best["p50_ms"]:
                best = r
        _print_lap(best)
        entries[f"{preset}|smoke"] = best
    return {
        "schema": SNAPSHOT_SCHEMA,
        "profile": dataclasses.asdict(profile),
        "entries": entries,
    }


def check_serve_snapshot(snapshot: dict, current: dict,
                         strict: bool) -> list:
    """Structure (schema, entry set, profile) must match exactly —
    errors.  Throughput drops and latency growth beyond REGRESSION_TOL
    are warnings, errors under --strict.  Returns (level, message)
    pairs."""
    if snapshot.get("schema") != SNAPSHOT_SCHEMA:
        return [("error", f"snapshot schema {snapshot.get('schema')} != "
                 f"{SNAPSHOT_SCHEMA}; regenerate with --snapshot")]
    problems = []
    if snapshot.get("profile") != current.get("profile"):
        problems.append(("error", "load profile drifted from the snapshot "
                         "(regenerate with --snapshot)"))
    for key, snap in sorted(snapshot.get("entries", {}).items()):
        cur = current["entries"].get(key)
        if cur is None:
            problems.append(("error", f"{key}: entry vanished from the "
                             "current lap (preset wiring drifted)"))
            continue
        checks = (("req_s", -1), ("p50_ms", +1), ("p99_ms", +1))
        for field, direction in checks:
            was, now = snap[field], cur[field]
            if was <= 0:
                continue
            reg = direction * (now - was) / was
            if reg > REGRESSION_TOL:
                level = "error" if strict else "warning"
                what = "dropped" if direction < 0 else "regressed"
                problems.append(
                    (level, f"{key}: {field} {what} {reg * 100:.0f}% "
                     f"(snapshot {was}, now {now})"))
    for key in sorted(current.get("entries", {})):
        if key not in snapshot.get("entries", {}):
            problems.append(("error", f"{key}: new entry missing from the "
                             "snapshot; regenerate with --snapshot"))
    return problems


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--presets", nargs="*", default=None,
                    help=f"cipher presets (default {SNAPSHOT_PRESETS})")
    ap.add_argument("--smoke", action="store_true",
                    help="the small fixed profile the snapshot pins")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--snapshot", action="store_true",
                    help="write the trajectory snapshot "
                         "(benchmarks/BENCH_serve_trajectory.json)")
    ap.add_argument("--check", action="store_true",
                    help="replay the snapshot profile and compare; exit 1 "
                         "on structural drift")
    ap.add_argument("--strict", action="store_true",
                    help="with --check: >20%% req/s / latency regression "
                         "is an error, not a warning")
    ap.add_argument("--snapshot-path", type=pathlib.Path,
                    default=DEFAULT_SNAPSHOT, metavar="PATH")
    args = ap.parse_args()

    presets = tuple(args.presets) if args.presets else SNAPSHOT_PRESETS

    if args.snapshot or args.check:
        import json

        print("serve load lap (snapshot profile):")
        current = build_serve_snapshot(presets)
        if args.snapshot:
            args.snapshot_path.write_text(
                json.dumps(current, indent=1, sort_keys=True) + "\n")
            print(f"wrote {args.snapshot_path}")
            return 0
        if not args.snapshot_path.exists():
            print(f"snapshot {args.snapshot_path} missing; run --snapshot",
                  file=sys.stderr)
            return 1
        snapshot = json.loads(args.snapshot_path.read_text())
        problems = check_serve_snapshot(snapshot, current,
                                        strict=args.strict)
        for level, msg in problems:
            print(f"[{level}] {msg}")
        errors = [m for level, m in problems if level == "error"]
        print(f"serve trajectory check: {len(errors)} error(s), "
              f"{len(problems) - len(errors)} warning(s)")
        return 0 if not errors else 1

    profile = SMOKE if args.smoke else FULL
    if args.clients or args.requests:
        profile = dataclasses.replace(
            profile, clients=args.clients or profile.clients,
            requests=args.requests or profile.requests)
    print(f"serve load lap ({'smoke' if args.smoke else 'full'} profile, "
          f"{profile.clients} clients, {profile.requests} req/client):")
    for preset in presets:
        _print_lap(run_lap(preset, profile))
    return 0


if __name__ == "__main__":
    sys.exit(main())
