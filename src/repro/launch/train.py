"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --smoke \
        --steps 50 --batch 8 --seq 128 --encrypted --cipher rubato-128l

Production use targets the (16,16)/(2,16,16) meshes; on this CPU container
use --smoke (reduced config, host mesh).  Includes: checkpoint/restart
(--ckpt-dir, auto-resume), straggler watchdog, deterministic resumable data,
optional HHE-encrypted data plane.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.encrypted import EncryptedSource, make_decryptor
from repro.data.pipeline import make_source
from repro.core.cipher import make_cipher
from repro.launch.elastic import StragglerWatchdog
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.models.sharding import make_policy
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_loop import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--encrypted", action="store_true",
                    help="HHE-encrypted data plane")
    ap.add_argument("--cipher", default="rubato-128l")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    policy = make_policy(mesh, cfg, batch=args.batch, train=True)
    opt = OptConfig(lr=args.lr, eightbit=cfg.opt_8bit,
                    total_steps=args.steps, warmup_steps=max(args.steps // 20, 5))

    source = make_source(cfg, args.batch, args.seq, seed=args.seed)
    decryptor = None
    if args.encrypted:
        cipher = make_cipher(args.cipher, seed=args.seed)
        source = EncryptedSource(source, cipher)
        decryptor = make_decryptor(cipher)

    step_fn, _specs = make_train_step(
        cfg, policy, opt, microbatch=args.microbatch, decryptor=decryptor,
    )

    params = M.init_params(cfg, jax.random.key(args.seed))
    opt_state = init_opt_state(params, opt)
    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start_step, extra = ckpt.restore(
            args.ckpt_dir, (params, opt_state)
        )
        print(f"resumed from step {start_step}")

    watchdog = StragglerWatchdog()
    t_log = time.time()
    for step in range(start_step, args.steps):
        batch = source.batch_at(step)
        batch = jax.tree.map(jnp.asarray, batch)
        t0 = time.time()
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jnp.asarray(step, jnp.int32)
        )
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        if watchdog.observe(step, dt):
            print(f"[watchdog] straggler event at step {step}: {dt:.2f}s")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  {dt*1e3:.0f} ms  "
                  f"({time.time()-t_log:.1f}s total)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, (params, opt_state),
                      extra={"data_step": step + 1}, async_write=True)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, (params, opt_state),
                  extra={"data_step": args.steps})
    print("done")
    return params


if __name__ == "__main__":
    main()
