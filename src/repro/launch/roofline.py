import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

"""Roofline analysis — probe-composed, exact FLOP accounting.

XLA's cost_analysis counts EVERY while-loop body ONCE (verified): that
includes the scan over layer groups, the flash-attention KV scan, the SSD
chunk scan, the CE chunk scan and the microbatch scan.  So the roofline is
assembled from PROBES compiled with `probe_unroll=True` configs (all inner
scans unrolled -> every FLOP visible):

  train:   total = mb * (G * group_bwd + embed_bwd + ce_bwd) + optimizer
  prefill: total = G * group_fwd + embed_fwd + head_fwd(last token)
  decode:  total = G * group_decode + embed + head     (via 1-group step
           minus embed/head probes)

Each probe runs under the SAME mesh and shardings as the real cell, so
collective bytes (parsed per-device from the probe HLO) compose the same
way.  Memory numbers come from the full-step dry-run (dryrun_results.json).

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI.

    T_comp = FLOPs_per_dev / 197e12
    T_mem  = Bytes_per_dev / 819e9      (bytes-accessed upper bound: XLA
             counts every op's operands; on-chip fusion reduces real HBM
             traffic, so true T_mem is lower — see EXPERIMENTS.md)
    T_coll = CollBytes_per_dev / 50e9

MFU-proxy = T_comp / max(terms); useful = MODEL_FLOPS / (FLOPs_per_dev * chips).
"""

import argparse
import dataclasses
import functools
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.launch import cells as C
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.sharding import make_policy

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
MICROBATCH = 4  # must match dryrun.lower_cell


def _cost(compiled):
    ca = compiled.cost_analysis()
    cb, _, _ = collective_bytes(compiled.as_text())
    return {"flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
            "coll": float(cb)}


def _zero():
    return {"flops": 0.0, "bytes": 0.0, "coll": 0.0}


def _add(*costs, scales=None):
    scales = scales or [1.0] * len(costs)
    out = _zero()
    for c, s in zip(costs, scales):
        for k in out:
            out[k] += s * c[k]
    return out


def _shard(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


class CellProber:
    def __init__(self, arch: str, shape_name: str, mesh):
        from repro.train.train_loop import act_shardings
        self.cfg = get_config(arch)
        self.shape = C.SHAPES[shape_name]
        self.train = self.shape.kind == "train"
        self.policy = make_policy(mesh, self.cfg,
                                  batch=self.shape.global_batch,
                                  train=self.train)
        self.mesh = self.policy.mesh
        # 1-group model with all inner scans unrolled
        self.cfg1 = dataclasses.replace(
            self.cfg, num_layers=len(self.cfg.group), probe_unroll=True)
        self.acts = act_shardings(self.cfg1, self.policy)
        self.B = (self.shape.global_batch // MICROBATCH if self.train
                  else self.shape.global_batch)
        self.T = self.shape.seq_len
        bs = tuple(self.policy.batch_spec())
        self.x_spec = P(bs[0], bs[1], self.policy.tp_full)
        self.tok_spec = P(*bs)

    def _compile(self, fn, args, in_specs):
        jf = jax.jit(fn, in_shardings=_shard(self.mesh, in_specs))
        with self.mesh:
            return _cost(jf.lower(*args).compile())

    # ------------------------------------------------------------------
    def group_probe(self):
        cfg1, policy = self.cfg1, self.policy
        pspecs = M.param_specs(cfg1, policy)["blocks"]
        params = C.params_specs_abstract(cfg1)["blocks"]
        x = jax.ShapeDtypeStruct((self.B, self.T, cfg1.d_model), jnp.bfloat16)
        pos = jax.ShapeDtypeStruct(
            (self.B, self.T) + ((3,) if cfg1.rope_kind == "mrope" else ()),
            jnp.int32)
        from repro.models import attention as A

        def apply_group(blocks, x, pos):
            cos, sin = (A.rope_angles(cfg1, pos)
                        if cfg1.rope_kind != "none" else (None, None))
            aux = jnp.zeros((), jnp.float32)
            for spec, p in zip(cfg1.group, blocks):
                fn = functools.partial(
                    lambda sp, pp, xx: M._block_apply(
                        cfg1, sp, pp, xx, cos, sin, shardings=self.acts)[::2],
                    spec)
                if cfg1.remat and self.train:
                    fn = jax.checkpoint(
                        fn, policy=jax.checkpoint_policies.nothing_saveable)
                x, a = fn(p, x)
                aux = aux + a
            return x, aux

        # strip the leading group dim from stacked params
        blocks1 = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), params)
        bspecs1 = jax.tree.map(
            lambda s: P(*tuple(s)[1:]), pspecs,
            is_leaf=lambda x: isinstance(x, P))

        if self.train:
            def probe(blocks, x, pos):
                def lf(b, xx):
                    y, aux = apply_group(b, xx, pos)
                    return jnp.sum(y.astype(jnp.float32) ** 2) + aux
                return jax.grad(lf, argnums=(0, 1))(blocks, x)
        else:
            probe = apply_group
        return self._compile(
            probe, (blocks1, jax.ShapeDtypeStruct((self.B, self.T, self.cfg.d_model), jnp.bfloat16), pos),
            (bspecs1, self.x_spec, self.tok_spec if pos.ndim == 2 else P(*(tuple(self.tok_spec) + (None,)))),
        )

    def embed_probe(self):
        cfg1 = self.cfg1
        Vp, D = cfg1.vocab_padded, cfg1.d_model
        emb = jax.ShapeDtypeStruct((Vp, D), jnp.dtype(
            jnp.bfloat16 if cfg1.param_dtype == "bfloat16" else jnp.float32))
        espec = self.policy.spec("embed", cfg1)
        if cfg1.frontend != "none":
            fr = jax.ShapeDtypeStruct((self.B, self.T, cfg1.frontend_dim),
                                      jnp.bfloat16)
            proj = jax.ShapeDtypeStruct((cfg1.frontend_dim, D), emb.dtype)

            def fwd(e, w):
                return jnp.einsum("btf,fd->btd", e, w.astype(e.dtype))

            if self.train:
                probe = lambda e, w: jax.grad(
                    lambda ww: jnp.sum(fwd(e, ww).astype(jnp.float32)))(w)
            else:
                probe = fwd
            return self._compile(
                probe, (fr, proj),
                (P(*(tuple(self.tok_spec) + (None,))),
                 self.policy.spec("frontend", cfg1)))
        toks = jax.ShapeDtypeStruct((self.B, self.T), jnp.int32)

        def fwd(e, t):
            return e[t]

        if self.train:
            probe = lambda e, t: jax.grad(
                lambda ee: jnp.sum(ee[t].astype(jnp.float32)))(e)
        else:
            probe = fwd
        return self._compile(probe, (emb, toks), (espec, self.tok_spec))

    def head_probe(self, n_tokens=None):
        """CE head (train: fwd+bwd over one chunk x n_chunks) or last-token
        logits (serve)."""
        cfg1, policy = self.cfg1, self.policy
        D = cfg1.d_model
        head_dt = jnp.dtype(
            jnp.bfloat16 if cfg1.param_dtype == "bfloat16" else jnp.float32)
        if cfg1.tie_embeddings:
            w = jax.ShapeDtypeStruct((cfg1.vocab_padded, D), head_dt)
            wspec = policy.spec("embed", cfg1)
            logits_fn = lambda x, w: jnp.einsum(
                "btd,vd->btv", x, w.astype(x.dtype)).astype(jnp.float32)
        else:
            w = jax.ShapeDtypeStruct((D, cfg1.vocab_padded), head_dt)
            wspec = policy.spec("head", cfg1)
            logits_fn = lambda x, w: jnp.einsum(
                "btd,dv->btv", x, w.astype(x.dtype)).astype(jnp.float32)

        if self.train:
            CE_CHUNKS = 8
            Tc = self.T // CE_CHUNKS
            x = jax.ShapeDtypeStruct((self.B, Tc, D), jnp.bfloat16)
            lab = jax.ShapeDtypeStruct((self.B, Tc), jnp.int32)

            def probe(x, w, lab):
                def lf(x, w):
                    lg = logits_fn(x, w)
                    lz = jax.nn.logsumexp(lg, axis=-1)
                    io = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
                    ll = jnp.sum(jnp.where(io == lab[..., None], lg, 0.0), -1)
                    return jnp.sum(lz - ll)
                return jax.grad(lf, argnums=(0, 1))(x, w)

            c = self._compile(probe, (x, w, lab),
                              (self.x_spec, wspec, self.tok_spec))
            return _add(c, scales=[CE_CHUNKS])
        # serve: last-token logits only
        x = jax.ShapeDtypeStruct((self.B, 1, D), jnp.bfloat16)
        return self._compile(lambda x, w: logits_fn(x, w), (x, w),
                             (P(tuple(self.x_spec)[0], None, None), wspec))

    def opt_probe(self):
        from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, opt_state_specs
        cfg = self.cfg
        opt = OptConfig(eightbit=cfg.opt_8bit)
        params = C.params_specs_abstract(cfg)
        pspecs = M.param_specs(cfg, self.policy)
        ostate = jax.eval_shape(functools.partial(init_opt_state, cfg=opt),
                                params)
        ospecs = opt_state_specs(pspecs, params, opt)
        gspecs = pspecs

        def probe(p, g, s):
            return adamw_update(p, g, s, jnp.asarray(1, jnp.int32), opt)[:2]

        grads = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape, jnp.bfloat16 if cfg.param_dtype == "bfloat16"
                else jnp.float32), params)
        return self._compile(probe, (params, grads, ostate),
                             (pspecs, gspecs, ospecs))

    def decode_probe(self):
        """Full 1-group decode step (embed + 1 group + head)."""
        from repro.serve.serve_loop import make_decode_step
        cfg1 = self.cfg1
        fn = make_decode_step(cfg1, self.policy)
        params1 = C.params_specs_abstract(cfg1)
        cache1 = C.cache_specs_abstract(cfg1, self.shape.global_batch, self.T)
        toks = jax.ShapeDtypeStruct((self.shape.global_batch, 1), jnp.int32)
        cl = jax.ShapeDtypeStruct((), jnp.int32)
        with self.policy.mesh:
            return _cost(fn.lower(params1, cache1, toks, cl).compile())


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = C.SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


def analyze_cell(arch: str, shape_name: str, mesh, chips: int,
                 mem_rec: dict | None = None):
    cfg = get_config(arch)
    pr = CellProber(arch, shape_name, mesh)
    G = cfg.num_groups
    if pr.shape.kind == "train":
        total = _add(pr.group_probe(), pr.embed_probe(), pr.head_probe(),
                     scales=[MICROBATCH * G, MICROBATCH, MICROBATCH])
        total = _add(total, pr.opt_probe())
    elif pr.shape.kind == "prefill":
        total = _add(pr.group_probe(), pr.embed_probe(),
                     scales=[G, 1])
        if cfg.causal:
            total = _add(total, pr.head_probe())
    else:
        one = pr.decode_probe()
        head = pr.head_probe()
        per_group = {k: max(one[k] - head[k], 0.0) for k in one}
        total = _add(one, per_group, scales=[1, G - 1])

    t_comp = total["flops"] / PEAK_FLOPS
    t_mem = total["bytes"] / HBM_BW
    t_coll = total["coll"] / ICI_BW
    dominant = max((("compute", t_comp), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(arch, shape_name)
    rec = {
        "arch": arch, "shape": shape_name, "chips": chips,
        "flops_per_dev": total["flops"], "bytes_per_dev": total["bytes"],
        "coll_bytes_per_dev": total["coll"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "step_time_s": max(t_comp, t_mem, t_coll),
        "mfu_proxy": t_comp / max(t_comp, t_mem, t_coll),
        "model_flops": mf,
        "useful_ratio": mf / max(total["flops"] * chips, 1.0),
        "tp": (pr.policy.tp_a, pr.policy.tp_b, pr.policy.sp),
    }
    if mem_rec:
        rec["peak_bytes_per_dev"] = mem_rec.get("peak_bytes_per_dev")
        rec["fits_16GB"] = (mem_rec.get("peak_bytes_per_dev", 0) < 16e9)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="dryrun_results.json")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="roofline_results.json")
    args = ap.parse_args()

    try:
        with open(args.dryrun) as f:
            dr = {(r["arch"], r["shape"]): r for r in json.load(f)
                  if r.get("ok") and not r.get("skipped")
                  and r["mesh"] == "1pod_16x16"}
    except FileNotFoundError:
        dr = {}

    mesh = make_production_mesh(multi_pod=False)
    out = []
    for arch, sname, ok, why in C.all_cells():
        if args.arch and arch != args.arch:
            continue
        if args.shape and sname != args.shape:
            continue
        if not ok:
            continue
        try:
            rec = analyze_cell(arch, sname, mesh, 256,
                               mem_rec=dr.get((arch, sname)))
            out.append(rec)
            print(f"{arch:18s} {sname:12s} dom={rec['dominant']:10s} "
                  f"Tc={rec['t_compute_s']:.2e} Tm={rec['t_memory_s']:.2e} "
                  f"Tx={rec['t_collective_s']:.2e} "
                  f"mfu~{rec['mfu_proxy']:.2f} useful={rec['useful_ratio']:.2f}")
        except Exception as e:
            import traceback
            print(f"{arch:18s} {sname:12s} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
