"""The (architecture × input-shape) cell matrix.

Shapes (assigned):
    train_4k     seq 4096,   global_batch 256  -> train_step
    prefill_32k  seq 32768,  global_batch 32   -> prefill (forward for
                                                  encoder-only archs)
    decode_32k   seq 32768,  global_batch 128  -> decode_step (1 new token,
                                                  cache of seq_len)
    long_500k    seq 524288, global_batch 1    -> decode_step; only for
                                                  sub-quadratic archs

`input_specs` returns ShapeDtypeStruct stand-ins for every input — weak-type
correct, shardable, zero allocation (the dry-run lowers against these).
Skips (docs/DESIGN.md §5): long_500k only for mamba2/jamba; hubert (encoder-only)
has no decode shapes.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config, list_archs
from repro.models import model as M


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

SUBQUADRATIC = {"mamba2-2.7b", "jamba-1.5-large"}


def cell_applicable(arch: str, shape_name: str):
    """Returns (applicable, reason_if_not)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "decode" and not cfg.causal:
        return False, "encoder-only arch has no autoregressive decode step"
    if shape_name == "long_500k" and arch not in SUBQUADRATIC:
        return False, ("full-attention arch: 500k context needs sub-quadratic "
                       "attention (see docs/DESIGN.md §5)")
    return True, ""


def all_cells():
    """Every (arch, shape) incl. skips: [(arch, shape, applicable, reason)]."""
    out = []
    for arch in list_archs():
        for sname in SHAPES:
            ok, why = cell_applicable(arch, sname)
            out.append((arch, sname, ok, why))
    return out


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input stand-ins
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_input_specs(cfg: ModelConfig, B: int, T: int, *, train: bool):
    d = {}
    if cfg.frontend == "none":
        d["tokens"] = _sds((B, T), jnp.int32)
    else:
        d["embeds"] = _sds((B, T, cfg.frontend_dim), jnp.bfloat16)
        if cfg.rope_kind == "mrope":
            d["positions"] = _sds((B, T, 3), jnp.int32)
    if train:
        d["labels"] = _sds((B, T), jnp.int32)
    return d


def params_specs_abstract(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(M.init_params, cfg), jax.random.key(0)
    )


def cache_specs_abstract(cfg: ModelConfig, B: int, max_len: int):
    return jax.eval_shape(
        functools.partial(M.init_cache, cfg, B, max_len)
    )


def input_specs(arch: str, shape_name: str, *, opt=None, smoke: bool = False):
    """All inputs for the cell's step function, as ShapeDtypeStructs.

    train  -> (params, opt_state, batch, step_idx)
    prefill-> (params, batch)
    decode -> (params, cache, tokens, cur_len)
    """
    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    B, T = shape.global_batch, shape.seq_len
    params = params_specs_abstract(cfg)
    if shape.kind == "train":
        from repro.train.optimizer import OptConfig, init_opt_state
        opt = opt or OptConfig(eightbit=cfg.opt_8bit)
        opt_state = jax.eval_shape(
            functools.partial(init_opt_state, cfg=opt), params
        )
        batch = batch_input_specs(cfg, B, T, train=True)
        return (params, opt_state, batch, _sds((), jnp.int32))
    if shape.kind == "prefill":
        batch = batch_input_specs(cfg, B, T, train=False)
        return (params, batch)
    # decode
    cache = cache_specs_abstract(cfg, B, T)
    tokens = _sds((B, 1), jnp.int32)
    return (params, cache, tokens, _sds((), jnp.int32))
