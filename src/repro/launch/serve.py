"""Serving driver: batched prefill + decode with the HHE-encrypted request
path (client sends HHE-encrypted prompts under any registered cipher
preset — HERA, Rubato, or PASTA; pod decrypts via keystream subtraction,
generates, and re-encrypts the response stream).

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --smoke \
        --batch 4 --prompt-len 32 --gen 16 --encrypted

The encrypted path is farm-backed: the server holds ONE symmetric key in a
:class:`repro.core.cipher.CipherBatch` pool with one `StreamSession` per
batch lane, and every keystream materialization — prompt decryption AND
response re-encryption — runs through the :class:`repro.serve.hhe_loop.
HHEServer` window scheduler over the depth-buffered `KeystreamFarm`
(consumer backend selectable with --engine; see `repro.core.engine`;
constants producer per `repro.core.producer`).  The whole pipeline tuple
(producer, engine, variant, window, depth) can come from a measured
`repro.core.tuner.StreamPlan`: --autotune measures one for this serving
shape and persists it; --plan serves from a persisted cache.  Clients
encrypt/decrypt with their own session's single-stream view
(`CipherBatch.session_cipher`) — bit-exact with the farm by contract.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.cipher import CipherBatch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.models.sharding import make_policy
from repro.serve.hhe_loop import HHERequest, HHEServer
from repro.serve.serve_loop import make_decode_step, make_prefill_step


def _pack_tokens(tokens_1d: np.ndarray, l: int) -> np.ndarray:
    """(T,) token ids -> (blocks, l) uint32, zero-padded to whole blocks."""
    t = np.asarray(tokens_1d).reshape(-1)
    nblk = -(t.shape[0] // -l)  # ceil
    out = np.zeros(nblk * l, np.uint32)
    out[: t.shape[0]] = t.astype(np.uint32)
    return out.reshape(nblk, l)


class EncryptedChannel:
    """The farm-backed HHE request path for one serving batch.

    Server role: an :class:`HHEServer` (one symmetric key, one session per
    batch lane, fixed-window farm scheduling).  Client role: per-lane
    single-stream encrypt/decrypt via ``session_cipher`` — the two sides
    share only (key, nonce, counters), never keystream material over the
    wire.
    """

    def __init__(self, cipher_name: str, batch: int, engine: str = "auto",
                 window: int = 0, seed: int = 0, variant: str = "auto",
                 plan=None):
        self.batch = CipherBatch(cipher_name, seed=seed)
        self.lanes = batch
        self.l = self.batch.params.l
        self.mod = self.batch.params.mod
        # window: one wave of per-lane prompt blocks by default, so a whole
        # prefill's decryption is a handful of shape-stable windows
        self.window = window
        self.server: HHEServer | None = None
        self.engine = engine
        # schedule-orientation plan: "auto" = the engine's preferred one
        # (alternating on the unrolled kernel; bit-exact either way)
        self.variant = variant
        # a measured StreamPlan (repro.core.tuner) overrides engine/variant
        # and supplies producer + FIFO depth + window in one shot
        self.plan = plan
        for _ in range(batch):
            self.batch.add_session()

    def _server(self, blocks_hint: int) -> HHEServer:
        if self.server is None:
            if self.plan is not None:
                # honor the plan's measured window unless --window overrode
                self.server = HHEServer(self.batch,
                                        window=self.window or None,
                                        plan=self.plan)
            else:
                w = self.window or max(1, self.lanes * blocks_hint)
                self.server = HHEServer(self.batch, window=w,
                                        engine=self.engine,
                                        variant=self.variant)
            self.server.warmup()
        return self.server

    # ---- client role ----------------------------------------------------
    def client_encrypt(self, tokens: np.ndarray) -> list:
        """(B, T) token ids -> per-lane (blocks, l) u32 ciphertext, lane i
        encrypted under session i's nonce on that session's next counters
        (read from the live cursor, so multi-turn channels stay aligned
        with the server's take_window reservations).

        The client owns its nonce: when a lane's counter space cannot fit
        the prompt, the client rotates the session BEFORE encrypting
        (fresh nonce, cursor 0) — never encrypts past the limit, which
        would alias earlier XOF streams (keystream reuse).
        """
        cts = []
        for i in range(self.lanes):
            pt = _pack_tokens(tokens[i], self.l)
            sess = self.batch.sessions[i]
            if pt.shape[0] > sess.remaining():
                # turn boundaries flush fully, so no server work is
                # pending against the old nonce here
                if self.server is not None:
                    self.server.flush()
                sess = self.batch.rotate_session(i)
                if pt.shape[0] > sess.remaining():
                    raise RuntimeError(
                        f"prompt of {pt.shape[0]} blocks exceeds a whole "
                        "session's counter space; split it across windows"
                    )
            ci = self.batch.session_cipher(i)
            ctrs = sess.next_ctr + jnp.arange(pt.shape[0], dtype=jnp.uint32)
            z = ci.keystream(ctrs)
            cts.append(np.asarray(self.mod.add(jnp.asarray(pt), z)))
        return cts

    def client_decrypt(self, ct: np.ndarray, block_ctrs, lane: int,
                       n_tokens: int) -> np.ndarray:
        """Decrypt one lane's (blocks, l) u32 response at the server-issued
        counters; returns (n_tokens,) int32."""
        ci = self.batch.session_cipher(lane)
        z = ci.keystream(jnp.asarray(block_ctrs, jnp.uint32))
        toks = np.asarray(self.mod.sub(jnp.asarray(ct), z))
        return toks.reshape(-1)[:n_tokens].astype(np.int32)

    # ---- server role (everything runs through hhe_loop windows) ---------
    def serve_decrypt_prompts(self, cts: list, prompt_len: int) -> jnp.ndarray:
        """Ciphertext prompts -> (B, T) token batch, via one farm flush."""
        srv = self._server(blocks_hint=cts[0].shape[0])
        for i, ct in enumerate(cts):
            srv.submit(HHERequest(session_id=i, op="decrypt_tokens",
                                  payload=ct))
        resps = srv.flush()
        toks = np.stack([
            r.result.reshape(-1)[:prompt_len] for r in resps
        ]).astype(np.int32)
        return jnp.asarray(toks)

    def serve_encrypt_responses(self, gen: np.ndarray) -> list:
        """(B, T_gen) generated tokens -> per-lane (ciphertext, block_ctrs),
        re-encrypted through the same farm windows."""
        srv = self._server(blocks_hint=_pack_tokens(gen[0], self.l).shape[0])
        for i in range(self.lanes):
            srv.submit(HHERequest(session_id=i, op="encrypt_tokens",
                                  payload=_pack_tokens(gen[i], self.l)))
        return [(r.result, r.block_ctrs) for r in srv.flush()]

    def latency_stats(self) -> dict:
        if self.server is not None:
            return self.server.latency_stats()
        # same zeroed shape HHEServer.latency_stats() guarantees pre-traffic
        return {"count": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0,
                "queue_depth_lanes": 0, "inflight_lanes": 0,
                "windows_served": 0, "fill_fires": 0, "deadline_fires": 0,
                "shed": 0, "rejected": 0}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--encrypted", action="store_true")
    from repro.core.params import REGISTRY as _CIPHERS
    ap.add_argument("--cipher", default="rubato-128l",
                    choices=sorted(_CIPHERS),
                    help="HHE cipher preset for --encrypted (any "
                         "registered kind: hera / rubato / pasta)")
    ap.add_argument("--engine", default="auto",
                    help="keystream engine for --encrypted "
                         "(see repro.core.engine; 'auto' resolves per "
                         "backend)")
    ap.add_argument("--window", type=int, default=0,
                    help="farm window lanes for --encrypted "
                         "(0 = one prompt wave)")
    ap.add_argument("--schedule-variant", default="auto",
                    choices=["auto", "normal", "alternating"],
                    help="cipher schedule-orientation plan for --encrypted "
                         "(core/schedule.py; 'auto' = engine preference)")
    ap.add_argument("--plan", default=None,
                    help="StreamPlan JSON cache to serve --encrypted from "
                         "(repro.core.tuner; looked up by preset + host)")
    ap.add_argument("--autotune", action="store_true",
                    help="measure a StreamPlan for this serving shape "
                         "before taking traffic (persisted to the tuner "
                         "cache; overrides --engine/--schedule-variant)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only; no decode serving")
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    policy = make_policy(mesh, cfg, batch=args.batch, train=False)
    max_len = args.prompt_len + args.gen

    prefill = make_prefill_step(cfg, policy, max_len)
    decode = make_decode_step(cfg, policy)

    params = M.init_params(cfg, jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)

    chan = None
    if args.encrypted:
        plan = None
        if args.plan or args.autotune:
            from repro.core.params import get_params
            from repro.core.tuner import autotune, load_plan

            # the serving window shape: one wave of per-lane prompt blocks
            cl = get_params(args.cipher).l
            lanes = args.window or max(
                1, args.batch * (-(args.prompt_len // -cl)))
            if args.autotune:
                plan = autotune(args.cipher, lanes, sessions=args.batch,
                                cache_path=args.plan, verbose=True)
            else:
                plan = load_plan(args.cipher, lanes, cache_path=args.plan)
                if plan is None:
                    raise SystemExit(
                        f"no StreamPlan cached for {args.cipher}/"
                        f"lanes={lanes} on this host in "
                        f"{args.plan} — run with --autotune first")
            print(f"serving from measured StreamPlan: {plan.describe()}")
        chan = EncryptedChannel(args.cipher, args.batch, engine=args.engine,
                                window=args.window, seed=args.seed,
                                variant=args.schedule_variant, plan=plan)
        cts = chan.client_encrypt(prompts)                 # client side
        toks = chan.serve_decrypt_prompts(cts, args.prompt_len)
        np.testing.assert_array_equal(np.asarray(toks), prompts)
        batch = {"tokens": toks}
        print(f"prompts arrived HHE-encrypted; decrypted through "
              f"KeystreamFarm windows (engine={chan.server.farm.engine.name}"
              f", schedule={chan.server.farm.engine.variant}"
              f", producer={chan.batch.producer.name}"
              f", depth={chan.server.farm.depth}"
              f", window={chan.server.window}, "
              f"{args.batch} sessions)")
    else:
        batch = {"tokens": jnp.asarray(prompts)}

    t0 = time.time()
    with policy.mesh:
        logits, cache, cur_len = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.3f}s")

    toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [toks]
    t0 = time.time()
    for i in range(args.gen - 1):
        cur_len = cur_len + 1
        with policy.mesh:
            logits, cache = decode(params, cache, toks, cur_len)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"decoded {args.gen-1} steps in {dt:.3f}s "
          f"({(args.gen-1)*args.batch/max(dt,1e-9):.1f} tok/s)")
    print("sample:", gen[0][:16])

    if chan is not None:
        enc = chan.serve_encrypt_responses(gen)            # server side
        for i, (ct, ctrs) in enumerate(enc):               # client side
            back = chan.client_decrypt(ct, ctrs, i, gen.shape[1])
            np.testing.assert_array_equal(back, gen[i])
        stats = chan.latency_stats()
        print(f"responses re-encrypted through the farm; round-trip "
              f"verified client-side ({len(enc)} lanes)")
        print(f"HHE window latency: count={stats['count']} "
              f"p50={stats['p50_ms']:.2f}ms p99={stats['p99_ms']:.2f}ms")
    return gen


if __name__ == "__main__":
    main()
