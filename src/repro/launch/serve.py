"""Serving driver: batched prefill + decode with the HHE-encrypted request
path (client sends Rubato-encrypted prompts; pod decrypts via keystream
subtraction, generates, and can re-encrypt the response stream).

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --smoke \
        --batch 4 --prompt-len 32 --gen 16 --encrypted
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.cipher import make_cipher
from repro.data.encrypted import encrypt_tokens, make_decryptor
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.models.sharding import make_policy
from repro.serve.serve_loop import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--encrypted", action="store_true")
    ap.add_argument("--cipher", default="rubato-128l")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only; no decode serving")
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    policy = make_policy(mesh, cfg, batch=args.batch, train=False)
    max_len = args.prompt_len + args.gen

    prefill = make_prefill_step(cfg, policy, max_len)
    decode = make_decode_step(cfg, policy)

    params = M.init_params(cfg, jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)

    if args.encrypted:
        cipher = make_cipher(args.cipher, seed=args.seed)
        enc = encrypt_tokens(cipher, prompts, base_ctr=0)
        dec = make_decryptor(cipher, labels_from_tokens=False)
        batch = {"tokens": dec(enc)["tokens"]}
        print("prompts arrived HHE-encrypted; decrypted on-device")
    else:
        batch = {"tokens": jnp.asarray(prompts)}

    t0 = time.time()
    with policy.mesh:
        logits, cache, cur_len = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.3f}s")

    toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [toks]
    t0 = time.time()
    for i in range(args.gen - 1):
        cur_len = cur_len + 1
        with policy.mesh:
            logits, cache = decode(params, cache, toks, cur_len)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"decoded {args.gen-1} steps in {dt:.3f}s "
          f"({(args.gen-1)*args.batch/max(dt,1e-9):.1f} tok/s)")
    print("sample:", gen[0][:16])
    return gen


if __name__ == "__main__":
    main()
