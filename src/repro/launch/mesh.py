"""Production mesh construction.

Defined as a FUNCTION so importing this module never touches jax device
state (device count is locked at first jax init; the dry-run sets
XLA_FLAGS before importing jax)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for smoke tests / CPU examples."""
    return jax.make_mesh((1, 1), ("data", "model"))
