"""Elastic scaling + straggler mitigation (docs/DESIGN.md §6).

Elasticity model: the mesh is rebuilt from surviving devices after a node
failure — the data/pod axes shrink to the largest supported configuration,
and the checkpoint restore path (train/checkpoint.py) reshards onto the new
mesh (restore takes arbitrary NamedShardings).  Because the data pipeline is
a pure function of (seed, step), no data-state migration is needed.

Straggler mitigation: at SPMD scale a straggler shows up as a slow step for
*everyone* (collectives synchronize).  The watchdog tracks a per-step-time
EMA; a sustained regression beyond `threshold`× flags a straggler event, and
the deployment policy is checkpoint -> evict -> elastic restart (hot-spare
promotion), which this module's `ElasticPlan` encodes.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import numpy as np
from jax.sharding import Mesh


SUPPORTED_DP = (32, 16, 8, 4, 2, 1)  # data-axis sizes we can shrink to


@dataclasses.dataclass
class ElasticPlan:
    mesh_shape: tuple
    axis_names: tuple
    n_devices: int
    dropped: int


def plan_mesh(available_devices: int, *, model: int = 16,
              multi_pod: bool = False) -> ElasticPlan:
    """Largest supported mesh from the surviving device count.

    The model axis is preserved (TP degree is baked into layer shardings);
    elasticity happens on the data/pod axes.
    """
    per_pod = available_devices if not multi_pod else available_devices // 2
    usable_dp = 0
    for dp in SUPPORTED_DP:
        if dp * model <= per_pod:
            usable_dp = dp
            break
    if usable_dp == 0:
        raise RuntimeError(
            f"{available_devices} devices cannot host model axis {model}"
        )
    if multi_pod:
        shape = (2, usable_dp, model)
        names = ("pod", "data", "model")
        used = 2 * usable_dp * model
    else:
        shape = (usable_dp, model)
        names = ("data", "model")
        used = usable_dp * model
    return ElasticPlan(shape, names, used, available_devices - used)


def build_mesh(plan: ElasticPlan) -> Mesh:
    devs = np.array(jax.devices()[: plan.n_devices]).reshape(plan.mesh_shape)
    return Mesh(devs, plan.axis_names)


@dataclasses.dataclass
class StragglerWatchdog:
    """EMA step-time monitor; flags sustained slowdowns."""

    alpha: float = 0.1
    threshold: float = 1.8
    patience: int = 5
    warmup: int = 10

    _ema: Optional[float] = None
    _strikes: int = 0
    _steps: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, step_time_s: float) -> bool:
        """Returns True if a straggler event fires at this step."""
        self._steps += 1
        if self._ema is None:
            self._ema = step_time_s
            return False
        fired = False
        if (self._steps > self.warmup
                and step_time_s > self.threshold * self._ema):
            self._strikes += 1
            if self._strikes >= self.patience:
                fired = True
                self.events.append({
                    "step": step, "step_time": step_time_s,
                    "ema": self._ema, "action": "checkpoint+evict+restart",
                })
                self._strikes = 0
        else:
            self._strikes = 0
            # only fold healthy steps into the EMA
            self._ema = (1 - self.alpha) * self._ema + self.alpha * step_time_s
        return fired
