import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-2.7b \
        --shape long_500k --multi-pod both
    PYTHONPATH=src python -m repro.launch.dryrun --out dryrun_results.json

The XLA_FLAGS line above MUST stay the first statement: jax locks the device
count at first init.  (Do not set this flag anywhere else — smoke tests and
benches see 1 device.)
"""

import argparse
import json
import re
import time
import traceback
from collections import Counter

import jax

from repro.configs.base import get_config
from repro.launch import cells as C
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.sharding import make_policy
from repro.serve.serve_loop import make_decode_step, make_prefill_step
from repro.train.optimizer import OptConfig
from repro.train.train_loop import make_train_step

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def collective_bytes(hlo_text: str):
    """Sum operand bytes of every collective op in an HLO module text.

    Builds a name -> bytes table from op definitions, then looks up the
    operands of each collective.  while-bodies appear once (see roofline.py
    for trip-count correction)."""
    dtype_bytes = {
        "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
        "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
        "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    }

    def shape_bytes(ty: str) -> int:
        # e.g. "bf16[16,4096]{1,0}" or tuple "(f32[2], f32[2])"
        total = 0
        for m in re.finditer(r"(\w+)\[([\d,]*)\]", ty):
            dt, dims = m.group(1), m.group(2)
            if dt not in dtype_bytes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * dtype_bytes[dt]
        return total

    defs = {}
    op_lines = []
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+(\S+)\(",
                     line)
        if not m:
            continue
        name, ty, opname = m.group(1), m.group(2), m.group(3)
        defs[name] = shape_bytes(ty)
        op_lines.append((name, ty, opname, line))

    total = 0
    counts = Counter()
    per_kind = Counter()
    for name, ty, opname, line in op_lines:
        kind = next((c for c in COLLECTIVES if opname.startswith(c)), None)
        if kind is None:
            continue
        # operand names inside the call parens
        call = line.split(opname + "(", 1)[1]
        operands = re.findall(r"%?([\w.\-]+)", call.split(")")[0])
        b = sum(defs.get(o, 0) for o in operands if o in defs)
        if b == 0:
            b = shape_bytes(ty)  # fallback: output size
        total += b
        counts[kind] += 1
        per_kind[kind] += b
    return total, dict(counts), dict(per_kind)


def lower_cell(arch: str, shape_name: str, mesh, *, smoke: bool = False):
    """Build the step function for a cell and lower it.  Returns lowered."""
    cfg = get_config(arch, smoke=smoke)
    shape = C.SHAPES[shape_name]
    train = shape.kind == "train"
    policy = make_policy(mesh, cfg, batch=shape.global_batch, train=train)

    if shape.kind == "train":
        opt = OptConfig(eightbit=cfg.opt_8bit)
        # microbatch=4: gradient-accumulation scan — bounds per-token temps
        # and amortizes the single per-step gradient reduction (docs/DESIGN.md §6)
        step, _ = make_train_step(cfg, policy, opt, donate=True, microbatch=4)
        specs = C.input_specs(arch, shape_name, opt=opt, smoke=smoke)
        with policy.mesh:
            return step.lower(*specs), policy
    if shape.kind == "prefill":
        if not cfg.causal:
            # encoder-only: "prefill" is a full forward (no cache)
            from repro.train.train_loop import batch_specs, _shard
            pspecs = M.param_specs(cfg, policy)
            bspecs = batch_specs(cfg, policy, train=False)
            fn = jax.jit(
                lambda p, b: M.forward_train(cfg, p, b)[0],
                in_shardings=(_shard(policy.mesh, pspecs),
                              _shard(policy.mesh, bspecs)),
            )
        else:
            fn = make_prefill_step(cfg, policy, shape.seq_len)
        specs = C.input_specs(arch, shape_name, smoke=smoke)
        with policy.mesh:
            return fn.lower(*specs), policy
    # decode
    fn = make_decode_step(cfg, policy)
    specs = C.input_specs(arch, shape_name, smoke=smoke)
    with policy.mesh:
        return fn.lower(*specs), policy


def run_cell(arch: str, shape_name: str, mesh, mesh_tag: str):
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag}
    try:
        lowered, policy = lower_cell(arch, shape_name, mesh)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ca = compiled.cost_analysis()
        ma = compiled.memory_analysis()
        cbytes, ccounts, ckinds = collective_bytes(compiled.as_text())
        alias = getattr(ma, "alias_size_in_bytes", 0)
        rec.update({
            "ok": True,
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
            "collective_bytes": cbytes,
            "collective_counts": ccounts,
            "collective_bytes_by_kind": ckinds,
            "arg_bytes_per_dev": ma.argument_size_in_bytes,
            "out_bytes_per_dev": ma.output_size_in_bytes,
            "tmp_bytes_per_dev": ma.temp_size_in_bytes,
            "alias_bytes_per_dev": alias,
            # donated inputs alias their outputs — don't double count
            "peak_bytes_per_dev": (
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - alias
            ),
            "tp": (policy.tp_a, policy.tp_b, policy.sp),
            "fsdp": policy.fsdp,
            "seq_shard": policy.seq_shard_data,
        })
    except Exception as e:  # a failure here is a bug in the system
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:]})
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.multi_pod in ("single", "both"):
        meshes.append(("1pod_16x16", make_production_mesh(multi_pod=False)))
    if args.multi_pod in ("multi", "both"):
        meshes.append(("2pod_2x16x16", make_production_mesh(multi_pod=True)))

    results = []
    for arch, sname, ok, why in C.all_cells():
        if args.arch and arch != args.arch:
            continue
        if args.shape and sname != args.shape:
            continue
        if not ok:
            for tag, _ in meshes:
                results.append({"arch": arch, "shape": sname, "mesh": tag,
                                "ok": True, "skipped": True, "reason": why})
            print(f"SKIP  {arch:18s} {sname:12s} ({why})")
            continue
        for tag, mesh in meshes:
            rec = run_cell(arch, sname, mesh, tag)
            results.append(rec)
            if rec["ok"]:
                print(
                    f"PASS  {arch:18s} {sname:12s} {tag:12s} "
                    f"compile={rec['compile_s']:6.1f}s "
                    f"flops/dev={rec['flops']:.3e} "
                    f"peak/dev={rec['peak_bytes_per_dev']/1e9:6.2f}GB "
                    f"coll={rec['collective_bytes']/1e9:8.3f}GB"
                )
            else:
                print(f"FAIL  {arch:18s} {sname:12s} {tag:12s} {rec['error']}")
                if args.verbose:
                    print(rec.get("trace", ""))
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    n_fail = sum(1 for r in results if not r.get("ok"))
    n_skip = sum(1 for r in results if r.get("skipped"))
    print(f"\n{len(results)} cells: {len(results)-n_fail-n_skip} passed, "
          f"{n_skip} skipped-by-design, {n_fail} FAILED -> {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
