"""Presto core: the paper's contribution — CKKS-targeting HHE stream ciphers
(HERA, Rubato, and the PASTA family beyond the paper's pair) as composable
JAX modules, with the decoupled-RNG producer/consumer split and the RtF
transciphering scaffold.
"""

from repro.core.params import (
    CipherParams,
    HERA_128A,
    RUBATO_128S,
    RUBATO_128M,
    RUBATO_128L,
    PASTA_128S,
    PASTA_128L,
    get_params,
)
from repro.core.cipher import Cipher, CipherBatch, StreamSession, make_cipher
from repro.core.engine import (
    EngineCaps,
    KeystreamEngine,
    engine_caps,
    make_engine,
    registered_engines,
    resolve_engine,
)
from repro.core.farm import (
    KeystreamFarm,
    WindowPlan,
    pack_windows,
    plan_windows,
)
from repro.core.producer import (
    ConstantsProducer,
    ProducerCaps,
    compatible_producers,
    make_producer,
    producer_caps,
    registered_producers,
    resolve_producer,
)
from repro.core.tuner import StreamPlan, autotune, load_plan
from repro.core.hera import hera_stream_key
from repro.core.pasta import pasta_stream_key
from repro.core.rubato import rubato_stream_key
from repro.core.schedule import (
    Schedule,
    build_schedule,
    execute_schedule,
)
from repro.core.transcipher import transcipher, evaluate_decryption_circuit

__all__ = [
    "CipherParams",
    "HERA_128A",
    "RUBATO_128S",
    "RUBATO_128M",
    "RUBATO_128L",
    "PASTA_128S",
    "PASTA_128L",
    "get_params",
    "Cipher",
    "CipherBatch",
    "StreamSession",
    "EngineCaps",
    "KeystreamEngine",
    "engine_caps",
    "make_engine",
    "registered_engines",
    "resolve_engine",
    "KeystreamFarm",
    "WindowPlan",
    "pack_windows",
    "plan_windows",
    "ConstantsProducer",
    "ProducerCaps",
    "compatible_producers",
    "make_producer",
    "producer_caps",
    "registered_producers",
    "resolve_producer",
    "StreamPlan",
    "autotune",
    "load_plan",
    "Schedule",
    "build_schedule",
    "execute_schedule",
    "make_cipher",
    "hera_stream_key",
    "pasta_stream_key",
    "rubato_stream_key",
    "transcipher",
    "evaluate_decryption_circuit",
]
