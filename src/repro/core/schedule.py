"""Round-schedule IR: the cipher as a declarative program (docs/DESIGN.md §9).

Presto's core move is treating HERA/Rubato not as code but as a *schedule*:
a linear sequence of vectorized modules (ARK, MRMC, nonlinearity, truncate,
AGN) whose round constants stream in from a decoupled RNG and whose
MixColumns/MixRows orientation may alternate between normal and transposed
state (Eq. 2 transposition-invariance) so the datapath never stalls on a
relayout.  This module is that schedule as data:

  * :class:`ARK` / :class:`MRMC` / :class:`NONLINEAR` / :class:`TRUNCATE` /
    :class:`AGN` — one op each, annotated with its round-constant slice and
    the state **orientation** it executes in (``normal`` | ``transposed``);
  * :func:`build_schedule` — emits the HERA and Rubato programs from ONE
    skeleton (both ciphers share ARK ∘ [MRMC ∘ NL ∘ ARK]^{r-1} ∘ MRMC ∘ NL ∘
    MRMC ∘ [Tr] ∘ ARK ∘ [AGN]), in a ``normal`` variant (every op row-major)
    and an ``alternating`` variant that flips MRMC orientation per round —
    the TPU analogue of the paper's bubble elimination: because MRMC
    commutes with transposition (Eq. 2), an orientation flip costs nothing
    in the unrolled kernel (it is a static relabeling of which sublanes get
    combined), and downstream ARK/Feistel consume the state in whatever
    orientation it was left in.  PASTA (the third CKKS-targeting HHE
    cipher) is a second program family off the same op set: the key IS the
    initial state (``Schedule.init == "key"``), its per-block-random affine
    layer is the `MRMC` op generalized with an **additive** per-branch
    round-constant slice and a cross-branch mix, the state is two branches
    (``Schedule.branches == 2``), intermediate rounds use the Feistel
    nonlinearity and the final round the cube, then truncation to one
    branch — proving the IR generalizes beyond the paper's cipher pair;
  * :func:`execute_schedule` — the pure-JAX interpreter.  `core/hera.py`,
    `core/rubato.py`, `core/pasta.py`, and `kernels/keystream/ref.py` are
    thin wrappers over it; `kernels/keystream/keystream.py` interprets the
    same program as a fused Pallas kernel; `core/transcipher.py` interprets
    it with FV-style multiplicative-depth tracking.

Round-constant accounting (``n_arks``, ``n_round_constants``) is derived
from the program — `core/params.py` delegates to it — so the paper's
FIFO-depth numbers (96 for HERA Par-128a, 188 = 64+64+60 for Rubato
Par-128L, (r+1)·2t for PASTA's affine layers) are a property of the
schedule, not a duplicated formula.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import rounds as R
from repro.core.rounds import ic_vector

if TYPE_CHECKING:  # params imports us lazily (accounting properties)
    from repro.core.params import CipherParams

NORMAL = "normal"
TRANSPOSED = "transposed"
ORIENTATIONS = (NORMAL, TRANSPOSED)

#: Schedule variants build_schedule understands.
VARIANTS = ("normal", "alternating")


def _flip(orientation: str) -> str:
    return TRANSPOSED if orientation == NORMAL else NORMAL


def transpose_perm(v: int) -> np.ndarray:
    """The state-transposition permutation on flat row-major indices.

    ``perm[c*v + r] = r*v + c`` — the stored element at flat position i of a
    transposed state is the logical element ``perm[i]``.  An involution, so
    the same array maps stored->logical and logical->stored.
    """
    return np.arange(v * v).reshape(v, v).T.reshape(-1)


def state_transpose_perm(v: int, branches: int = 1) -> np.ndarray:
    """Transposition permutation for the FULL flat state.

    Each branch's (v, v) view transposes independently — branches never
    interleave — so the permutation is :func:`transpose_perm` blocked per
    branch.  With one branch this is plain ``transpose_perm(v)``.  Still an
    involution.
    """
    tp = transpose_perm(v)
    t = v * v
    return np.concatenate([tp + b * t for b in range(branches)])


def dense_mat_perm(v: int, in_orientation: str,
                   out_orientation: str) -> np.ndarray:
    """Storage-order re-index of one branch's flattened t×t stream matrix.

    A stream-sourced affine layer applies a *logical* dense matrix
    y[i] = Σ_j M[i, j]·x[j] per branch.  When the chain stores the input
    state permuted by p_in and must deliver the output permuted by p_out
    (the transpose permutation per orientation), the stored-state compute
    is y_s[i] = Σ_j M[p_out[i], p_in[j]]·x_s[j] — i.e. the matrix itself
    is re-indexed, rows by p_out and columns by p_in, and the datapath
    never gathers.  Returns p with ``mat_storage = mat_logical[p]`` over
    the branch's flat row-major t² words (identity when both normal).
    """
    t = v * v
    ident = np.arange(t)
    p_in = transpose_perm(v) if in_orientation == TRANSPOSED else ident
    p_out = transpose_perm(v) if out_orientation == TRANSPOSED else ident
    return (p_out[:, None] * t + p_in[None, :]).reshape(-1)


# ==========================================================================
# Ops
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class Op:
    """Base: every op carries the orientation its input state is stored in."""

    orientation: str = NORMAL


@dataclasses.dataclass(frozen=True)
class ARK(Op):
    """Add-round-key x + k ⊙ rc, with the randomized key schedule.

    ``rc_slice`` is the [start, stop) window of the flat logical
    round-constant stream this op consumes — the paper's RNG-FIFO
    accounting: the producer must have delivered exactly ``stop`` constants
    before this op fires.  ``key_len`` is n except for Rubato's final
    truncated ARK (l: the trailing n−l constants are dead).
    """

    rc_slice: Tuple[int, int] = (0, 0)
    key_len: int = 0


@dataclasses.dataclass(frozen=True)
class MRMC(Op):
    """Fused MixRows∘MixColumns M_v·X·M_vᵀ, applied per branch.

    ``out_orientation`` may differ from ``orientation``: by Eq. 2
    (MRMC(Xᵀ) = MRMC(X)ᵀ) the stored-state computation is *identical* in
    both orientations, and a flip is a free relabeling of the output
    stacking — this is what lets the alternating variant hand each round
    the state in the orientation the previous round left it.

    The PASTA generalization: ``rc_slice`` (non-empty) turns the op into
    the cipher's affine layer — the matrix output gets per-branch round
    constants **added** (consumed in ``out_orientation``, unlike ARK's
    key-multiplied constants consumed in ``orientation``), and
    ``mix_branches`` then applies the (2·y_L + y_R, y_L + 2·y_R) branch
    coupling.  HERA/Rubato programs leave both at their defaults.

    ``matrix_source`` selects where the matrix comes from: ``"static"``
    (the fixed circulant M_v — HERA/Rubato, and the pre-stream PASTA
    stand-in) or ``"stream"`` — the published PASTA affine layer, a fresh
    per-(nonce, counter) dense t×t matrix per branch drawn from the same
    decoupled XOF stream as the constants.  ``mat_slice`` is then the
    [start, stop) window of the flat logical matrix-plane word stream this
    op consumes (branches·t² words: branch 0's t×t row-major, then branch
    1's), the matrix-plane analogue of the rc FIFO accounting.
    """

    out_orientation: str = NORMAL
    rc_slice: Tuple[int, int] = (0, 0)
    mix_branches: bool = False
    matrix_source: str = "static"
    mat_slice: Tuple[int, int] = (0, 0)

    @property
    def has_rc(self) -> bool:
        return self.rc_slice[1] > self.rc_slice[0]

    @property
    def streams_matrix(self) -> bool:
        return self.matrix_source == "stream"


@dataclasses.dataclass(frozen=True)
class NONLINEAR(Op):
    """Elementwise cipher nonlinearity: ``cube`` (HERA, PASTA's final
    round) or ``feistel`` (Rubato, PASTA's intermediate rounds) — applied
    per branch (PASTA's Feistel chain restarts at the branch boundary).

    Cube is orientation-agnostic; Feistel couples flat-index neighbors, so
    in transposed orientation the neighbor pattern becomes a static
    row/column shift of the (v, v) view (no data transpose).
    """

    kind: str = "cube"


@dataclasses.dataclass(frozen=True)
class TRUNCATE(Op):
    """Tr_{n,l}: keep the first ``keep`` logical elements (normal-only)."""

    keep: int = 0


@dataclasses.dataclass(frozen=True)
class AGN(Op):
    """Add the cipher's own discrete-Gaussian noise (Rubato; client-side).

    Executors apply it only when noise is supplied — the op records that
    the *program* ends with an AGN stage, not that every run draws noise.
    """


@dataclasses.dataclass(frozen=True)
class OpInfo:
    """Static per-op facts from one walk of the program.

    The shared substrate for the `repro.analysis` passes: each entry
    records the state the *chain* is actually in when the op fires
    (``chain_orientation`` — propagated through MRMC flips, which is what
    the op's own ``orientation`` annotation must match) plus the state
    width flowing in and out (TRUNCATE shrinks it).  ``provenance`` is the
    human-readable site string analyzers attach to findings.
    """

    index: int
    op: Op
    in_width: int
    out_width: int
    chain_orientation: str   # orientation the chain delivers to this op
    out_orientation: str     # orientation the chain is in after this op
    provenance: str          # "hera-128a/alternating ops[3] NONLINEAR(cube)"


def _op_label(op: Op) -> str:
    if isinstance(op, NONLINEAR):
        return f"NONLINEAR({op.kind})"
    if isinstance(op, MRMC) and op.has_rc:
        return "MRMC(affine)"
    return type(op).__name__


# ==========================================================================
# Schedule
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class Schedule:
    """One cipher program: ops plus the static facts executors need."""

    name: str          # e.g. "hera-128a/alternating"
    kind: str          # "hera" | "rubato" | "pasta"
    variant: str       # "normal" | "alternating"
    n: int
    l: int
    v: int
    ops: Tuple[Op, ...]
    branches: int = 1  # PASTA: 2 independent (v, v) branch matrices
    init: str = "ic"   # initial state: "ic" (public constant) | "key"
    #: `repro.analysis.lint` rule codes suppressed for this program (the
    #: `# noqa`-style escape hatch; docs/DESIGN.md §13 on when it is OK)
    suppress: Tuple[str, ...] = ()

    # ---- derived accounting (the single source of truth) -----------------
    @property
    def n_arks(self) -> int:
        return sum(isinstance(op, ARK) for op in self.ops)

    @property
    def n_round_constants(self) -> int:
        return max(op.rc_slice[1] for op in self.ops
                   if isinstance(op, (ARK, MRMC)) and op.rc_slice[1])

    @property
    def n_matrix_constants(self) -> int:
        """Total matrix-plane words per stream key — the matrix FIFO depth.

        0 for static-matrix programs (HERA/Rubato); PASTA's stream-sourced
        affine layers draw (r+1)·branches·t² words ((r+1)·n·t).
        """
        return max((op.mat_slice[1] for op in self.ops
                    if isinstance(op, MRMC) and op.streams_matrix),
                   default=0)

    @property
    def n_mrmc(self) -> int:
        return sum(isinstance(op, MRMC) for op in self.ops)

    @property
    def has_transposed_ops(self) -> bool:
        return any(op.orientation == TRANSPOSED for op in self.ops)

    # ---- layout helpers --------------------------------------------------
    def rc_storage_perm(self) -> Optional[np.ndarray]:
        """Logical→storage constant reorder for lane-major kernels.

        Returns a permutation p with ``rc_storage = rc_logical[p]`` such
        that every constant-consuming op reads a *contiguous* slice already
        matching its orientation — the RNG FIFO delivers constants in
        exactly the order the datapath consumes them, so a transposed-
        orientation ARK (or PASTA affine layer) costs no in-kernel gather.
        ARK constants are consumed in the op's input orientation; an
        affine MRMC adds its constants AFTER the matrix, i.e. in
        ``out_orientation``.  None when no reorder is needed.
        """
        perm = np.arange(self.n_round_constants)
        tp = state_transpose_perm(self.v, self.branches)
        changed = False
        for op in self.ops:
            if isinstance(op, ARK) and op.orientation == TRANSPOSED:
                a, b = op.rc_slice
                perm[a:b] = a + tp[: b - a]
                changed = True
            elif (isinstance(op, MRMC) and op.has_rc
                  and op.out_orientation == TRANSPOSED):
                a, b = op.rc_slice
                perm[a:b] = a + tp[: b - a]
                changed = True
        return perm if changed else None

    def mat_storage_perm(self) -> Optional[np.ndarray]:
        """Logical→storage matrix-plane reorder — `rc_storage_perm`'s
        matrix analogue, extending the storage-order constant FIFO to the
        dense planes.

        Each stream-sourced op's branch-local t² block is re-indexed by
        :func:`dense_mat_perm` (rows by the op's output orientation,
        columns by its input orientation) so the lane-major kernel's
        dense matvec consumes matrix words in exactly the stored-state
        order — no in-kernel gather, and never across a branch boundary.
        None when no reorder is needed (normal-variant programs, and any
        program with no stream matrices).
        """
        n_mat = self.n_matrix_constants
        if not n_mat:
            return None
        perm = np.arange(n_mat)
        t = self.v * self.v
        changed = False
        for op in self.ops:
            if not (isinstance(op, MRMC) and op.streams_matrix):
                continue
            if op.orientation == NORMAL and op.out_orientation == NORMAL:
                continue
            block = dense_mat_perm(self.v, op.orientation,
                                   op.out_orientation)
            a, _ = op.mat_slice
            for br in range(self.branches):
                base = a + br * t * t
                perm[base:base + t * t] = base + block
            changed = True
        return perm if changed else None

    # ---- analysis substrate ---------------------------------------------
    def op_table(self) -> Tuple[OpInfo, ...]:
        """One walk of the program -> per-op static facts (:class:`OpInfo`).

        Never raises on malformed programs — the linter
        (`repro.analysis.lint`) diagnoses those, and it needs the walk to
        keep going past the first inconsistency: the chain orientation is
        propagated through MRMC ``out_orientation`` regardless of whether
        the op's own annotation matched, and TRUNCATE narrows the width
        even when ``keep`` is nonsensical (clamped at >= 0).
        """
        rows = []
        cur = NORMAL
        width = self.n
        for i, op in enumerate(self.ops):
            out_w = width
            out_o = cur
            if isinstance(op, MRMC):
                out_o = op.out_orientation
            elif isinstance(op, TRUNCATE):
                out_w = max(0, min(width, op.keep))
            rows.append(OpInfo(
                index=i, op=op, in_width=width, out_width=out_w,
                chain_orientation=cur, out_orientation=out_o,
                provenance=f"{self.name} ops[{i}] {_op_label(op)}",
            ))
            cur, width = out_o, out_w
        return tuple(rows)

    # ---- validation ------------------------------------------------------
    def validate(self) -> "Schedule":
        """Check orientation continuity and round-constant coverage."""
        cur = NORMAL
        next_rc = 0
        next_mat = 0
        width = self.n
        for i, op in enumerate(self.ops):
            if op.orientation != cur:
                raise ValueError(
                    f"{self.name}: op {i} ({type(op).__name__}) expects "
                    f"{op.orientation} state but the schedule is {cur} here"
                )
            if isinstance(op, ARK):
                a, b = op.rc_slice
                if a != next_rc or b - a != op.key_len or op.key_len != width:
                    raise ValueError(
                        f"{self.name}: ARK {i} rc_slice {op.rc_slice} / "
                        f"key_len {op.key_len} inconsistent (state width "
                        f"{width}, next constant {next_rc})"
                    )
                next_rc = b
            elif isinstance(op, MRMC):
                if op.has_rc:
                    a, b = op.rc_slice
                    if a != next_rc or b - a != width:
                        raise ValueError(
                            f"{self.name}: affine MRMC {i} rc_slice "
                            f"{op.rc_slice} inconsistent (state width "
                            f"{width}, next constant {next_rc})"
                        )
                    next_rc = b
                if op.mix_branches and self.branches != 2:
                    raise ValueError(
                        f"{self.name}: MRMC {i} mixes branches but the "
                        f"schedule has {self.branches}"
                    )
                if op.matrix_source not in ("static", "stream"):
                    raise ValueError(
                        f"{self.name}: MRMC {i} unknown matrix_source "
                        f"{op.matrix_source!r}"
                    )
                if op.streams_matrix:
                    a, b = op.mat_slice
                    want = width * (width // self.branches)  # branches·t²
                    if a != next_mat or b - a != want:
                        raise ValueError(
                            f"{self.name}: stream MRMC {i} mat_slice "
                            f"{op.mat_slice} inconsistent (need {want} "
                            f"words, next matrix word {next_mat})"
                        )
                    next_mat = b
                elif op.mat_slice != (0, 0):
                    raise ValueError(
                        f"{self.name}: static MRMC {i} carries mat_slice "
                        f"{op.mat_slice}"
                    )
                cur = op.out_orientation
            elif isinstance(op, TRUNCATE):
                if cur != NORMAL:
                    raise ValueError(
                        f"{self.name}: TRUNCATE needs normal orientation"
                    )
                width = op.keep
            elif isinstance(op, AGN) and cur != NORMAL:
                raise ValueError(f"{self.name}: AGN needs normal orientation")
        if cur != NORMAL:
            raise ValueError(f"{self.name}: program must end normal")
        if next_rc != self.n_round_constants:
            raise ValueError(f"{self.name}: round constants not contiguous")
        if next_mat != self.n_matrix_constants:
            raise ValueError(f"{self.name}: matrix planes not contiguous")
        if self.init not in ("ic", "key"):
            raise ValueError(f"{self.name}: unknown init {self.init!r}")
        return self

    def describe(self) -> str:
        """Human-readable program listing (docs/DESIGN.md §9/§11 format)."""
        head = (f"schedule {self.name}  (n={self.n}, l={self.l}, "
                f"{self.n_arks} ARKs, {self.n_round_constants} constants")
        if self.n_matrix_constants:
            head += f", {self.n_matrix_constants} matrix words"
        if self.branches > 1:
            head += f", {self.branches} branches, init={self.init}"
        rows = [head + ")"]
        for i, op in enumerate(self.ops):
            o = "T" if op.orientation == TRANSPOSED else "N"
            if isinstance(op, ARK):
                a, b = op.rc_slice
                rows.append(f"  {i:2d}  ARK[{o}]      rc[{a}:{b}]  "
                            f"key[:{op.key_len}]")
            elif isinstance(op, MRMC):
                oo = "T" if op.out_orientation == TRANSPOSED else "N"
                extra = ""
                if op.streams_matrix:
                    extra += f"  mat[{op.mat_slice[0]}:{op.mat_slice[1]}]"
                if op.has_rc:
                    extra += f"  +rc[{op.rc_slice[0]}:{op.rc_slice[1]}]"
                if op.mix_branches:
                    extra += "  mix"
                rows.append(f"  {i:2d}  MRMC[{o}->{oo}]{extra}")
            elif isinstance(op, NONLINEAR):
                rows.append(f"  {i:2d}  {op.kind.upper()}[{o}]")
            elif isinstance(op, TRUNCATE):
                rows.append(f"  {i:2d}  TRUNCATE[{o}] keep {op.keep}")
            elif isinstance(op, AGN):
                rows.append(f"  {i:2d}  AGN[{o}]")
        return "\n".join(rows)


# ==========================================================================
# Builder
# ==========================================================================
@functools.lru_cache(maxsize=None)
def build_schedule(params: "CipherParams", variant: str = "normal") -> Schedule:
    """Emit the cipher program for ``params`` — the ONE place the HERA,
    Rubato, and PASTA round structures are written down.

    HERA and Rubato share the skeleton (paper §III):

        ARK ∘ [MRMC ∘ NL ∘ ARK]^{r-1} ∘ MRMC ∘ NL ∘ MRMC ∘ [Tr] ∘ ARK ∘ [AGN]

    differing only in the nonlinearity (Cube vs Feistel), truncation
    (Rubato: l < n makes the final ARK's trailing constants dead) and AGN.

    PASTA applies its two-branch permutation to the KEY (init="key") with
    per-block randomness entering through additive affine constants:

        Tr_t ∘ A_r ∘ Cube ∘ [A_i ∘ Feistel]... reading right-to-left:
        [A_i ∘ S_i]^r ∘ A_r where A = per-branch MRMC + rc + branch mix,
        S_i = Feistel for i < r-1 and Cube for the final round,

    i.e. r+1 affine layers consuming (r+1)·n constants — the same MRMC
    count as the shared skeleton, so the alternating variant's flip plan
    carries over unchanged (docs/DESIGN.md §11 documents the stand-ins).

    ``variant="alternating"`` flips MRMC orientation per application; when
    the MRMC count is odd the last one stays put so TRUNCATE/output see
    normal orientation.  Cached per (params, variant) — CipherParams is
    frozen/hashable — so accounting properties can call this freely.
    """
    if variant not in VARIANTS:
        raise ValueError(f"unknown schedule variant {variant!r}; "
                         f"have {VARIANTS}")
    n, l, r, v = params.n, params.l, params.rounds, params.v
    n_mrmc = r + 1
    # flip at every MRMC; with an odd count the last one keeps orientation
    # so truncation and the output stage always see normal state
    flips = (n_mrmc - (n_mrmc % 2)) if variant == "alternating" else 0

    ops = []
    cur = NORMAL
    mrmc_seen = 0

    def mrmc(**kw):
        nonlocal cur, mrmc_seen
        out = _flip(cur) if mrmc_seen < flips else cur
        ops.append(MRMC(orientation=cur, out_orientation=out, **kw))
        cur = out
        mrmc_seen += 1

    if params.kind == "pasta":
        # [A_i ∘ S_i]^r ∘ A_r on the key state; constants consumed by the
        # affine layers in out-orientation, mix coupling the two branches.
        # Each affine layer applies a fresh per-block dense t×t matrix per
        # branch, streamed from the producer (n·t matrix words per layer).
        t = n // params.branches
        for j in range(r):
            mrmc(rc_slice=(j * n, (j + 1) * n), mix_branches=True,
                 matrix_source="stream",
                 mat_slice=(j * n * t, (j + 1) * n * t))
            ops.append(NONLINEAR(
                orientation=cur, kind="feistel" if j < r - 1 else "cube"))
        mrmc(rc_slice=(r * n, (r + 1) * n), mix_branches=True,
             matrix_source="stream",
             mat_slice=(r * n * t, (r + 1) * n * t))
        ops.append(TRUNCATE(orientation=cur, keep=l))
        return Schedule(
            name=f"{params.name}/{variant}", kind=params.kind,
            variant=variant, n=n, l=l, v=v, ops=tuple(ops),
            branches=params.branches, init="key",
        ).validate()

    nl = "cube" if params.kind == "hera" else "feistel"
    ops.append(ARK(orientation=cur, rc_slice=(0, n), key_len=n))
    for j in range(1, r):                          # RF_1 .. RF_{r-1}
        mrmc()
        ops.append(NONLINEAR(orientation=cur, kind=nl))
        ops.append(ARK(orientation=cur, rc_slice=(j * n, (j + 1) * n),
                       key_len=n))
    # Fin
    mrmc()
    ops.append(NONLINEAR(orientation=cur, kind=nl))
    mrmc()
    if l < n:
        ops.append(TRUNCATE(orientation=cur, keep=l))
    ops.append(ARK(orientation=cur, rc_slice=(r * n, r * n + l), key_len=l))
    if params.kind == "rubato" and params.sigma > 0:
        ops.append(AGN(orientation=cur))

    return Schedule(
        name=f"{params.name}/{variant}", kind=params.kind, variant=variant,
        n=n, l=l, v=v, ops=tuple(ops),
    ).validate()


# ==========================================================================
# Pure-JAX interpreter (the reference executor)
# ==========================================================================
def _mrmc_flat(params: "CipherParams", x, flip_out: bool,
               in_bound: int | None = None, lazy: bool = False):
    """M_v·X·M_vᵀ per branch on flat (..., n) state; flip_out transposes
    the output (free by Eq. 2 — the stored-state compute is orientation-
    independent, which is also why the no-flip transposed case is plain
    R.mrmc).  in_bound/lazy thread the reduction plan's lazy-accumulate
    policy into the shift-add passes."""
    out = R.mrmc(params, x, in_bound=in_bound, lazy=lazy)
    if flip_out:
        v, b = params.v, params.branches
        O = out.reshape(out.shape[:-1] + (b, v, v))
        out = jnp.swapaxes(O, -1, -2).reshape(out.shape)
    return out


def _feistel_transposed(params: "CipherParams", x):
    """Feistel on transposed-stored state, as static shifts of each
    branch's (v, v) view: stored (c, r) holds logical r·v + c, so the
    logical predecessor sits one row up — wrapping to (v-1, r-1) at the
    row boundary.  The branch axis rides in front untouched (PASTA's
    chain restarts per branch)."""
    mod, v, b = params.mod, params.v, params.branches
    S = x.reshape(x.shape[:-1] + (b, v, v))       # axes (..., b, c, r)
    sq = mod.square(S)
    row0 = jnp.concatenate(
        [jnp.zeros_like(sq[..., :1, :1]), sq[..., v - 1:, : v - 1]], axis=-1
    )
    shifted = jnp.concatenate([row0, sq[..., : v - 1, :]], axis=-2)
    return mod.add(S, shifted).reshape(x.shape)


def execute_schedule(params: "CipherParams", schedule: Schedule, key, rc,
                     noise_signed=None, ic=None, mats=None,
                     reduction: str = "lazy", plan=None):
    """Interpret ``schedule`` in pure JAX — the oracle all backends match.

    ``reduction`` selects the reduction-scheduling mode ("lazy" — the
    default, provably bit-exact — or "eager", the legacy
    reduce-everything graphs); ``plan`` overrides it with an explicit
    `core.redplan.ReductionPlan` (validated against the terminal-
    reduction law before any op executes).  Either way the output is the
    same canonical keystream — the plan only moves *where* the
    conditional-subtract chains fire.

    key: (..., n) u32 in Z_q; rc: (..., n_round_constants) u32 in *logical*
    (producer) order; noise_signed: (..., l) i32 or None; mats:
    (..., n_matrix_constants) u32 matrix-plane words in logical order
    (required iff the program streams matrices); returns (..., l) u32
    keystream.  Orientation handling: transposed ARKs index key/rc
    through the transpose permutation (a static gather on small vectors),
    and an affine MRMC landing transposed indexes its additive constants
    the same way; MRMC flips are output relabelings; the state itself is
    never transposed except at explicit MRMC orientation changes.  A
    stream-sourced MRMC re-indexes its dense matrix per orientation pair
    (:func:`dense_mat_perm`) so the stored-state matvec is direct.
    ``schedule.init`` selects the initial state: the public ic constant
    (HERA/Rubato) or the key itself (PASTA's keyed permutation).
    """
    if rc.shape[-1] != schedule.n_round_constants:
        raise ValueError(
            f"rc last dim {rc.shape[-1]} != {schedule.n_round_constants} "
            f"(schedule {schedule.name})"
        )
    n_mat = schedule.n_matrix_constants
    if n_mat and (mats is None or mats.shape[-1] != n_mat):
        got = "None" if mats is None else mats.shape[-1]
        raise ValueError(
            f"mats last dim {got} != {n_mat} (schedule {schedule.name} "
            "streams its affine matrices)"
        )
    from repro.core import redplan as RP

    if plan is None:
        plan = RP.plan_reductions(params, schedule, reduction)
    plan.validate(schedule)

    if schedule.init == "key":
        x = jnp.broadcast_to(key, rc.shape[:-1] + (params.n,))
    else:
        if ic is None:
            ic = jnp.asarray(ic_vector(params))
        x = jnp.broadcast_to(ic, rc.shape[:-1] + (params.n,))
    tp = state_transpose_perm(schedule.v, schedule.branches)

    for i, op in enumerate(schedule.ops):
        p_i = plan.ops[i]
        if isinstance(op, ARK):
            a, b = op.rc_slice
            rcs = rc[..., a:b]
            k = key[..., : op.key_len]
            if op.orientation == TRANSPOSED:
                rcs, k = rcs[..., tp], key[..., tp]
            x = R.ark(params, x, k, rcs,
                      reduce_out=not p_i.has(RP.DEFER_OUT))
        elif isinstance(op, MRMC):
            if op.streams_matrix:
                a, b = op.mat_slice
                m = mats[..., a:b]
                perm = dense_mat_perm(schedule.v, op.orientation,
                                      op.out_orientation)
                if not np.array_equal(perm, np.arange(len(perm))):
                    nb, tt = schedule.branches, len(perm)
                    idx = np.concatenate([perm + br * tt
                                          for br in range(nb)])
                    m = m[..., idx]
                t = schedule.v * schedule.v
                M = m.reshape(m.shape[:-1] + (schedule.branches, t, t))
                X = x.reshape(x.shape[:-1] + (schedule.branches, t))
                if p_i.has(RP.LAZY_DENSE):
                    y = params.mod.matvec_dense(M, X, x_bound=p_i.in_bound,
                                                lazy=True)
                else:
                    y = params.mod.matvec_dense(M, X)
                x = y.reshape(x.shape)
            else:
                x = _mrmc_flat(params, x,
                               op.orientation != op.out_orientation,
                               in_bound=p_i.in_bound,
                               lazy=p_i.has(RP.LAZY_ACCUMULATE))
            fold = p_i.has(RP.FOLD_MIX)
            if op.has_rc:
                a, b = op.rc_slice
                rcs = rc[..., a:b]
                if op.out_orientation == TRANSPOSED:
                    rcs = rcs[..., tp]
                # fold-mix: the raw sum (< 2q) defers into the mix reduce
                x = x + rcs if fold else params.mod.add(x, rcs)
            if op.mix_branches:
                mix_in = params.mod.q * (2 if op.has_rc else 1)
                x = R.branch_mix(params, x, in_bound=mix_in, lazy=fold)
        elif isinstance(op, NONLINEAR):
            if op.kind == "cube":
                x = R.cube(params, x)            # orientation-agnostic
            elif op.orientation == TRANSPOSED:
                x = _feistel_transposed(params, x)
            else:
                x = R.feistel(params, x)
        elif isinstance(op, TRUNCATE):
            x = x[..., : op.keep]
        elif isinstance(op, AGN):
            if noise_signed is not None and params.sigma > 0:
                x = R.agn(params, x, noise_signed)
    return x
