"""Cipher parameter sets for HERA and Rubato.

Paper-benchmarked sets: HERA Par-128a (n=16, r=5, ~28-bit q, 96 round
constants) and Rubato Par-128L (n=64, r=2, ~25-bit q, 188 = 64+64+60 round
constants, truncation to l=60, AGN noise).  Moduli are Solinas primes of the
matching bit width (the paper does not list exact production moduli); the
mixing matrix for v != 4 is our documented circulant stand-in (docs/DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.crypto.modmath import Modulus, Q_HERA, Q_RUBATO


@dataclasses.dataclass(frozen=True)
class CipherParams:
    name: str
    kind: str          # "hera" | "rubato"
    n: int             # state size (must be a perfect square)
    l: int             # keystream length after truncation (hera: l == n)
    rounds: int        # r
    mod: Modulus
    sigma: float = 0.0  # AGN sigma (rubato only; 0 disables)
    xof: str = "aes"   # "aes" | "threefry"

    def __post_init__(self):
        v = math.isqrt(self.n)
        if v * v != self.n:
            raise ValueError(f"state size n={self.n} must be a perfect square")
        if not (0 < self.l <= self.n):
            raise ValueError("invalid truncation length")
        if self.kind not in ("hera", "rubato"):
            raise ValueError(f"unknown cipher kind {self.kind!r}")
        if self.kind == "hera" and self.l != self.n:
            raise ValueError("HERA does not truncate")
        # matvec accumulation bound (docs/DESIGN.md §2): v partial sums of < q
        if self.v * 3 * self.mod.q >= 2**33:
            raise ValueError("v*q too large for shift-add accumulation")

    @property
    def v(self) -> int:
        return math.isqrt(self.n)

    def schedule(self, variant: str = "normal"):
        """The declarative round program for this parameter set (cached).

        See `core/schedule.py` — the ONE place the round structure lives;
        executors (pure JAX, Pallas kernel, depth-tracked circuit) all
        interpret it, and the accounting properties below derive from it.
        """
        from repro.core.schedule import build_schedule

        return build_schedule(self, variant)

    @property
    def n_arks(self) -> int:
        """ARK executions per stream key: initial + (r-1) RFs + final —
        counted off the schedule program, not a duplicated formula."""
        return self.schedule().n_arks

    @property
    def n_round_constants(self) -> int:
        """Total uniform round constants per stream key, derived from the
        schedule's rc-slice annotations (the RNG FIFO depth).

        HERA: (r+1)*n (96 for Par-128a).  Rubato: r*n + l because the final
        ARK feeds a truncation, so only l of its constants matter (188 for
        Par-128L = 64+64+60), matching the paper's FIFO-depth accounting.
        """
        return self.schedule().n_round_constants

    @property
    def n_noise(self) -> int:
        return self.l if (self.kind == "rubato" and self.sigma > 0) else 0

    def mix_matrix(self) -> np.ndarray:
        """M_v: circulant with first row [2, 3, 1, ..., 1] (paper's M_4).

        For v=4 this is exactly the paper's matrix; v in {6, 8} uses the same
        circulant family (small coefficients {1,2,3} => shift-add datapath).
        """
        first = [2, 3] + [1] * (self.v - 2)
        rows = [np.roll(first, i) for i in range(self.v)]
        return np.array(rows, dtype=np.int64)

    def xof_words_per_block(self) -> int:
        """uint32 XOF words one stream-key block consumes (constants+noise).

        Uses the stream (compact) rejection sampler: ~1 word per constant +
        a fixed safety pad — this reproduces the paper's accounting of ~37
        AES invocations (~4700 bits) for Rubato Par-128L.
        """
        from repro.crypto.sampler import words_needed_uniform_stream

        return words_needed_uniform_stream(self.n_round_constants) + 2 * self.n_noise


HERA_128A = CipherParams(
    name="hera-128a", kind="hera", n=16, l=16, rounds=5, mod=Q_HERA
)

# Rubato family: bigger state <-> fewer rounds (Rubato paper's S/M/L split).
RUBATO_128S = CipherParams(
    name="rubato-128s", kind="rubato", n=16, l=12, rounds=5, mod=Q_RUBATO,
    sigma=1.6,
)
RUBATO_128M = CipherParams(
    name="rubato-128m", kind="rubato", n=36, l=32, rounds=3, mod=Q_RUBATO,
    sigma=1.6,
)
RUBATO_128L = CipherParams(
    name="rubato-128l", kind="rubato", n=64, l=60, rounds=2, mod=Q_RUBATO,
    sigma=1.6,
)

REGISTRY = {
    p.name: p for p in (HERA_128A, RUBATO_128S, RUBATO_128M, RUBATO_128L)
}


def get_params(name: str) -> CipherParams:
    if name not in REGISTRY:
        raise KeyError(f"unknown cipher {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]
