"""Cipher parameter sets for HERA, Rubato, and PASTA.

Paper-benchmarked sets: HERA Par-128a (n=16, r=5, ~28-bit q, 96 round
constants) and Rubato Par-128L (n=64, r=2, ~25-bit q, 188 = 64+64+60 round
constants, truncation to l=60, AGN noise).  The PASTA family (Dobraunig et
al., the canonical third CKKS-targeting HHE stream cipher) rides the same
schedule IR: a two-branch state of 2t elements initialized from the key,
per-branch affine layers with additive per-block constants, branch mixing,
Feistel intermediate rounds and a cube final round, truncation to t — see
docs/DESIGN.md §11 for the stand-ins.  Moduli are Solinas primes of the
matching bit width (the papers do not list exact production moduli); the
mixing matrix for v != 4 is our documented circulant stand-in (docs/DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.crypto.modmath import Modulus, Q_HERA, Q_PASTA, Q_RUBATO


@dataclasses.dataclass(frozen=True)
class CipherParams:
    name: str
    kind: str          # "hera" | "rubato" | "pasta"
    n: int             # state size (branches * a perfect square)
    l: int             # keystream length after truncation (hera: l == n)
    rounds: int        # r
    mod: Modulus
    sigma: float = 0.0  # AGN sigma (rubato only; 0 disables)
    xof: str = "aes"   # "aes" | "threefry"

    def __post_init__(self):
        if self.kind not in ("hera", "rubato", "pasta"):
            raise ValueError(f"unknown cipher kind {self.kind!r}")
        t = self.n // self.branches
        v = math.isqrt(t)
        if t * self.branches != self.n or v * v != t:
            raise ValueError(
                f"state size n={self.n} must be {self.branches} branch(es) "
                "of a perfect square"
            )
        if not (0 < self.l <= self.n):
            raise ValueError("invalid truncation length")
        if self.kind == "hera" and self.l != self.n:
            raise ValueError("HERA does not truncate")
        if self.kind == "pasta":
            if self.l != t:
                raise ValueError("PASTA truncates to one branch (l == n/2)")
            if self.sigma != 0.0:
                raise ValueError("PASTA has no AGN stage")
        # matvec accumulation bound (docs/DESIGN.md §2): v partial sums of < q
        if self.v * 3 * self.mod.q >= 2**33:
            raise ValueError("v*q too large for shift-add accumulation")

    @property
    def branches(self) -> int:
        """State branches: PASTA's two-word state; 1 for HERA/Rubato."""
        return 2 if self.kind == "pasta" else 1

    @property
    def v(self) -> int:
        """Per-branch matrix dimension: each branch is a (v, v) state."""
        return math.isqrt(self.n // self.branches)

    def schedule(self, variant: str = "normal"):
        """The declarative round program for this parameter set (cached).

        See `core/schedule.py` — the ONE place the round structure lives;
        executors (pure JAX, Pallas kernel, depth-tracked circuit) all
        interpret it, and the accounting properties below derive from it.
        """
        from repro.core.schedule import build_schedule

        return build_schedule(self, variant)

    @property
    def n_arks(self) -> int:
        """ARK executions per stream key (HERA/Rubato: initial + (r-1) RFs
        + final; PASTA: none — its key is the initial state and constants
        enter additively through the affine layers) — counted off the
        schedule program, not a duplicated formula."""
        return self.schedule().n_arks

    @property
    def n_round_constants(self) -> int:
        """Total uniform round constants per stream key, derived from the
        schedule's rc-slice annotations (the RNG FIFO depth).

        HERA: (r+1)*n (96 for Par-128a).  Rubato: r*n + l because the final
        ARK feeds a truncation, so only l of its constants matter (188 for
        Par-128L = 64+64+60), matching the paper's FIFO-depth accounting.
        """
        return self.schedule().n_round_constants

    @property
    def n_matrix_constants(self) -> int:
        """Matrix-plane words per stream key, derived from the schedule's
        mat-slice annotations (0 for HERA/Rubato; PASTA's stream-sourced
        affine layers draw (r+1)·n·t dense-matrix words)."""
        return self.schedule().n_matrix_constants

    @property
    def n_noise(self) -> int:
        return self.l if (self.kind == "rubato" and self.sigma > 0) else 0

    def mix_matrix(self) -> np.ndarray:
        """M_v: circulant with first row [2, 3, 1, ..., 1] (paper's M_4).

        For v=4 this is exactly the paper's matrix; v in {6, 8} uses the same
        circulant family (small coefficients {1,2,3} => shift-add datapath).
        """
        first = [2, 3] + [1] * (self.v - 2)
        rows = [np.roll(first, i) for i in range(self.v)]
        return np.array(rows, dtype=np.int64)

    def xof_words_per_block(self) -> int:
        """uint32 XOF words one stream-key block consumes (constants+noise).

        Uses the stream (compact) rejection sampler: ~1 word per constant +
        a fixed safety pad — this reproduces the paper's accounting of ~37
        AES invocations (~4700 bits) for Rubato Par-128L.
        """
        from repro.crypto.sampler import words_needed_uniform_stream

        words = words_needed_uniform_stream(self.n_round_constants) + 2 * self.n_noise
        if self.n_matrix_constants:
            # Matrix planes draw AFTER rc+noise from the same per-block
            # stream, so the rc/noise word positions (and hence HERA/Rubato
            # streams) are unchanged by their presence.
            words += words_needed_uniform_stream(self.n_matrix_constants)
        return words


# HERA 80-bit set (the paper's other benchmarked HERA point): same state,
# one fewer round than Par-128a — the cheapest preset, which is why the
# serving-plane load bench leans on it.
HERA_80 = CipherParams(
    name="hera-80", kind="hera", n=16, l=16, rounds=4, mod=Q_HERA
)

HERA_128A = CipherParams(
    name="hera-128a", kind="hera", n=16, l=16, rounds=5, mod=Q_HERA
)

# Rubato family: bigger state <-> fewer rounds (Rubato paper's S/M/L split).
RUBATO_128S = CipherParams(
    name="rubato-128s", kind="rubato", n=16, l=12, rounds=5, mod=Q_RUBATO,
    sigma=1.6,
)
RUBATO_128M = CipherParams(
    name="rubato-128m", kind="rubato", n=36, l=32, rounds=3, mod=Q_RUBATO,
    sigma=1.6,
)
RUBATO_128L = CipherParams(
    name="rubato-128l", kind="rubato", n=64, l=60, rounds=2, mod=Q_RUBATO,
    sigma=1.6,
)

# PASTA family: two t-element branches (n = 2t, t = v^2 for the per-branch
# matrix datapath), keystream = one branch.  The S/L split mirrors the
# PASTA paper's Pasta-4 (smaller state, more rounds) / Pasta-3 (bigger
# state, fewer rounds) trade; t is a perfect square here so each branch
# rides the (v, v) shift-add matrix machinery (docs/DESIGN.md §11).
PASTA_128S = CipherParams(
    name="pasta-128s", kind="pasta", n=32, l=16, rounds=4, mod=Q_PASTA
)
PASTA_128L = CipherParams(
    name="pasta-128l", kind="pasta", n=128, l=64, rounds=3, mod=Q_PASTA
)

REGISTRY = {
    p.name: p for p in (HERA_80, HERA_128A, RUBATO_128S, RUBATO_128M,
                        RUBATO_128L, PASTA_128S, PASTA_128L)
}


def get_params(name: str) -> CipherParams:
    if name not in REGISTRY:
        raise KeyError(f"unknown cipher {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]
