"""HERA stream-key generation (paper §III-A).

    HERA(k) = Fin ∘ RF_{r-1} ∘ ... ∘ RF_1 ∘ ARK(k)       applied to ic
    RF  = ARK ∘ Cube ∘ MixRows ∘ MixColumns
    Fin = ARK ∘ MixRows ∘ MixColumns ∘ Cube ∘ MixRows ∘ MixColumns

The round structure is *data*, not code: `core/schedule.py` emits it once
(`build_schedule`), and this module is a thin wrapper over the pure-JAX
interpreter `execute_schedule` — the same program the fused Pallas kernel
runs.  Round-constant accounting ((r+1) ARKs × n constants = 96 for
Par-128a) is a property of that program.
"""

from __future__ import annotations

from repro.core.params import CipherParams
from repro.core.schedule import build_schedule, execute_schedule


def hera_stream_key(params: CipherParams, key, rc, ic=None,
                    variant: str = "normal"):
    """Generate keystream blocks.

    key: (..., n) uint32 in Z_q (broadcastable against rc's batch dims).
    rc:  (..., r+1, n) uint32 round constants (from the XOF producer — the
         decoupled-RNG interface: constants are an *input*, so the producer
         runs concurrently; see docs/DESIGN.md T3).
    Returns (..., n) uint32 keystream block.
    """
    if rc.shape[-2] != params.n_arks or rc.shape[-1] != params.n:
        raise ValueError(f"rc shape {rc.shape} != (..., {params.n_arks}, {params.n})")
    sched = build_schedule(params, variant)
    flat = rc.reshape(rc.shape[:-2] + (sched.n_round_constants,))
    return execute_schedule(params, sched, key, flat, ic=ic)
