"""HERA stream-key generation (paper §III-A).

    HERA(k) = Fin ∘ RF_{r-1} ∘ ... ∘ RF_1 ∘ ARK(k)       applied to ic
    RF  = ARK ∘ Cube ∘ MixRows ∘ MixColumns
    Fin = ARK ∘ MixRows ∘ MixColumns ∘ Cube ∘ MixRows ∘ MixColumns

Round-constant accounting: (r+1) ARKs × n constants = 96 for Par-128a.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import rounds as R
from repro.core.params import CipherParams


def hera_stream_key(params: CipherParams, key, rc, ic=None):
    """Generate keystream blocks.

    key: (..., n) uint32 in Z_q (broadcastable against rc's batch dims).
    rc:  (..., r+1, n) uint32 round constants (from the XOF producer — the
         decoupled-RNG interface: constants are an *input*, so the producer
         runs concurrently; see docs/DESIGN.md T3).
    Returns (..., n) uint32 keystream block.
    """
    if rc.shape[-2] != params.n_arks or rc.shape[-1] != params.n:
        raise ValueError(f"rc shape {rc.shape} != (..., {params.n_arks}, {params.n})")
    if ic is None:
        ic = jnp.asarray(R.ic_vector(params))
    x = jnp.broadcast_to(ic, rc.shape[:-2] + (params.n,))

    x = R.ark(params, x, key, rc[..., 0, :])
    for j in range(1, params.rounds):          # RF_1 .. RF_{r-1}
        x = R.mrmc(params, x)                  # MixColumns then MixRows
        x = R.cube(params, x)
        x = R.ark(params, x, key, rc[..., j, :])
    # Fin
    x = R.mrmc(params, x)
    x = R.cube(params, x)
    x = R.mrmc(params, x)
    x = R.ark(params, x, key, rc[..., params.rounds, :])
    return x
