"""Measured StreamPlan autotuner: producer × engine × variant × window ×
depth × matrix_depth × reduction.

The ROADMAP's named follow-up to the engine registry — "latency-measured
autotuning of (engine, variant)" — generalized to the full pipeline tuple
now that the producer half is a registry too.  DNA-HHE's dual-mode
accelerator and Medha's microcoded configurability both win by *selecting*
among execution strategies per workload shape; this module makes that
selection measured, cached, and first-class:

  * :class:`StreamPlan` — one immutable pipeline configuration: which
    `repro.core.producer` backend materializes constants, which
    `repro.core.engine` backend consumes them, under which schedule
    orientation, at what window size, behind what FIFO depth.
  * :func:`autotune` — times every candidate plan on the *real*
    `KeystreamFarm` loop (same dispatch pattern the serving path runs,
    not a microbenchmark), picks by measured per-window p50, and persists
    the winner to a JSON cache keyed by (preset, lanes, noise, host
    fingerprint) so serving restarts skip re-tuning.
  * :func:`load_plan` — the cheap cache-only lookup "auto" resolution
    consults (`repro.core.engine.resolve_engine` /
    `repro.core.producer.resolve_producer`); static preference remains
    the no-cache fallback.

Candidate plans are *stream-preserving* by construction: only producers
whose XOF stream matches ``params.xof`` are eligible
(`repro.core.producer.compatible_producers`), and every engine × variant
is bit-exact by the registry contract — so a tuned plan can change
latency, never a keystream bit.

    PYTHONPATH=src python -m repro.core.tuner                 # tables
    PYTHONPATH=src python -m repro.core.tuner --autotune \\
        --preset rubato-128l --lanes 256                      # measure

The cache lives at ``$REPRO_TUNER_CACHE`` (or
``~/.cache/repro-presto/streamplans.json``); `scripts/ci.sh` smokes the
measure→persist→reload loop with a temp cache.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import platform
import time
from typing import List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core.cipher import CipherBatch
from repro.core.engine import engine_caps
from repro.core.farm import KeystreamFarm, pack_windows
from repro.core.params import CipherParams, get_params
from repro.core.producer import compatible_producers, producer_caps

CACHE_VERSION = 1
#: Per-entry plan schema.  Bump whenever a backend changes SEMANTICS under
#: an unchanged name (a plan measured against the old semantics must not
#: steer the new code) — the ROADMAP's plan-invalidation follow-up.
#: History: 1 = PR 4 entries (implicit, no schema field);
#:          2 = branch-aware schedule executors (PASTA introduction);
#:          3 = stream-sourced matrix planes (PASTA's dense affine
#:              matrices; plans gain the farm's matrix_depth knob);
#:          4 = reduction-scheduling pass (core/redplan.py; plans gain
#:              the lazy/eager reduction mode as a measured dimension,
#:              and the executors' default datapath moved to lazy).
PLAN_SCHEMA = 4
_ENV_CACHE = "REPRO_TUNER_CACHE"


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """One pipeline configuration — the autotuner's unit of selection.

    Round-trips through JSON bit-identically (`to_json`/`from_json`):
    every field is a primitive, and unknown keys on load are ignored so
    cache entries can carry measurement metadata beside the plan.
    """

    producer: str      # repro.core.producer backend name
    engine: str        # repro.core.engine backend name
    variant: str       # schedule orientation (core/schedule.py)
    window: int        # lanes per farm window
    depth: int         # producer->consumer FIFO depth (farm)
    matrix_depth: int = 1  # matrix-plane prefetch depth (farm; PASTA only)
    reduction: str = "lazy"  # reduction-scheduling mode (core/redplan.py)

    def to_json(self) -> dict:
        return {
            "producer": self.producer,
            "engine": self.engine,
            "variant": self.variant,
            "window": int(self.window),
            "depth": int(self.depth),
            "matrix_depth": int(self.matrix_depth),
            "reduction": self.reduction,
        }

    @classmethod
    def from_json(cls, d: dict) -> "StreamPlan":
        return cls(
            producer=str(d["producer"]),
            engine=str(d["engine"]),
            variant=str(d["variant"]),
            window=int(d["window"]),
            depth=int(d["depth"]),
            matrix_depth=int(d.get("matrix_depth", 1)),
            reduction=str(d.get("reduction", "lazy")),
        )

    def describe(self) -> str:
        return (f"producer={self.producer} engine={self.engine} "
                f"variant={self.variant} window={self.window} "
                f"depth={self.depth} matrix_depth={self.matrix_depth} "
                f"reduction={self.reduction}")


# ==========================================================================
# Cache: JSON keyed by (preset, lanes, noise, host fingerprint)
# ==========================================================================
def host_fingerprint() -> str:
    """Stable id for "this machine, this backend" — a plan measured on one
    host must not steer another (the tuner's answer is hardware-shaped)."""
    dev = jax.devices()[0]
    raw = "|".join([
        platform.machine(),
        platform.system(),
        jax.default_backend(),
        getattr(dev, "device_kind", "?"),
        str(jax.device_count()),
        str(os.cpu_count()),
    ])
    return hashlib.sha1(raw.encode()).hexdigest()[:12]


def cache_key(params: CipherParams, lanes: Optional[int]) -> str:
    return (f"{params.name}|lanes={lanes}|noise={params.n_noise}"
            f"|host={host_fingerprint()}")


def default_cache_path() -> pathlib.Path:
    env = os.environ.get(_ENV_CACHE)
    if env:
        return pathlib.Path(env)
    return (pathlib.Path.home() / ".cache" / "repro-presto"
            / "streamplans.json")


def _read_cache(path: pathlib.Path) -> dict:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {"version": CACHE_VERSION, "plans": {}}
    if data.get("version") != CACHE_VERSION:
        return {"version": CACHE_VERSION, "plans": {}}
    return data


def _write_cache(path: pathlib.Path, data: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(path.suffix + ".tmp")
    with open(tmp, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    os.replace(tmp, path)


def _coerce_params(params: Union[CipherParams, str]) -> CipherParams:
    return get_params(params) if isinstance(params, str) else params


def _plan_is_valid(plan: StreamPlan, params: CipherParams, *,
                   mesh=None, axis: str = "data") -> bool:
    """A cached plan is only trusted if every named backend still exists,
    is available here, and preserves the preset's XOF stream."""
    pcaps = producer_caps().get(plan.producer)
    if pcaps is None or not pcaps.available:
        return False
    if pcaps.stream not in (None, params.xof):
        return False
    ecaps = engine_caps(mesh=mesh, axis=axis).get(plan.engine)
    if ecaps is None or not ecaps.available:
        return False
    if plan.variant not in ecaps.schedule_variants:
        return False
    from repro.core.redplan import REDUCTION_MODES

    if plan.reduction not in REDUCTION_MODES:
        return False
    return (plan.window >= 1 and plan.depth >= 1
            and plan.matrix_depth >= 1)


def save_plan(params: Union[CipherParams, str], lanes: int, plan: StreamPlan,
              p50_ms: float, cache_path=None,
              measurements: Optional[List[dict]] = None) -> pathlib.Path:
    """Persist a measured plan (with its measurement, as metadata).

    Entries are stamped with the current ``PLAN_SCHEMA`` so a later
    semantics bump invalidates them on load instead of letting a
    stale-semantics measurement steer the new code.  ``measurements``
    (optional) is the full per-candidate timing table from the autotune
    lap — plan fields + ``p50_ms`` per candidate — stored as entry
    metadata so the analytic cost model (`repro.analysis.cost`) can
    validate its predicted ordering against what was actually measured,
    not just against the single winner.
    """
    params = _coerce_params(params)
    path = pathlib.Path(cache_path) if cache_path else default_cache_path()
    data = _read_cache(path)
    entry = plan.to_json()
    entry.update({"schema": PLAN_SCHEMA, "p50_ms": float(p50_ms),
                  "measured_at": time.time(),
                  "backend": jax.default_backend()})
    if measurements:
        entry["measurements"] = [
            {**m, "p50_ms": float(m["p50_ms"])} for m in measurements
        ]
    data["plans"][cache_key(params, lanes)] = entry
    _write_cache(path, data)
    return path


def _entry_schema(entry: dict) -> int:
    """Schema an entry was measured under (1 = legacy, pre-stamp)."""
    try:
        return int(entry.get("schema", 1))
    except (TypeError, ValueError):
        return 0


def _entry_plan(entry: dict, params: CipherParams, *, mesh=None,
                axis: str = "data") -> Optional[StreamPlan]:
    """Parse + validate one cache entry; None when it must not be trusted
    (stale schema, malformed, or naming gone/unavailable backends)."""
    if _entry_schema(entry) != PLAN_SCHEMA:
        return None
    try:
        plan = StreamPlan.from_json(entry)
    except (KeyError, TypeError, ValueError):
        return None
    return plan if _plan_is_valid(plan, params, mesh=mesh, axis=axis) \
        else None


def load_plan(params: Union[CipherParams, str], lanes: Optional[int] = None,
              cache_path=None, *, mesh=None,
              axis: str = "data") -> Optional[StreamPlan]:
    """Cache-only lookup (never measures): the tuned plan for (preset,
    lanes) on this host, or None.

    With ``lanes=None`` — or when the exact lane count was never tuned —
    falls back to the nearest tuned lane count for the same (preset,
    noise, host), deterministically (closest; ties break toward the
    smaller).  Plans naming backends that are gone or unavailable here,
    and entries persisted under a different ``PLAN_SCHEMA`` (measured
    against since-changed backend semantics), are ignored rather than
    trusted.
    """
    params = _coerce_params(params)
    path = pathlib.Path(cache_path) if cache_path else default_cache_path()
    plans = _read_cache(path)["plans"]
    exact = plans.get(cache_key(params, lanes))
    if exact is not None:
        return _entry_plan(exact, params, mesh=mesh, axis=axis)
    # nearest-lanes fallback within the same (preset, noise, host) family
    prefix = f"{params.name}|lanes="
    suffix = f"|noise={params.n_noise}|host={host_fingerprint()}"
    candidates: List[Tuple[int, StreamPlan]] = []
    for key, entry in plans.items():
        if not (key.startswith(prefix) and key.endswith(suffix)):
            continue
        lane_s = key[len(prefix) : len(key) - len(suffix)]
        try:
            lane_n = int(lane_s)
        except ValueError:
            continue
        plan = _entry_plan(entry, params, mesh=mesh, axis=axis)
        if plan is not None:
            candidates.append((lane_n, plan))
    if not candidates:
        return None
    target = lanes if lanes is not None else max(n for n, _ in candidates)
    candidates.sort(key=lambda np_: (abs(np_[0] - target), np_[0]))
    return candidates[0][1]


def load_measurements(params: Union[CipherParams, str],
                      lanes: Optional[int] = None,
                      cache_path=None) -> List[dict]:
    """The per-candidate timing table persisted by the last autotune lap
    for (preset, lanes) on this host — ``[]`` when nothing was measured.

    Each row is a plan's JSON fields plus its measured ``p50_ms``.  Unlike
    :func:`load_plan` this returns raw measurements (it does not validate
    backend availability — a measurement stays a fact about the lap that
    produced it), but stale-``PLAN_SCHEMA`` entries are still ignored:
    timings taken under changed backend semantics must not validate the
    current cost model.  With ``lanes=None`` the nearest tuned lane count
    is used, matching :func:`load_plan`'s fallback.
    """
    params = _coerce_params(params)
    path = pathlib.Path(cache_path) if cache_path else default_cache_path()
    plans = _read_cache(path)["plans"]

    def _rows(entry) -> List[dict]:
        if entry is None or _entry_schema(entry) != PLAN_SCHEMA:
            return []
        rows = entry.get("measurements", [])
        return [r for r in rows if isinstance(r, dict) and "p50_ms" in r]

    exact = _rows(plans.get(cache_key(params, lanes)))
    if exact:
        return exact
    prefix = f"{params.name}|lanes="
    suffix = f"|noise={params.n_noise}|host={host_fingerprint()}"
    candidates: List[Tuple[int, List[dict]]] = []
    for key, entry in plans.items():
        if not (key.startswith(prefix) and key.endswith(suffix)):
            continue
        try:
            lane_n = int(key[len(prefix): len(key) - len(suffix)])
        except ValueError:
            continue
        rows = _rows(entry)
        if rows:
            candidates.append((lane_n, rows))
    if not candidates:
        return []
    target = lanes if lanes is not None else max(n for n, _ in candidates)
    candidates.sort(key=lambda nr: (abs(nr[0] - target), nr[0]))
    return candidates[0][1]


# ==========================================================================
# Measurement: the real farm loop, per candidate plan
# ==========================================================================
def candidate_plans(params: Union[CipherParams, str], lanes: int, *,
                    mesh=None, axis: str = "data",
                    producers: Optional[Sequence[str]] = None,
                    engines: Optional[Sequence[str]] = None,
                    variants: Optional[Sequence[str]] = None,
                    windows: Optional[Sequence[int]] = None,
                    depths: Optional[Sequence[int]] = None,
                    matrix_depths: Optional[Sequence[int]] = None,
                    reductions: Optional[Sequence[str]] = None
                    ) -> List[StreamPlan]:
    """The default candidate grid for one (preset, lanes) workload shape.

    Producers: every stream-preserving registered backend.  Engines: every
    available backend except the oracles ("ref") and interpret-mode Pallas
    (correctness tools, not serving paths).  Windows: the full batch and a
    half-batch split (more pipelining); depths: double and triple
    buffering.  Matrix depths: no-prefetch vs double-prefetch of the
    matrix plane — only a real dimension for stream-sourced-MRMC presets
    (PASTA); otherwise pinned at 1.  Reductions: the lazy reduction
    schedule vs the eager baseline (core/redplan.py; bit-exact, so like
    variant it is purely a latency dimension).  Pass explicit sequences
    to override any dimension.
    """
    params = _coerce_params(params)
    if producers is None:
        producers = compatible_producers(params)
    if engines is None:
        caps = engine_caps(mesh=mesh, axis=axis)
        engines = [n for n, c in caps.items()
                   if c.available and n not in ("ref", "pallas-interpret")]
        if not engines:
            engines = ["jax"]
    if variants is None:
        variants = ("normal", "alternating")
    if windows is None:
        half = lanes // 2
        windows = sorted({lanes, half} - {0})
    if depths is None:
        depths = (2, 3)
    if matrix_depths is None:
        matrix_depths = (1, 2) if params.n_matrix_constants else (1,)
    if reductions is None:
        reductions = ("lazy", "eager")
    plans = []
    for prod in producers:
        for eng in engines:
            for var in variants:
                for win in windows:
                    for dep in depths:
                        for mdep in matrix_depths:
                            for red in reductions:
                                plans.append(StreamPlan(
                                    prod, eng, var, int(win), int(dep),
                                    int(mdep), str(red)))
    return plans


def measure_plan(params: Union[CipherParams, str], plan: StreamPlan,
                 lanes: int, *, sessions: int = 2, n_windows: int = 4,
                 reps: int = 2, mesh=None, axis: str = "data",
                 seed: int = 0) -> float:
    """Per-window p50 latency (seconds) of one plan on the real farm loop.

    Runs ``n_windows`` windows of ``plan.window`` lanes over a
    ``sessions``-session pool, ``reps`` times (after a warmup lap that
    absorbs compilation), exactly the dispatch pattern `KeystreamFarm.run`
    serves — so the number the tuner ranks on is the number serving sees.
    """
    params = _coerce_params(params)
    batch = CipherBatch(params, seed=seed, producer=plan.producer)
    batch.add_sessions(sessions)
    farm = KeystreamFarm(batch, engine=plan.engine, variant=plan.variant,
                         depth=plan.depth, matrix_depth=plan.matrix_depth,
                         reduction=plan.reduction, mesh=mesh, axis=axis)

    total = plan.window * n_windows
    sids = np.resize(np.arange(sessions, dtype=np.int64), total)

    def wplans(base: int):
        # counters unique per (session, lane occurrence); tuning draws no
        # real session counters (nothing is ever sent), so plain ranges do
        ctrs = base + np.arange(total, dtype=np.int64) // sessions
        return pack_windows(sids, ctrs, plan.window)

    for _, z in farm.run(wplans(0)):        # warmup: compile both programs
        jax.block_until_ready(z)
    lat: List[float] = []
    for rep in range(reps):
        it = farm.run(wplans((rep + 1) * total))
        while True:
            t0 = time.perf_counter()
            try:
                _, z = next(it)
            except StopIteration:
                break
            jax.block_until_ready(z)
            lat.append(time.perf_counter() - t0)
    return float(np.percentile(np.asarray(lat), 50))


def autotune(params: Union[CipherParams, str], lanes: int, *,
             sessions: int = 2, n_windows: int = 4, reps: int = 2,
             mesh=None, axis: str = "data",
             producers: Optional[Sequence[str]] = None,
             engines: Optional[Sequence[str]] = None,
             variants: Optional[Sequence[str]] = None,
             windows: Optional[Sequence[int]] = None,
             depths: Optional[Sequence[int]] = None,
             reductions: Optional[Sequence[str]] = None,
             cache_path=None, force: bool = False,
             verbose: bool = False) -> StreamPlan:
    """Measure every candidate plan and return (and persist) the winner.

    Consults the cache first: a valid persisted plan for this (preset,
    lanes, host) is returned as-is (deterministically — no re-timing)
    unless ``force=True``.  Selection is by measured per-window p50 on
    the real farm loop; ties break toward the earlier candidate, which
    orders the grid's defaults (paper-conformance producer, shallower
    pipeline) first.
    """
    params = _coerce_params(params)
    if not force:
        cached = load_plan(params, lanes, cache_path, mesh=mesh, axis=axis)
        if cached is not None:
            if verbose:
                print(f"[tuner] cache hit for {params.name}/lanes={lanes}: "
                      f"{cached.describe()}")
            return cached
    plans = candidate_plans(params, lanes, mesh=mesh, axis=axis,
                            producers=producers, engines=engines,
                            variants=variants, windows=windows,
                            depths=depths, reductions=reductions)
    if not plans:
        raise RuntimeError("no candidate StreamPlans (empty grid?)")
    best: Optional[StreamPlan] = None
    best_p50 = float("inf")
    measurements: List[dict] = []
    for plan in plans:
        p50 = measure_plan(params, plan, lanes, sessions=sessions,
                           n_windows=n_windows, reps=reps, mesh=mesh,
                           axis=axis)
        measurements.append({**plan.to_json(), "p50_ms": p50 * 1e3})
        if verbose:
            print(f"[tuner] {plan.describe():60s} p50={p50 * 1e3:8.3f} ms")
        if p50 < best_p50:
            best, best_p50 = plan, p50
    path = save_plan(params, lanes, best, best_p50 * 1e3, cache_path,
                     measurements=measurements)
    if verbose:
        print(f"[tuner] winner: {best.describe()} "
              f"(p50={best_p50 * 1e3:.3f} ms) -> {path}")
    return best


# ==========================================================================
# Introspection CLI: `python -m repro.core.tuner`
# ==========================================================================
def describe(cache_path=None) -> str:
    """The plan table (every cached StreamPlan for this host) printed next
    to the producer and engine registry tables — one view of the whole
    selection space."""
    from repro.core import engine as engine_mod
    from repro.core import producer as producer_mod

    path = pathlib.Path(cache_path) if cache_path else default_cache_path()
    plans = _read_cache(path)["plans"]
    fp = host_fingerprint()
    lines = ["=== cached StreamPlans (this host) ==="]
    rows = [("key", "producer", "engine", "variant", "window", "depth",
             "mdepth", "reduction", "p50 ms")]
    for key in sorted(plans):
        if f"|host={fp}" not in key:
            continue
        e = plans[key]
        schema = _entry_schema(e)
        stale = "" if schema == PLAN_SCHEMA else \
            f"  [STALE schema {schema} != {PLAN_SCHEMA}: ignored]"
        rows.append((key.split("|host=")[0], e["producer"], e["engine"],
                     e["variant"], str(e["window"]), str(e["depth"]),
                     str(e.get("matrix_depth", 1)),
                     str(e.get("reduction", "lazy")),
                     f"{e.get('p50_ms', float('nan')):.3f}" + stale))
    if len(rows) == 1:
        lines.append(f"  (none at {path}; run --autotune, or serve with "
                     "--autotune)")
    else:
        widths = [max(len(r[i]) for r in rows) for i in range(9)]
        for i, r in enumerate(rows):
            lines.append("  ".join(r[j].ljust(widths[j]) for j in range(9)))
            if i == 0:
                lines.append("  ".join("-" * w for w in widths))
    lines += ["", "=== producer registry ===", producer_mod.describe(),
              "", "=== engine registry ===", engine_mod.describe()]
    return "\n".join(lines)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--autotune", action="store_true",
                    help="measure (and persist) a plan before printing")
    ap.add_argument("--preset", default="rubato-128l")
    ap.add_argument("--lanes", type=int, default=64)
    ap.add_argument("--sessions", type=int, default=2)
    ap.add_argument("--windows", type=int, default=4,
                    help="timed windows per rep")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--force", action="store_true",
                    help="re-measure even on a cache hit")
    ap.add_argument("--cache", default=None,
                    help=f"cache path (default ${_ENV_CACHE} or "
                         f"{default_cache_path()})")
    args = ap.parse_args(argv)
    if args.autotune:
        plan = autotune(args.preset, args.lanes, sessions=args.sessions,
                        n_windows=args.windows, reps=args.reps,
                        cache_path=args.cache, force=args.force,
                        verbose=True)
        print(f"\ntuned plan for {args.preset}/lanes={args.lanes}: "
              f"{plan.describe()}\n")
    print(describe(args.cache))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
