"""Unified client-side cipher API: keystream / encrypt / decrypt.

Producer/consumer split (the paper's T3, "RNG decoupling"):

  * :meth:`Cipher.round_constant_stream` — the *producer*: XOF + rejection
    sampling + Gaussian sampling.  Depends only on (nonce, block counters),
    NOT on the key or message, so it can be dispatched concurrently with
    the previous batch's compute (async dispatch on TPU) or precomputed.
  * :meth:`Cipher.keystream` — the *consumer*: the round pipeline, taking
    the constants as an explicit input.  Consumers are pluggable
    :mod:`repro.core.engine` backends; a Cipher binds the eager ``ref``
    engine by default (the oracle all other engines must match).
  * :meth:`Cipher.keystream_coupled` — paper's D1-style baseline: a single
    computation that serializes XOF → sampling → rounds (for benchmarks).

Multi-stream farm (the T3 split lifted from kernel to system level):

  * :class:`StreamSession` — one client stream: a public nonce plus a
    block-counter cursor that hands out disjoint counter windows.
  * :class:`CipherBatch` — one symmetric key, a pool of sessions.  Its
    producer/consumer pair takes *per-lane* (session, counter) pairs, so a
    single jit'd call serves lanes drawn from arbitrarily many concurrent
    sessions — bit-exact with each session's own single-stream `Cipher`.
    `core/farm.py` double-buffers these producers against the fused Pallas
    consumer; `serve/hhe_loop.py` packs request traffic into its windows.

Message encoding: real vectors are fixed-point encoded, m_q = round(m·Δ)
centered into Z_q; encryption is c = m_q + z, decryption m_q = c − z (the
RtF client side).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineSpec, make_engine
from repro.core.params import CipherParams, get_params
from repro.crypto.aes import aes128_key_expand
from repro.crypto.sampler import (
    DGaussTable,
    discrete_gaussian,
    uniform_mod_q_stream,
    words_needed_uniform_stream,
)
from repro.crypto.xof import (
    aes_xof_words_batched,
    threefry_root_key,
    threefry_xof_words_batched,
    xof_words,
)


def _constants_from_words(params: CipherParams, words, gauss: Optional[DGaussTable]):
    """Shared producer tail: XOF words -> dict(rc=..., noise=...).

    words: (..., total) uint32 where total = words_needed_uniform_stream(
    n_round_constants) + 2*n_noise.  Used by both the single-stream and the
    batched producer so the two are bit-exact by construction.
    """
    p = params
    n_u = p.n_round_constants
    w_u = words_needed_uniform_stream(n_u)
    rc = uniform_mod_q_stream(words[..., :w_u], n_u, p.mod)
    noise = None
    if p.n_noise:
        hi = words[..., w_u : w_u + p.n_noise]
        lo = words[..., w_u + p.n_noise : w_u + 2 * p.n_noise]
        noise = discrete_gaussian(hi, lo, gauss)
    return {"rc": rc, "noise": noise}


def encode_fixed(mod, m_real, delta: float):
    """Fixed-point encode: m_q = round(m·Δ) centered into Z_q.

    THE encoding convention — every encrypt path (Cipher, CipherBatch,
    farm streams, serve loop) must go through this pair so bit-exactness
    holds across them.
    """
    mq = jnp.round(jnp.asarray(m_real, jnp.float32) * delta).astype(jnp.int32)
    return mod.from_signed(mq)


def decode_fixed(mod, m_q, delta: float):
    """Inverse of :func:`encode_fixed`."""
    return mod.to_signed(m_q).astype(jnp.float32) / delta


@dataclasses.dataclass
class Cipher:
    params: CipherParams
    key: jnp.ndarray          # (n,) uint32 in Z_q — the symmetric secret
    nonce: np.ndarray         # (16,) uint8, public
    engine: EngineSpec = "ref"   # consumer backend (see repro.core.engine)

    def __post_init__(self):
        self.key = jnp.asarray(self.key, dtype=jnp.uint32)
        if self.key.shape != (self.params.n,):
            raise ValueError(f"key shape {self.key.shape} != ({self.params.n},)")
        self.nonce = np.asarray(self.nonce, dtype=np.uint8).reshape(16)
        self._gauss = (
            DGaussTable.build(self.params.sigma) if self.params.n_noise else None
        )
        # the single-stream default is the eager reference engine — the
        # oracle everything else (farm engines, kernels) is checked against
        self._engine = make_engine(self.engine, self.params, self.key)

    # ---------------- producer (decoupled RNG) ---------------------------
    def round_constant_stream(self, block_ctrs):
        """Sample all per-block randomness.  Returns dict(rc=..., noise=...).

        rc: (lanes, n_round_constants) uint32; noise: (lanes, l) int32 or None.
        """
        p = self.params
        total = words_needed_uniform_stream(p.n_round_constants) + 2 * p.n_noise
        words = xof_words(p.xof, self.nonce, block_ctrs, total)
        return _constants_from_words(p, words, self._gauss)

    # ---------------- consumer (round pipeline) --------------------------
    def keystream_from_constants(self, rc, noise=None):
        return self._engine.keystream_from_constants(rc, noise)

    def keystream(self, block_ctrs, constants=None):
        """(lanes,) block counters -> (lanes, l) keystream."""
        if constants is None:
            constants = self.round_constant_stream(block_ctrs)
        return self.keystream_from_constants(constants["rc"], constants["noise"])

    def keystream_coupled(self, block_ctrs):
        """D1-style baseline: RNG serialized with rounds inside one call."""
        c = self.round_constant_stream(block_ctrs)
        # optimization_barrier pins the ordering (no overlap), mirroring the
        # software baseline that samples ALL constants before any round work.
        c = jax.lax.optimization_barrier(
            {k: v for k, v in c.items() if v is not None}
        )
        return self.keystream_from_constants(c["rc"], c.get("noise"))

    # ---------------- encryption ----------------------------------------
    def encode(self, m_real, delta: float):
        return encode_fixed(self.params.mod, m_real, delta)

    def decode(self, m_q, delta: float):
        return decode_fixed(self.params.mod, m_q, delta)

    def encrypt(self, m_real, block_ctrs, delta: float = 1024.0, constants=None):
        """Encrypt (lanes, l) real messages -> (lanes, l) uint32 ciphertext."""
        z = self.keystream(block_ctrs, constants)
        return self.params.mod.add(self.encode(m_real, delta), z)

    def decrypt(self, c, block_ctrs, delta: float = 1024.0, constants=None):
        z = self.keystream(block_ctrs, constants)
        return self.decode(self.params.mod.sub(c, z), delta)


def make_cipher(name: str, key=None, nonce=None, seed: int = 0,
                engine: EngineSpec = "ref") -> Cipher:
    """Convenience constructor; random key/nonce from ``seed`` if omitted."""
    p = get_params(name)
    rng = np.random.default_rng(seed)
    if key is None:
        key = rng.integers(1, p.mod.q, size=(p.n,), dtype=np.uint32)
    if nonce is None:
        nonce = rng.integers(0, 256, size=(16,), dtype=np.uint8)
    return Cipher(p, jnp.asarray(key, jnp.uint32), nonce, engine)


# ==========================================================================
# Multi-stream farm: one key, many (nonce, counter-window) sessions
# ==========================================================================
#: Block counters per session.  The AES XOF gives each cipher-block counter
#: a 2^16-block subspace of a 32-bit AES counter field (crypto/xof.py), so
#: counters >= 2^16 alias earlier XOF streams — a two-time pad.  A session
#: is therefore capped at 2^16 blocks (~4M Z_q elements for Rubato-128L);
#: clients needing more open a fresh session (new nonce).
SESSION_CTR_LIMIT = 1 << 16


@dataclasses.dataclass
class StreamSession:
    """One client stream: public nonce + a block-counter window cursor.

    Sessions never share (nonce, counter) pairs: `take_window` hands out
    consecutive disjoint counter ranges, so keystream reuse cannot happen
    within a session, and distinct nonces keep sessions independent.
    Exhausting the counter space (SESSION_CTR_LIMIT) raises instead of
    silently wrapping into keystream reuse — long-lived streams rotate to
    a fresh nonce via :meth:`CipherBatch.rotate_session` (``generation``
    counts rotations).
    """

    index: int
    nonce: np.ndarray          # (16,) uint8, public
    next_ctr: int = 0
    generation: int = 0        # bumped by CipherBatch.rotate_session

    def __post_init__(self):
        self.nonce = np.asarray(self.nonce, dtype=np.uint8).reshape(16)

    def remaining(self) -> int:
        """Counters left before this (nonce, generation) is exhausted."""
        return SESSION_CTR_LIMIT - self.next_ctr

    def take_window(self, n_blocks: int) -> np.ndarray:
        """Reserve the next ``n_blocks`` counters; advances the cursor."""
        if self.next_ctr + n_blocks > SESSION_CTR_LIMIT:
            raise RuntimeError(
                f"session {self.index} counter space exhausted "
                f"({self.next_ctr} + {n_blocks} > {SESSION_CTR_LIMIT}); "
                "rotate_session (fresh nonce) instead of reusing keystream"
            )
        ctrs = np.arange(
            self.next_ctr, self.next_ctr + n_blocks, dtype=np.uint32
        )
        self.next_ctr += n_blocks
        return ctrs


class CipherBatch:
    """Session-batched cipher: one symmetric key, a pool of stream sessions.

    Every producer/consumer method takes parallel per-lane arrays
    ``(session_ids, block_ctrs)`` — lanes may mix sessions and counters
    arbitrarily, so one jit'd dispatch serves traffic from any number of
    concurrent clients.  Bit-exact with the single-stream :class:`Cipher`
    of each session (see :meth:`session_cipher`); the cross-check is
    tests/test_farm.py.

    Per-session XOF material (expanded AES round keys / threefry roots) is
    precompiled host-side at `add_session` time and gathered per lane on
    device, so adding sessions never retriggers tracing.
    """

    def __init__(self, params: CipherParams | str, key=None, seed: int = 0,
                 engine: EngineSpec = "ref"):
        if isinstance(params, str):
            params = get_params(params)
        self.params = params
        rng = np.random.default_rng(seed)
        if key is None:
            key = rng.integers(1, params.mod.q, size=(params.n,),
                               dtype=np.uint32)
        self.key = jnp.asarray(key, jnp.uint32)
        if self.key.shape != (params.n,):
            raise ValueError(f"key shape {self.key.shape} != ({params.n},)")
        self._rng = rng
        self._gauss = (
            DGaussTable.build(params.sigma) if params.n_noise else None
        )
        self._engine = self.make_engine(engine)
        self.sessions: List[StreamSession] = []
        # host-side per-session XOF material, stacked lazily into tables
        self._rk_host: List[np.ndarray] = []      # aes: (11, 16) u8 each
        self._root_host: list = []                # threefry: key each
        self._tables = None                       # device tables, lazy
        self._producer = None                     # built once, pool-agnostic

    def make_engine(self, spec: EngineSpec = "auto", *, mesh=None,
                    axis: str = "data", interpret=None,
                    variant: Optional[str] = None):
        """Bind a consumer engine to this pool's (params, key).

        The farm, serving loop, and data plane all get their consumers
        here, so backend policy stays in `repro.core.engine`.  ``variant``
        picks the schedule orientation plan (core/schedule.py; "auto" =
        the backend's preferred one) — bit-exact either way.
        """
        return make_engine(spec, self.params, self.key, mesh=mesh,
                           axis=axis, interpret=interpret, variant=variant)

    # ---------------- session pool ---------------------------------------
    def add_session(self, nonce=None) -> StreamSession:
        if nonce is None:
            nonce = self._rng.integers(0, 256, size=(16,), dtype=np.uint8)
        s = StreamSession(index=len(self.sessions), nonce=nonce)
        self.sessions.append(s)
        if self.params.xof == "aes":
            self._rk_host.append(aes128_key_expand(s.nonce))
        else:
            self._root_host.append(threefry_root_key(s.nonce))
        self._tables = None
        return s

    def add_sessions(self, count: int) -> List[StreamSession]:
        return [self.add_session() for _ in range(count)]

    def rotate_session(self, session_id: int, nonce=None) -> StreamSession:
        """Retire a session's (nonce, counter) space: fresh nonce, cursor 0.

        The replacement keeps the session's index (lane ids stay stable for
        long-lived clients) and bumps ``generation``; its XOF table row is
        rebuilt in place, so table *shapes* are unchanged and no producer
        retrace happens.  Any keystream still pending against the old nonce
        must be materialized before rotating (serve/hhe_loop.py flushes its
        queue first) — after rotation the pool can no longer regenerate the
        old stream.
        """
        old = self.sessions[session_id]
        if nonce is None:
            nonce = self._rng.integers(0, 256, size=(16,), dtype=np.uint8)
        s = StreamSession(index=session_id, nonce=nonce,
                          generation=old.generation + 1)
        self.sessions[session_id] = s
        if self.params.xof == "aes":
            self._rk_host[session_id] = aes128_key_expand(s.nonce)
        else:
            self._root_host[session_id] = threefry_root_key(s.nonce)
        self._tables = None
        return s

    def __len__(self) -> int:
        return len(self.sessions)

    def session_cipher(self, session_id: int) -> Cipher:
        """Single-stream view of one session (the bit-exactness oracle)."""
        return Cipher(self.params, self.key, self.sessions[session_id].nonce)

    def xof_tables(self):
        """Device-side per-session XOF material, rebuilt lazily on growth."""
        if self._tables is None:
            if self.params.xof == "aes":
                rk = jnp.asarray(np.stack(self._rk_host))      # (S, 11, 16)
                n12 = jnp.asarray(
                    np.stack([s.nonce[:12] for s in self.sessions])
                )                                              # (S, 12)
                self._tables = (rk, n12)
            else:
                self._tables = (jnp.stack(self._root_host),)   # (S,) keys
        return self._tables

    # ---------------- producer (decoupled, multi-stream) ------------------
    def make_producer_fn(self):
        """Pure producer ``fn(tables, session_ids, block_ctrs) -> constants``.

        Tables are runtime args (not baked constants) so a jit of this
        function stays valid — and retraces on shape change — as the
        session pool grows.  `core/farm.py` jits this as its producer.
        The closure depends only on (params, gauss), both fixed, so it is
        built once and cached.
        """
        if self._producer is not None:
            return self._producer
        p, gauss = self.params, self._gauss
        total = words_needed_uniform_stream(p.n_round_constants) + 2 * p.n_noise

        if p.xof == "aes":
            def producer(tables, session_ids, block_ctrs):
                rk, n12 = tables
                sid = jnp.asarray(session_ids, jnp.int32)
                ctrs = jnp.asarray(block_ctrs, jnp.uint32)
                words = aes_xof_words_batched(rk[sid], n12[sid], ctrs, total)
                return _constants_from_words(p, words, gauss)
        else:
            def producer(tables, session_ids, block_ctrs):
                (roots,) = tables
                sid = jnp.asarray(session_ids, jnp.int32)
                ctrs = jnp.asarray(block_ctrs, jnp.uint32)
                words = threefry_xof_words_batched(roots[sid], ctrs, total)
                return _constants_from_words(p, words, gauss)

        self._producer = producer
        return producer

    def round_constant_stream(self, session_ids, block_ctrs):
        """Per-lane randomness for lanes drawn from many sessions.

        session_ids/block_ctrs: (lanes,) int arrays (parallel).  Returns
        dict(rc=(lanes, n_round_constants) u32, noise=(lanes, l) i32|None).
        """
        return self.make_producer_fn()(
            self.xof_tables(), session_ids, block_ctrs
        )

    # ---------------- consumer (shared key, round pipeline) ---------------
    def keystream_from_constants(self, rc, noise=None):
        return self._engine.keystream_from_constants(rc, noise)

    def keystream(self, session_ids, block_ctrs, constants=None):
        """(lanes,) (session, ctr) pairs -> (lanes, l) keystream."""
        if constants is None:
            constants = self.round_constant_stream(session_ids, block_ctrs)
        return self.keystream_from_constants(
            constants["rc"], constants["noise"]
        )

    # ---------------- streaming encrypt / decrypt -------------------------
    def encrypt(self, m_real, session_ids, block_ctrs, delta: float = 1024.0,
                constants=None):
        z = self.keystream(session_ids, block_ctrs, constants)
        mod = self.params.mod
        return mod.add(encode_fixed(mod, m_real, delta), z)

    def decrypt(self, c, session_ids, block_ctrs, delta: float = 1024.0,
                constants=None):
        z = self.keystream(session_ids, block_ctrs, constants)
        mod = self.params.mod
        return decode_fixed(mod, mod.sub(c, z), delta)
