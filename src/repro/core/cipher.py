"""Unified client-side cipher API: keystream / encrypt / decrypt.

Producer/consumer split (the paper's T3, "RNG decoupling"):

  * :meth:`Cipher.round_constant_stream` — the *producer*: XOF + rejection
    sampling + Gaussian sampling.  Depends only on (nonce, block counters),
    NOT on the key or message, so it can be dispatched concurrently with
    the previous batch's compute (async dispatch on TPU) or precomputed.
  * :meth:`Cipher.keystream` — the *consumer*: the round pipeline, taking
    the constants as an explicit input.
  * :meth:`Cipher.keystream_coupled` — paper's D1-style baseline: a single
    computation that serializes XOF → sampling → rounds (for benchmarks).

Message encoding: real vectors are fixed-point encoded, m_q = round(m·Δ)
centered into Z_q; encryption is c = m_q + z, decryption m_q = c − z (the
RtF client side).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rounds as R
from repro.core.hera import hera_stream_key
from repro.core.params import CipherParams, get_params
from repro.core.rubato import rubato_stream_key
from repro.crypto.sampler import (
    DGaussTable,
    discrete_gaussian,
    uniform_mod_q_stream,
    words_needed_uniform_stream,
)
from repro.crypto.xof import xof_words


@dataclasses.dataclass
class Cipher:
    params: CipherParams
    key: jnp.ndarray          # (n,) uint32 in Z_q — the symmetric secret
    nonce: np.ndarray         # (16,) uint8, public

    def __post_init__(self):
        self.key = jnp.asarray(self.key, dtype=jnp.uint32)
        if self.key.shape != (self.params.n,):
            raise ValueError(f"key shape {self.key.shape} != ({self.params.n},)")
        self.nonce = np.asarray(self.nonce, dtype=np.uint8).reshape(16)
        self._gauss = (
            DGaussTable.build(self.params.sigma) if self.params.n_noise else None
        )

    # ---------------- producer (decoupled RNG) ---------------------------
    def round_constant_stream(self, block_ctrs):
        """Sample all per-block randomness.  Returns dict(rc=..., noise=...).

        rc: (lanes, n_round_constants) uint32; noise: (lanes, l) int32 or None.
        """
        p = self.params
        n_u = p.n_round_constants
        w_u = words_needed_uniform_stream(n_u)
        total = w_u + 2 * p.n_noise
        words = xof_words(p.xof, self.nonce, block_ctrs, total)
        rc = uniform_mod_q_stream(words[..., :w_u], n_u, p.mod)
        noise = None
        if p.n_noise:
            hi = words[..., w_u : w_u + p.n_noise]
            lo = words[..., w_u + p.n_noise : w_u + 2 * p.n_noise]
            noise = discrete_gaussian(hi, lo, self._gauss)
        return {"rc": rc, "noise": noise}

    # ---------------- consumer (round pipeline) --------------------------
    def keystream_from_constants(self, rc, noise=None):
        p = self.params
        if p.kind == "hera":
            rc = rc.reshape(rc.shape[:-1] + (p.n_arks, p.n))
            return hera_stream_key(p, self.key, rc)
        return rubato_stream_key(p, self.key, rc, noise)

    def keystream(self, block_ctrs, constants=None):
        """(lanes,) block counters -> (lanes, l) keystream."""
        if constants is None:
            constants = self.round_constant_stream(block_ctrs)
        return self.keystream_from_constants(constants["rc"], constants["noise"])

    def keystream_coupled(self, block_ctrs):
        """D1-style baseline: RNG serialized with rounds inside one call."""
        c = self.round_constant_stream(block_ctrs)
        # optimization_barrier pins the ordering (no overlap), mirroring the
        # software baseline that samples ALL constants before any round work.
        c = jax.lax.optimization_barrier(
            {k: v for k, v in c.items() if v is not None}
        )
        return self.keystream_from_constants(c["rc"], c.get("noise"))

    # ---------------- encryption ----------------------------------------
    def encode(self, m_real, delta: float):
        p = self.params
        mq = jnp.round(jnp.asarray(m_real, jnp.float32) * delta).astype(jnp.int32)
        return p.mod.from_signed(mq)

    def decode(self, m_q, delta: float):
        return self.params.mod.to_signed(m_q).astype(jnp.float32) / delta

    def encrypt(self, m_real, block_ctrs, delta: float = 1024.0, constants=None):
        """Encrypt (lanes, l) real messages -> (lanes, l) uint32 ciphertext."""
        z = self.keystream(block_ctrs, constants)
        return self.params.mod.add(self.encode(m_real, delta), z)

    def decrypt(self, c, block_ctrs, delta: float = 1024.0, constants=None):
        z = self.keystream(block_ctrs, constants)
        return self.decode(self.params.mod.sub(c, z), delta)


def make_cipher(name: str, key=None, nonce=None, seed: int = 0) -> Cipher:
    """Convenience constructor; random key/nonce from ``seed`` if omitted."""
    p = get_params(name)
    rng = np.random.default_rng(seed)
    if key is None:
        key = rng.integers(1, p.mod.q, size=(p.n,), dtype=np.uint32)
    if nonce is None:
        nonce = rng.integers(0, 256, size=(16,), dtype=np.uint8)
    return Cipher(p, jnp.asarray(key, jnp.uint32), nonce)
