"""Unified client-side cipher API: keystream / encrypt / decrypt.

Producer/consumer split (the paper's T3, "RNG decoupling"):

  * :meth:`Cipher.round_constant_stream` — the *producer*: XOF + rejection
    sampling + Gaussian sampling.  Depends only on (nonce, block counters),
    NOT on the key or message, so it can be dispatched concurrently with
    the previous batch's compute (async dispatch on TPU) or precomputed.
    Producers are pluggable :mod:`repro.core.producer` backends (the
    registry mirroring the consumer side); a Cipher binds the preset's
    declared XOF stream by default.
  * :meth:`Cipher.keystream` — the *consumer*: the round pipeline, taking
    the constants as an explicit input.  Consumers are pluggable
    :mod:`repro.core.engine` backends; a Cipher binds the eager ``ref``
    engine by default (the oracle all other engines must match).
  * :meth:`Cipher.keystream_coupled` — paper's D1-style baseline: a single
    computation that serializes XOF → sampling → rounds (for benchmarks).

Multi-stream farm (the T3 split lifted from kernel to system level):

  * :class:`StreamSession` — one client stream: a public nonce plus a
    block-counter cursor that hands out disjoint counter windows.
  * :class:`CipherBatch` — one symmetric key, a pool of sessions.  Its
    producer/consumer pair takes *per-lane* (session, counter) pairs, so a
    single jit'd call serves lanes drawn from arbitrarily many concurrent
    sessions — bit-exact with each session's own single-stream `Cipher`.
    `core/farm.py` double-buffers these producers against the fused Pallas
    consumer; `serve/hhe_loop.py` packs request traffic into its windows.

Message encoding: real vectors are fixed-point encoded, m_q = round(m·Δ)
centered into Z_q; encryption is c = m_q + z, decryption m_q = c − z (the
RtF client side).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineSpec, make_engine
from repro.core.params import CipherParams, get_params
from repro.core.producer import (
    ConstantsProducer,
    ProducerSpec,
    SessionMaterial,
    make_producer,
)


def encode_fixed(mod, m_real, delta: float):
    """Fixed-point encode: m_q = round(m·Δ) centered into Z_q.

    THE encoding convention — every encrypt path (Cipher, CipherBatch,
    farm streams, serve loop) must go through this pair so bit-exactness
    holds across them.
    """
    mq = jnp.round(jnp.asarray(m_real, jnp.float32) * delta).astype(jnp.int32)
    return mod.from_signed(mq)


def decode_fixed(mod, m_q, delta: float):
    """Inverse of :func:`encode_fixed`."""
    return mod.to_signed(m_q).astype(jnp.float32) / delta


@dataclasses.dataclass
class Cipher:
    params: CipherParams
    key: jnp.ndarray          # (n,) uint32 in Z_q — the symmetric secret
    nonce: np.ndarray         # (16,) uint8, public
    engine: EngineSpec = "ref"   # consumer backend (see repro.core.engine)
    producer: ProducerSpec = None  # RNG backend (None = params.xof; see
                                   # repro.core.producer)

    def __post_init__(self):
        self.key = jnp.asarray(self.key, dtype=jnp.uint32)
        if self.key.shape != (self.params.n,):
            raise ValueError(f"key shape {self.key.shape} != ({self.params.n},)")
        self.nonce = np.asarray(self.nonce, dtype=np.uint8).reshape(16)
        # the producer half of T3: a registered ConstantsProducer bound to
        # params (None = the preset's declared XOF stream, statically)
        self._producer = make_producer(self.producer, self.params)
        # the single-stream default is the eager reference engine — the
        # oracle everything else (farm engines, kernels) is checked against
        self._engine = make_engine(self.engine, self.params, self.key)

    # ---------------- producer (decoupled RNG) ---------------------------
    def round_constant_stream(self, block_ctrs):
        """Sample all per-block randomness.  Returns dict(rc=..., noise=...).

        rc: (lanes, n_round_constants) uint32; noise: (lanes, l) int32 or None.
        """
        return self._producer.constants_for_nonce(self.nonce, block_ctrs)

    # ---------------- consumer (round pipeline) --------------------------
    def keystream_from_constants(self, rc, noise=None, mats=None):
        return self._engine.keystream_from_constants(rc, noise, mats)

    def keystream(self, block_ctrs, constants=None):
        """(lanes,) block counters -> (lanes, l) keystream."""
        if constants is None:
            constants = self.round_constant_stream(block_ctrs)
        return self.keystream_from_constants(
            constants["rc"], constants["noise"], constants.get("mats")
        )

    def keystream_coupled(self, block_ctrs):
        """D1-style baseline: RNG serialized with rounds inside one call."""
        c = self.round_constant_stream(block_ctrs)
        # optimization_barrier pins the ordering (no overlap), mirroring the
        # software baseline that samples ALL constants before any round work.
        c = jax.lax.optimization_barrier(
            {k: v for k, v in c.items() if v is not None}
        )
        return self.keystream_from_constants(c["rc"], c.get("noise"),
                                             c.get("mats"))

    # ---------------- encryption ----------------------------------------
    def encode(self, m_real, delta: float):
        return encode_fixed(self.params.mod, m_real, delta)

    def decode(self, m_q, delta: float):
        return decode_fixed(self.params.mod, m_q, delta)

    def encrypt(self, m_real, block_ctrs, delta: float = 1024.0, constants=None):
        """Encrypt (lanes, l) real messages -> (lanes, l) uint32 ciphertext."""
        z = self.keystream(block_ctrs, constants)
        return self.params.mod.add(self.encode(m_real, delta), z)

    def decrypt(self, c, block_ctrs, delta: float = 1024.0, constants=None):
        z = self.keystream(block_ctrs, constants)
        return self.decode(self.params.mod.sub(c, z), delta)


def make_cipher(name: str, key=None, nonce=None, seed: int = 0,
                engine: EngineSpec = "ref",
                producer: ProducerSpec = None) -> Cipher:
    """Convenience constructor; random key/nonce from ``seed`` if omitted."""
    p = get_params(name)
    rng = np.random.default_rng(seed)
    if key is None:
        key = rng.integers(1, p.mod.q, size=(p.n,), dtype=np.uint32)
    if nonce is None:
        nonce = rng.integers(0, 256, size=(16,), dtype=np.uint8)
    return Cipher(p, jnp.asarray(key, jnp.uint32), nonce, engine, producer)


# ==========================================================================
# Multi-stream farm: one key, many (nonce, counter-window) sessions
# ==========================================================================
#: Block counters per session.  The AES XOF gives each cipher-block counter
#: a 2^16-block subspace of a 32-bit AES counter field (crypto/xof.py), so
#: counters >= 2^16 alias earlier XOF streams — a two-time pad.  A session
#: is therefore capped at 2^16 blocks (~4M Z_q elements for Rubato-128L);
#: clients needing more open a fresh session (new nonce).
SESSION_CTR_LIMIT = 1 << 16


@dataclasses.dataclass
class StreamSession:
    """One client stream: public nonce + a block-counter window cursor.

    Sessions never share (nonce, counter) pairs: `take_window` hands out
    consecutive disjoint counter ranges, so keystream reuse cannot happen
    within a session, and distinct nonces keep sessions independent.
    Exhausting the counter space (SESSION_CTR_LIMIT) raises instead of
    silently wrapping into keystream reuse — long-lived streams rotate to
    a fresh nonce via :meth:`CipherBatch.rotate_session` (``generation``
    counts rotations).
    """

    index: int
    nonce: np.ndarray          # (16,) uint8, public
    next_ctr: int = 0
    generation: int = 0        # bumped by CipherBatch.rotate_session

    def __post_init__(self):
        self.nonce = np.asarray(self.nonce, dtype=np.uint8).reshape(16)

    def remaining(self) -> int:
        """Counters left before this (nonce, generation) is exhausted."""
        return SESSION_CTR_LIMIT - self.next_ctr

    def take_window(self, n_blocks: int) -> np.ndarray:
        """Reserve the next ``n_blocks`` counters; advances the cursor."""
        if self.next_ctr + n_blocks > SESSION_CTR_LIMIT:
            raise RuntimeError(
                f"session {self.index} counter space exhausted "
                f"({self.next_ctr} + {n_blocks} > {SESSION_CTR_LIMIT}); "
                "rotate_session (fresh nonce) instead of reusing keystream"
            )
        ctrs = np.arange(
            self.next_ctr, self.next_ctr + n_blocks, dtype=np.uint32
        )
        self.next_ctr += n_blocks
        return ctrs


class CipherBatch:
    """Session-batched cipher: one symmetric key, a pool of stream sessions.

    Every producer/consumer method takes parallel per-lane arrays
    ``(session_ids, block_ctrs)`` — lanes may mix sessions and counters
    arbitrarily, so one jit'd dispatch serves traffic from any number of
    concurrent clients.  Bit-exact with the single-stream :class:`Cipher`
    of each session (see :meth:`session_cipher`); the cross-check is
    tests/test_farm.py.

    Per-session XOF material (expanded AES round keys / threefry roots) is
    precompiled host-side at `add_session` time and gathered per lane on
    device, so adding sessions never retriggers tracing.

    The producer is a pluggable :mod:`repro.core.producer` backend
    (``producer=``: a registered name, an instance, "auto" = the tuner's
    measured plan, or None = the preset's declared XOF stream) —
    symmetric to the pluggable consumer engines.
    """

    def __init__(self, params: CipherParams | str, key=None, seed: int = 0,
                 engine: EngineSpec = "ref", producer: ProducerSpec = None):
        if isinstance(params, str):
            params = get_params(params)
        self.params = params
        rng = np.random.default_rng(seed)
        if key is None:
            key = rng.integers(1, params.mod.q, size=(params.n,),
                               dtype=np.uint32)
        self.key = jnp.asarray(key, jnp.uint32)
        if self.key.shape != (params.n,):
            raise ValueError(f"key shape {self.key.shape} != ({params.n},)")
        self._rng = rng
        self._engine = self.make_engine(engine)
        self.producer: ConstantsProducer = make_producer(producer, params)
        self.sessions: List[StreamSession] = []
        # host-side per-session producer material, stacked lazily
        self._mat_host: List[SessionMaterial] = []
        self._tables = None                       # device tables, lazy

    def make_engine(self, spec: EngineSpec = "auto", *, mesh=None,
                    axis: str = "data", interpret=None,
                    variant: Optional[str] = None,
                    reduction: Optional[str] = None):
        """Bind a consumer engine to this pool's (params, key).

        The farm, serving loop, and data plane all get their consumers
        here, so backend policy stays in `repro.core.engine`.  ``variant``
        picks the schedule orientation plan (core/schedule.py; "auto" =
        the backend's preferred one) and ``reduction`` the reduction-
        scheduling mode (core/redplan.py) — bit-exact either way.
        """
        return make_engine(spec, self.params, self.key, mesh=mesh,
                           axis=axis, interpret=interpret, variant=variant,
                           reduction=reduction)

    # ---------------- producer plumbing -----------------------------------
    def set_producer(self, spec: ProducerSpec) -> ConstantsProducer:
        """Swap the RNG backend in place (e.g. applying a tuned StreamPlan).

        Per-session material is rebuilt from the live nonces, so existing
        sessions keep their (nonce, counter) spaces.  Only stream-
        preserving swaps are allowed (see `repro.core.producer.
        compatible_producers`): swapping a live pool onto a different XOF
        stream would make the same (nonce, ctr) pairs yield different
        keystream — clients' earlier ciphertexts would decrypt to garbage
        with no error — so a mismatched spec raises instead.  (Choosing a
        different stream outright is a *construction-time* decision:
        ``CipherBatch(..., producer=...)``.)
        """
        prod = make_producer(spec, self.params)
        if prod.caps.stream not in (None, self.params.xof):
            raise ValueError(
                f"producer {prod.name!r} emits the {prod.caps.stream!r} "
                f"stream but this pool's preset declares "
                f"{self.params.xof!r}; swapping a live pool across streams "
                "would silently change every keystream — construct a new "
                "CipherBatch for a different stream"
            )
        self.producer = prod
        self._mat_host = [
            self.producer.session_material(s.nonce) for s in self.sessions
        ]
        self._tables = None
        return self.producer

    # ---------------- session pool ---------------------------------------
    def add_session(self, nonce=None) -> StreamSession:
        if nonce is None:
            nonce = self._rng.integers(0, 256, size=(16,), dtype=np.uint8)
        s = StreamSession(index=len(self.sessions), nonce=nonce)
        self.sessions.append(s)
        self._mat_host.append(self.producer.session_material(s.nonce))
        self._tables = None
        return s

    def add_sessions(self, count: int) -> List[StreamSession]:
        return [self.add_session() for _ in range(count)]

    def rotate_session(self, session_id: int, nonce=None) -> StreamSession:
        """Retire a session's (nonce, counter) space: fresh nonce, cursor 0.

        The replacement keeps the session's index (lane ids stay stable for
        long-lived clients) and bumps ``generation``; its XOF table row is
        rebuilt in place, so table *shapes* are unchanged and no producer
        retrace happens.  Any keystream still pending against the old nonce
        must be materialized before rotating (serve/hhe_loop.py flushes its
        queue first) — after rotation the pool can no longer regenerate the
        old stream.
        """
        old = self.sessions[session_id]
        if nonce is None:
            nonce = self._rng.integers(0, 256, size=(16,), dtype=np.uint8)
        s = StreamSession(index=session_id, nonce=nonce,
                          generation=old.generation + 1)
        self.sessions[session_id] = s
        self._mat_host[session_id] = self.producer.session_material(s.nonce)
        self._tables = None
        return s

    def __len__(self) -> int:
        return len(self.sessions)

    def session_cipher(self, session_id: int) -> Cipher:
        """Single-stream view of one session (the bit-exactness oracle)."""
        return Cipher(self.params, self.key, self.sessions[session_id].nonce,
                      producer=self.producer.name)

    def xof_tables(self):
        """Device-side per-session producer material, rebuilt lazily on
        growth/rotation (the producer's `stack_tables` over the pool)."""
        if self._tables is None:
            self._tables = self.producer.stack_tables(self._mat_host)
        return self._tables

    # ---------------- producer (decoupled, multi-stream) ------------------
    def round_constant_stream(self, session_ids, block_ctrs):
        """Per-lane randomness for lanes drawn from many sessions.

        session_ids/block_ctrs: (lanes,) int arrays (parallel).  Returns
        dict(rc=(lanes, n_round_constants) u32, noise=(lanes, l) i32|None).
        """
        return self.producer.produce(
            self.xof_tables(), session_ids, block_ctrs
        )

    # ---------------- consumer (shared key, round pipeline) ---------------
    def keystream_from_constants(self, rc, noise=None, mats=None):
        return self._engine.keystream_from_constants(rc, noise, mats)

    def keystream(self, session_ids, block_ctrs, constants=None):
        """(lanes,) (session, ctr) pairs -> (lanes, l) keystream."""
        if constants is None:
            constants = self.round_constant_stream(session_ids, block_ctrs)
        return self.keystream_from_constants(
            constants["rc"], constants["noise"], constants.get("mats")
        )

    # ---------------- streaming encrypt / decrypt -------------------------
    def encrypt(self, m_real, session_ids, block_ctrs, delta: float = 1024.0,
                constants=None):
        z = self.keystream(session_ids, block_ctrs, constants)
        mod = self.params.mod
        return mod.add(encode_fixed(mod, m_real, delta), z)

    def decrypt(self, c, session_ids, block_ctrs, delta: float = 1024.0,
                constants=None):
        z = self.keystream(session_ids, block_ctrs, constants)
        mod = self.params.mod
        return decode_fixed(mod, mod.sub(c, z), delta)
