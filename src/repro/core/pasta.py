"""PASTA stream-key generation (Dobraunig et al., the third HHE cipher).

    PASTA(k) = Tr_t ∘ A_r ∘ S_{r-1} ∘ A_{r-1} ∘ ... ∘ S_0 ∘ A_0   applied to k
    A_i = branch-mix ∘ (+rc_i) ∘ per-branch matrix      (the affine layer)
    S_i = Feistel for i < r-1, Cube for the final round

The key IS the initial state (two t-element branches, n = 2t) and all
per-block randomness enters through the affine layers — the decoupled-RNG
input: (r+1)·n additive constants plus (r+1)·n·t dense matrix words per
block, both squeezed from the same XOF stream.  The round structure is
*data*: `core/schedule.py` emits it once (`build_schedule`, with
``init="key"``, ``branches=2``, and the rc- and mat-annotated
stream-matrix `MRMC` affine op) and this module is a thin wrapper over
the pure-JAX interpreter `execute_schedule` — the same program the fused
Pallas kernel runs.  Deviations vs the published cipher (uniform dense
matrices without the invertibility construction; t restricted to perfect
squares) are documented in docs/DESIGN.md §8.7.
"""

from __future__ import annotations

from repro.core.params import CipherParams
from repro.core.schedule import build_schedule, execute_schedule


def pasta_stream_key(params: CipherParams, key, rc, mats=None,
                     variant: str = "normal"):
    """Generate keystream blocks.

    key: (..., n) uint32 in Z_q — the two-branch state the permutation is
         applied to (n = 2t).
    rc:  (..., (r+1)·n) flat uint32 affine constants (decoupled-RNG input).
    mats: (..., (r+1)·n·t) flat uint32 dense matrix planes — the per-block
          random affine matrices the schedule streams (docs/DESIGN.md §8.7).
    Returns (..., l) uint32 keystream block (l = t, the first branch).
    """
    if rc.shape[-1] != params.n_round_constants:
        raise ValueError(
            f"rc last dim {rc.shape[-1]} != {params.n_round_constants}"
        )
    sched = build_schedule(params, variant)
    return execute_schedule(params, sched, key, rc, mats=mats)
