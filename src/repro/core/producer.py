"""Pluggable constants-producer registry: the producer half of T3.

The paper's central architectural move (T3) decouples the RNG phase — XOF,
rejection sampling, Gaussian sampling, round-constant assembly — from the
key computation so the two pipeline halves can be engineered and tuned
independently.  `core/engine.py` gave the *consumer* half a first-class
registry; this module is its mirror for the *producer* half.  Every way to
turn (session material, block counters) into the constants dict the
engines consume is a registered :class:`ConstantsProducer` with declared
capabilities, and all producer policy ("auto" selection, availability
checks, stream compatibility) lives here and nowhere else.

Registered producers (see `registered_producers()` / `producer_caps()`):

  * ``aes``      — AES-128-CTR XOF (paper §IV-D conformance; the stream the
                   spec defines).  Per-session material: expanded round keys.
  * ``threefry`` — JAX's counter-based threefry2x32 PRF (TPU-native fast
                   path: add/xor/rotate only).  A *different* stream.
  * ``cached``   — memoizing wrapper over the stream-matching producer:
                   repeated (session nonce, counter-window) requests return
                   the memoized constants plane instead of re-running the
                   XOF — the re-keying traffic shape, where the same window
                   is regenerated for retries / replays.  Bit-exact with
                   its inner producer by construction.

Stream identity: ``ProducerCaps.stream`` names the XOF stream a producer
emits ("aes" / "threefry"); ``None`` means it follows ``params.xof``
(the ``cached`` wrapper).  Producers whose stream matches ``params.xof``
are interchangeable without changing a single keystream bit — that is the
set the :mod:`repro.core.tuner` selects among, so a tuned `StreamPlan`
can never silently change the cipher a client decrypts against.

Usage:

    prod = make_producer("auto", params)        # policy decided HERE
    mat = prod.session_material(nonce)          # host-side, once/session
    tables = prod.stack_tables([mat, ...])      # device tables
    consts = prod.produce(tables, session_ids, block_ctrs)

`core/cipher.py` binds a producer per Cipher/CipherBatch,
`core/farm.py` pipelines `produce` against its consumer engine, and
`python -m repro.core.producer` prints the registry table.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, NamedTuple, Optional, Tuple, Type, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import CipherParams
from repro.crypto.aes import aes128_key_expand
from repro.crypto.sampler import (
    DGaussTable,
    discrete_gaussian,
    uniform_mod_q_stream,
    words_needed_uniform_stream,
)
from repro.crypto.xof import (
    aes_xof_words_batched,
    threefry_root_key,
    threefry_xof_words_batched,
)


#: Constants-plane kinds a producer can materialize independently.  The
#: "vector" plane is the classic rc+noise payload; the "matrix" plane is
#: the dense affine matrices a stream-sourced MRMC schedule consumes
#: (PASTA).  "all" materializes both in one XOF pass.
PLANES = ("all", "vector", "matrix")


def constants_from_words(params: CipherParams, words,
                         gauss: Optional[DGaussTable], plane: str = "all"):
    """Shared producer tail: XOF words -> dict(rc=..., noise=..., mats=...).

    words: (..., total) uint32 where total covers at least the planes
    requested (see `ConstantsProducer.plane_words`).  The word layout is
    fixed: rc words first, then noise hi/lo, then matrix-plane words —
    matrix planes draw strictly AFTER the vector plane from the same
    stream, so presets without matrix constants are byte-identical to the
    pre-matrix layout.  Every producer backend funnels through this one
    function, so producers emitting the same word stream are bit-exact by
    construction.
    """
    if plane not in PLANES:
        raise ValueError(f"unknown constants plane {plane!r}; have {PLANES}")
    p = params
    n_u = p.n_round_constants
    w_u = words_needed_uniform_stream(n_u)
    out: Dict[str, Any] = {}
    if plane in ("all", "vector"):
        out["rc"] = uniform_mod_q_stream(words[..., :w_u], n_u, p.mod)
        noise = None
        if p.n_noise:
            hi = words[..., w_u : w_u + p.n_noise]
            lo = words[..., w_u + p.n_noise : w_u + 2 * p.n_noise]
            noise = discrete_gaussian(hi, lo, gauss)
        out["noise"] = noise
    if plane in ("all", "matrix"):
        mats = None
        if p.n_matrix_constants:
            base = w_u + 2 * p.n_noise
            n_m = p.n_matrix_constants
            w_m = words_needed_uniform_stream(n_m)
            mats = uniform_mod_q_stream(words[..., base : base + w_m],
                                        n_m, p.mod)
        out["mats"] = mats
    return out


class SessionMaterial(NamedTuple):
    """Host-side per-session producer material.

    ``nonce`` is the raw 16-byte public nonce — the cache identity a
    memoizing producer keys on; ``payload`` is backend-specific precompiled
    material (expanded AES round keys, threefry root key, ...).
    """

    nonce: bytes
    payload: Any


class ProducerTables(NamedTuple):
    """Stacked session tables: the device pytree the jit'd producer fn
    gathers from, plus the per-session nonce identities it was stacked
    from.  Carrying the nonces ON the tables (rather than as producer
    instance state) means a memoizing producer keys its cache on exactly
    the tables a `produce` call uses — a producer instance shared between
    two pools (or a pool and a single-stream Cipher) can never mix up
    whose nonce owns a cached plane."""

    device: Any               # backend-specific device arrays
    nonces: Tuple[bytes, ...]  # parallel to the session axis of ``device``


@dataclasses.dataclass(frozen=True)
class ProducerCaps:
    """What one producer backend can do, queried without instantiating it.

    ``stream`` names the XOF stream the backend emits ("aes"/"threefry");
    ``None`` means it follows ``params.xof`` (wrappers).  Producers with
    the same effective stream are interchangeable bit-for-bit — the set a
    tuned `StreamPlan` may select among.  ``memoizes`` marks backends that
    reuse materialized constants for repeated windows.
    """

    name: str
    description: str
    available: bool
    reason: str = ""
    stream: Optional[str] = None
    memoizes: bool = False
    jitted: bool = True


class ConstantsProducer:
    """One way to materialize round constants (+ noise) from counters.

    Subclasses implement `session_material` / `stack_tables` /
    `producer_fn`; the base class owns the jit plumbing and the
    single-stream convenience path so every backend honors the same
    contract.  Producers are bound to ``params`` at construction (they own
    the Gaussian table and the word budget); the key never enters — that
    is the whole point of T3.
    """

    name: str = "?"

    def __init__(self, params: CipherParams):
        self.params = params
        self._gauss = (
            DGaussTable.build(params.sigma) if params.n_noise else None
        )
        #: uint32 XOF words the vector plane (constants + noise) consumes
        self.vector_words = (
            words_needed_uniform_stream(params.n_round_constants)
            + 2 * params.n_noise
        )
        #: uint32 XOF words one lane consumes in total (+ matrix planes)
        self.total_words = params.xof_words_per_block()
        self.caps = type(self).query_caps()
        self._jit: Dict[str, Any] = {}

    # -- capability reporting (class-level: no instance needed) ------------
    @classmethod
    def query_caps(cls) -> ProducerCaps:
        raise NotImplementedError

    # -- backend surface ---------------------------------------------------
    def session_material(self, nonce) -> SessionMaterial:
        """Precompile one session's nonce material (host-side, once)."""
        raise NotImplementedError

    def _stack_payloads(self, materials: List[SessionMaterial]):
        """Stack per-session payloads into the device gather pytree."""
        raise NotImplementedError

    def stack_tables(self, materials: List[SessionMaterial]) -> ProducerTables:
        """Stack per-session materials into gather tables (+ identities)."""
        return ProducerTables(
            self._stack_payloads(materials),
            tuple(m.nonce for m in materials),
        )

    def producer_fn(self, plane: str = "all"):
        """Pure ``fn(device_tables, session_ids, block_ctrs) -> constants``.

        Tables are runtime args (not baked constants) so one jit stays
        valid — and retraces only on shape change — as a session pool
        grows.  The closure depends only on (params, gauss, plane), all
        fixed.  ``plane`` selects which constants plane to materialize
        ("all" / "vector" / "matrix") — the farm's matrix prefetch uses
        "matrix"-only dispatch so the heavy plane runs ahead of the
        consumer pipeline.
        """
        raise NotImplementedError

    def plane_words(self, plane: str = "all") -> int:
        """XOF words one lane draws to materialize ``plane``.

        The matrix plane sits after the vector plane in the stream, so a
        matrix-only pass still draws (and discards) the vector-plane
        prefix — a few percent of its own budget, the price of keeping one
        stream identity per (nonce, ctr)."""
        if plane == "vector" or not self.params.n_matrix_constants:
            return self.vector_words
        return self.total_words

    # -- the producer ------------------------------------------------------
    def jitted(self, plane: str = "all"):
        """The jit'd producer fn for one plane (built once per instance)."""
        if plane not in self._jit:
            self._jit[plane] = jax.jit(self.producer_fn(plane))
        return self._jit[plane]

    def produce(self, tables: ProducerTables, session_ids, block_ctrs,
                plane: str = "all"):
        """Materialize constants for per-lane (session, counter) pairs.

        tables: a `stack_tables` result; session_ids: (lanes,) int;
        block_ctrs: (lanes,) uint32.  Returns dict(rc=(lanes,
        n_round_constants) u32, noise=(lanes, l) i32|None, mats=(lanes,
        n_matrix_constants) u32|None), filtered to the requested plane.
        """
        return self.jitted(plane)(tables.device, session_ids, block_ctrs)

    def constants_for_nonce(self, nonce, block_ctrs):
        """Single-stream path: one nonce, a vector of counters (Cipher)."""
        tables = self.stack_tables([self.session_material(nonce)])
        ctrs = jnp.asarray(block_ctrs, jnp.uint32)
        return self.produce(tables, jnp.zeros(ctrs.shape, jnp.int32), ctrs)

    def __repr__(self):
        return f"<ConstantsProducer {self.name} params={self.params.name}>"


# ==========================================================================
# Registry
# ==========================================================================
_REGISTRY: Dict[str, Type[ConstantsProducer]] = {}


def register_producer(cls: Type[ConstantsProducer]) -> Type[ConstantsProducer]:
    """Class decorator: add a producer to the registry under ``cls.name``."""
    if cls.name in _REGISTRY:
        raise ValueError(f"producer {cls.name!r} already registered")
    _REGISTRY[cls.name] = cls
    return cls


def registered_producers() -> Tuple[str, ...]:
    """Names of all registered producers (available or not), sorted."""
    return tuple(sorted(_REGISTRY))


def producer_caps() -> Dict[str, ProducerCaps]:
    """Capability report for every registered producer."""
    return {name: cls.query_caps() for name, cls in sorted(_REGISTRY.items())}


def compatible_producers(params: CipherParams) -> Tuple[str, ...]:
    """Producers whose stream matches ``params.xof`` — interchangeable
    without changing a single keystream bit (the tuner's candidate set)."""
    return tuple(
        name for name, c in producer_caps().items()
        if c.available and c.stream in (None, params.xof)
    )


def _tuned_producer(params: Optional[CipherParams]) -> Optional[str]:
    """Consult the StreamPlan cache for a measured producer choice.

    Lazy import (the tuner sits above this module); returns None — never
    raises — when there is no cache, no plan for this (preset, host), or
    the cached producer is no longer registered / stream-compatible."""
    if params is None:
        return None
    try:
        from repro.core.tuner import load_plan

        plan = load_plan(params, lanes=None)
    except Exception:
        return None
    if plan is None or plan.producer not in _REGISTRY:
        return None
    caps = _REGISTRY[plan.producer].query_caps()
    if not caps.available or caps.stream not in (None, params.xof):
        return None
    return plan.producer


def resolve_producer(spec: Optional[str],
                     params: Optional[CipherParams] = None) -> str:
    """THE single place producer selection lives.

    ``spec`` is a producer name, None (= the preset's declared XOF,
    static), or "auto" (= the measured `StreamPlan` from the tuner cache
    when one exists for this (preset, host), else the static preference —
    the tuner consultation the ROADMAP named).  Unknown names raise
    ValueError listing the registered producers.
    """
    if spec == "auto":
        spec = _tuned_producer(params)
    if spec is None:
        spec = params.xof if params is not None else "aes"
    if spec not in _REGISTRY:
        raise ValueError(
            f"unknown constants producer {spec!r}; registered producers: "
            f"{list(registered_producers())} (plus 'auto'; run "
            "`python -m repro.core.producer` for the table)"
        )
    return spec


ProducerSpec = Union[str, ConstantsProducer, None]


def make_producer(spec: ProducerSpec, params: CipherParams,
                  **kwargs) -> ConstantsProducer:
    """Resolve ``spec`` and bind it to ``params``.

    ``spec`` may already be a ConstantsProducer instance (passed through —
    the pluggable-producer path), but only if it is bound to the SAME
    params: a producer sampling for different (q, constant-count) would
    emit constants no engine of this pool can consume correctly.  Raises
    RuntimeError when the resolved producer is unavailable, with the
    backend's own reason.
    """
    if isinstance(spec, ConstantsProducer):
        if spec.params != params:
            raise ValueError(
                f"producer {spec.name!r} is bound to different params "
                f"(producer has {spec.params.name}); rebind it with "
                "make_producer for this pool"
            )
        return spec
    name = resolve_producer(spec, params)
    cls = _REGISTRY[name]
    caps = cls.query_caps()
    if not caps.available:
        raise RuntimeError(
            f"constants producer {name!r} unavailable here: {caps.reason} "
            "(run `python -m repro.core.producer` for the registry table)"
        )
    return cls(params, **kwargs)


# ==========================================================================
# Backends
# ==========================================================================
@register_producer
class AesProducer(ConstantsProducer):
    """AES-128-CTR XOF — the paper's §IV-D conformance stream."""

    name = "aes"

    @classmethod
    def query_caps(cls) -> ProducerCaps:
        return ProducerCaps(
            name=cls.name,
            description="AES-128-CTR XOF (paper conformance stream)",
            available=True,
            stream="aes",
        )

    def session_material(self, nonce) -> SessionMaterial:
        nonce = np.asarray(nonce, dtype=np.uint8).reshape(16)
        return SessionMaterial(
            nonce.tobytes(),
            (aes128_key_expand(nonce), nonce[:12].copy()),
        )

    def _stack_payloads(self, materials):
        rk = jnp.asarray(np.stack([m.payload[0] for m in materials]))
        n12 = jnp.asarray(np.stack([m.payload[1] for m in materials]))
        return (rk, n12)                                   # (S,11,16),(S,12)

    def producer_fn(self, plane: str = "all"):
        p, gauss = self.params, self._gauss
        total = self.plane_words(plane)

        def producer(tables, session_ids, block_ctrs):
            rk, n12 = tables
            sid = jnp.asarray(session_ids, jnp.int32)
            ctrs = jnp.asarray(block_ctrs, jnp.uint32)
            words = aes_xof_words_batched(rk[sid], n12[sid], ctrs, total)
            return constants_from_words(p, words, gauss, plane)

        return producer


@register_producer
class ThreefryProducer(ConstantsProducer):
    """Counter-based threefry2x32 PRF — the TPU-native fast stream."""

    name = "threefry"

    @classmethod
    def query_caps(cls) -> ProducerCaps:
        return ProducerCaps(
            name=cls.name,
            description="threefry2x32 counter PRF (TPU-native fast stream)",
            available=True,
            stream="threefry",
        )

    def session_material(self, nonce) -> SessionMaterial:
        nonce = np.asarray(nonce, dtype=np.uint8).reshape(16)
        return SessionMaterial(nonce.tobytes(), threefry_root_key(nonce))

    def _stack_payloads(self, materials):
        return (jnp.stack([m.payload for m in materials]),)   # (S,) keys

    def producer_fn(self, plane: str = "all"):
        p, gauss = self.params, self._gauss
        total = self.plane_words(plane)

        def producer(tables, session_ids, block_ctrs):
            (roots,) = tables
            sid = jnp.asarray(session_ids, jnp.int32)
            ctrs = jnp.asarray(block_ctrs, jnp.uint32)
            words = threefry_xof_words_batched(roots[sid], ctrs, total)
            return constants_from_words(p, words, gauss, plane)

        return producer


@register_producer
class CachedProducer(ConstantsProducer):
    """Memoizing wrapper over the stream-matching producer.

    Repeated (session nonce, counter-window) requests — the re-keying
    traffic shape, where the same window is regenerated for retries,
    replays, or decrypt-after-encrypt round trips — return the memoized
    constants plane instead of re-running the XOF.  Keys are the raw
    per-lane nonce bytes (read from the `ProducerTables` each `produce`
    call actually uses, never from instance state) plus the counter
    vector plus the plane kind (vector vs matrix), so a session
    *rotation* (fresh nonce) can never serve a stale plane and a shared
    cache can never hand a vector plane to a matrix-plane request;
    entries are LRU-evicted at ``max_entries`` windows.  Bit-exact
    with the inner producer by construction (a hit returns what the inner
    producer materialized).  Under a jax trace (e.g. inside
    `keystream_coupled`) the cache is bypassed — tracers have no host
    identity to key on.
    """

    name = "cached"
    MAX_ENTRIES = 64

    def __init__(self, params: CipherParams, *, inner: Optional[str] = None,
                 max_entries: Optional[int] = None):
        super().__init__(params)
        inner = inner if inner is not None else params.xof
        if inner == self.name:
            raise ValueError("cached producer cannot wrap itself")
        self.inner = make_producer(inner, params)
        self.max_entries = max_entries or self.MAX_ENTRIES
        self._cache: "OrderedDict[tuple, dict]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    @classmethod
    def query_caps(cls) -> ProducerCaps:
        return ProducerCaps(
            name=cls.name,
            description="memoizes RC planes for repeated (session, ctr) "
                        "windows over the stream-matching producer",
            available=True,
            stream=None,          # follows params.xof (the inner stream)
            memoizes=True,
        )

    # material/tables delegate to the inner backend; the nonce identities
    # the cache keys on ride on the ProducerTables themselves
    def session_material(self, nonce) -> SessionMaterial:
        return self.inner.session_material(nonce)

    def _stack_payloads(self, materials):
        return self.inner._stack_payloads(materials)

    def producer_fn(self, plane: str = "all"):
        return self.inner.producer_fn(plane)

    @staticmethod
    def _key(tables: ProducerTables, session_ids, block_ctrs,
             plane: str = "all"):
        # Plane kind is part of the identity: a shared cache must never
        # serve a vector plane where a matrix plane is expected (or vice
        # versa) for the same (nonces, ctrs) window.
        sid = np.asarray(session_ids).reshape(-1)
        ctr = np.asarray(block_ctrs, np.uint64).reshape(-1)
        try:
            nonces = b"".join(tables.nonces[int(s)] for s in sid)
        except IndexError:   # lanes beyond the stacked tables: don't cache
            return None
        return (plane, nonces, ctr.tobytes())

    def produce(self, tables, session_ids, block_ctrs, plane: str = "all"):
        if isinstance(session_ids, jax.core.Tracer) or isinstance(
                block_ctrs, jax.core.Tracer):
            return self.inner.produce(tables, session_ids, block_ctrs, plane)
        key = self._key(tables, session_ids, block_ctrs, plane)
        if key is not None and key in self._cache:
            self.hits += 1
            self._cache.move_to_end(key)
            return self._cache[key]
        out = self.inner.produce(tables, session_ids, block_ctrs, plane)
        if key is not None:
            self.misses += 1
            self._cache[key] = out
            while len(self._cache) > self.max_entries:
                self._cache.popitem(last=False)
        return out

    def cache_stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "entries": len(self._cache),
            "hit_rate": self.hits / total if total else 0.0,
        }


# ==========================================================================
# Introspection CLI: `python -m repro.core.producer`
# ==========================================================================
def describe() -> str:
    """The producer registry as a table: one row per backend, with
    availability, stream identity, and memoization."""
    caps = producer_caps()
    rows = [("producer", "available", "stream", "memoizes",
             "description / reason")]
    for name, c in caps.items():
        stream = c.stream if c.stream is not None else "(params.xof)"
        detail = c.description if c.available else f"UNAVAILABLE: {c.reason}"
        rows.append((name, "yes" if c.available else "no", stream,
                     "yes" if c.memoizes else "no", detail))
    widths = [max(len(r[i]) for r in rows) for i in range(4)]
    lines = []
    for i, r in enumerate(rows):
        lines.append("  ".join(r[j].ljust(widths[j]) for j in range(4))
                     + "  " + r[4])
        if i == 0:
            lines.append("  ".join("-" * w for w in widths) + "  " + "-" * 24)
    return "\n".join(lines)


if __name__ == "__main__":
    print(describe())
