"""Shared round-function components for HERA, Rubato, and PASTA (pure JAX).

These are the *primitives*; the round structure that composes them lives
as data in `core/schedule.py` (`build_schedule`), and the pure-JAX
interpreter `execute_schedule` — which `core/hera.py` / `core/rubato.py` /
`core/pasta.py` wrap — applies them in program order.

State convention: a keystream block's state is a (..., n) uint32 vector in
Z_q, viewed row-major as ``branches`` (..., v, v) matrices per Eq. (1) of
the paper (HERA/Rubato: one branch; PASTA: two t-element branches, each a
(v, v) matrix with t = v²).  Matrix and Feistel primitives act per branch;
`branch_mix` is PASTA's cross-branch coupling.

The MRMC module fuses MixColumns followed by MixRows:

    MRMC(X) = MixRows(MixColumns(X)) = M_v (M_v X)^T ... = M_v X^T M_v^T   (paper §IV-B)

and is transposition-invariant: MRMC(X^T) = (MRMC(X))^T (Eq. 2).  On TPU we
exploit the same algebra the FPGA design does, but the "bubble" we eliminate
is a relayout/HBM round-trip: `mrmc` computes M_v X M_v^T as two back-to-back
small matvecs with NO materialized transpose between them, and the pure-JAX
form below is exactly what the fused Pallas kernel implements blockwise.

All multiplications by M_v coefficients ({1,2,3}) use the shift-add path
(`Modulus.matvec_small`) — the paper's T4, no integer multiplier.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.params import CipherParams


def ic_vector(params: CipherParams) -> np.ndarray:
    """Initial (public) state constant: (1, 2, ..., n) mod q."""
    return (np.arange(1, params.n + 1, dtype=np.uint32) % params.mod.q).astype(
        np.uint32
    )


def ark(params: CipherParams, x, key, rc, reduce_out: bool = True):
    """Add-round-key with randomized key schedule: x + k ⊙ rc (mod q).

    x: (..., m) state; key: (..., m) or (m,); rc: (..., m) round constants.
    m may be n (normal) or l (the truncated final ARK of Rubato).
    ``reduce_out=False`` (the reduction plan's defer-out flag,
    core/redplan.py) skips the output reduce: the raw sum, bounded by
    x's bound + q, flows into the next op's lazy accumulator.
    """
    mod = params.mod
    m = mod.mul(key, rc)
    return mod.add(x, m) if reduce_out else x + m


def _branch_view(params: CipherParams, x):
    """(..., n) state -> (..., branches, v, v) row-major branch matrices."""
    return x.reshape(x.shape[:-1] + (params.branches, params.v, params.v))


def mix_columns(params: CipherParams, x):
    """Y = M_v X per branch (matrix multiply on columns), state (..., n)."""
    mod = params.mod
    X = _branch_view(params, x)
    # columns of X are X[..., :, c]; M @ X contracts the row index (axis -2)
    Y = mod.matvec_small(params.mix_matrix(), X, axis=-2)
    return Y.reshape(x.shape)


def mix_rows(params: CipherParams, x):
    """Y^T[..] rows: each row of X multiplied by M_v  => Y = X M_v^T."""
    mod = params.mod
    X = _branch_view(params, x)
    Y = mod.matvec_small(params.mix_matrix(), X, axis=-1)
    return Y.reshape(x.shape)


def mrmc(params: CipherParams, x, in_bound: int | None = None,
         lazy: bool = False):
    """Fused MixRows∘MixColumns = M_v X M_v^T per branch, no transpose
    materialized.  ``lazy=True`` (the reduction plan's lazy-accumulate
    flag) runs both shift-add passes with raw terms and one terminal
    reduce per row, accepting operands up to ``in_bound`` on the first
    pass (its output is reduced, so the second pass relaxes from q)."""
    mod = params.mod
    M = params.mix_matrix()
    X = _branch_view(params, x)
    Y = mod.matvec_small(M, X, axis=-2, in_bound=in_bound, lazy=lazy)  # M X
    Z = mod.matvec_small(M, Y, axis=-1, lazy=lazy)   # (M X) M^T
    return Z.reshape(x.shape)


def mrmc_transposed(params: CipherParams, x_t):
    """MRMC applied to a transposed (column-major) state, per branch.

    By Eq. 2, MRMC(X^T) = (MRMC(X))^T, so this equals plain :func:`mrmc`
    on the stored array — the identity that licenses the alternating-
    orientation schedule variant's transposed-state rounds
    (core/schedule.py); tests/test_schedule.py asserts it directly.
    """
    X = _branch_view(params, x_t)
    Xt = jnp.swapaxes(X, -1, -2)
    out = mrmc(params, Xt.reshape(x_t.shape))
    O = _branch_view(params, out)
    return jnp.swapaxes(O, -1, -2).reshape(x_t.shape)


def cube(params: CipherParams, x):
    """HERA nonlinearity: elementwise x^3 mod q."""
    return params.mod.cube(x)


def feistel(params: CipherParams, x, in_bound: int | None = None):
    """Rubato/PASTA nonlinearity (type-3 Feistel, parallel form):

        y_1 = x_1;  y_i = x_i + x_{i-1}^2   (original x values — not chained)

    Applied independently per branch (PASTA's chain restarts at the branch
    boundary; with one branch this is the plain Rubato layer).
    ``in_bound`` relaxes the operand contract: the square runs the
    bound-carrying limb multiply (`Modulus.mul_fits` must hold) and the
    output add reduces from in_bound + q instead of 2q.
    """
    mod = params.mod
    b = params.branches
    in_b = mod.q if in_bound is None else in_bound
    X = x.reshape(x.shape[:-1] + (b, x.shape[-1] // b))
    if in_b <= mod.q:
        sq = mod.square(X[..., :-1])
        shifted = jnp.concatenate(
            [jnp.zeros_like(X[..., :1]), sq], axis=-1
        )
        return mod.add(X, shifted).reshape(x.shape)
    sq = mod.mul(X[..., :-1], X[..., :-1], x_bound=in_b, y_bound=in_b)
    shifted = jnp.concatenate(
        [jnp.zeros_like(X[..., :1]), sq], axis=-1
    )
    return mod.reduce(X + shifted, in_b + mod.q).reshape(x.shape)


def branch_mix(params: CipherParams, x, in_bound: int | None = None,
               lazy: bool = False):
    """PASTA branch mixing: (y_L, y_R) <- (2·y_L + y_R, y_L + 2·y_R) mod q.

    Linear and elementwise across the two branches, so it is orientation-
    agnostic (the same flat-index lanes combine in either storage order).
    Computed as s = y_L + y_R; (s + y_L, s + y_R) — two adds per output.
    ``lazy=True`` (the reduction plan's fold-mix flag) folds the three
    eager reduces into ONE terminal reduce from 3·in_bound, accepting
    operands up to ``in_bound`` (e.g. the raw matrix_out + rc sum < 2q).
    """
    mod = params.mod
    t = x.shape[-1] // 2
    L, R_ = x[..., :t], x[..., t:]
    if lazy:
        in_b = mod.q if in_bound is None else in_bound
        s = L + R_                                           # < 2·in_b
        out = jnp.concatenate([s + L, s + R_], axis=-1)      # < 3·in_b
        return mod.reduce(out, 3 * in_b)
    s = mod.add(L, R_)
    return jnp.concatenate([mod.add(s, L), mod.add(s, R_)], axis=-1)


def agn(params: CipherParams, x, noise_signed):
    """Add discrete-Gaussian noise (signed int32) to (..., l) state."""
    mod = params.mod
    e = mod.from_signed(noise_signed)
    return mod.add(x, e)
