"""Proof-guided lazy modular reduction: the reduction-scheduling pass.

Presto's frequency wins come from shortening the modular-arithmetic
critical path; our software analogue of that path is the branchless
conditional-subtract reduce chain (`Modulus.reduce`), which the eager
datapath fires after *every* add/mul/matvec-chunk even where uint32
headroom makes it provably unnecessary.  This pass (docs/DESIGN.md §14)
walks `Schedule.op_table()` once, propagates worst-case magnitude bounds
across consecutive ops, and emits a per-(preset, variant)
:class:`ReductionPlan`: per-op input/output bounds plus execution flags
saying where a reduce is skipped, deferred, or weakened.

The shipped lazy policy (every deferral is feasibility-checked against
the SAME `Modulus` bound enumerators the overflow proof replays, so
"proof-guided" is literal):

  * **defer-out (ARK)** — the `x + k·rc` output reduce is skipped when the
    next op is a static MRMC whose lazy shift-add accumulator provably
    absorbs < 2q operands (`Modulus.accumulate_sites(lazy=True)` all fit);
  * **lazy-accumulate (static MRMC)** — shift-add terms stay raw (no
    per-term reduce, relaxed input bound) and each row fires ONE terminal
    reduce (`Modulus.matvec_small(lazy=True)`);
  * **lazy-dense (stream MRMC)** — the dense matvec's t² per-product
    final reduces are deferred (`mul(reduce_out=False)`, products < 3q)
    with the chunk width recomputed by `dense_chunk_schedule(t, 3q)`
    (`Modulus.matvec_dense(lazy=True)`) — the dominant PASTA win;
  * **fold-mix (affine MRMC)** — the additive-constant add and PASTA's
    branch mix `(s+L, s+R)` run raw, folding three eager reduces into one
    terminal reduce from 3·(matrix_out + rc) — requires `mix_branches`.

NONLINEAR and every op feeding TRUNCATE/AGN/program-end emit fully
reduced state — the **terminal-reduction law** (lint rule SA111), which
:meth:`ReductionPlan.validate` enforces and `analysis/bounds.py`
discharges as an obligation per terminal site.  Bit-exactness is free:
every reduce chain lands on the canonical residue in [0, q) regardless
of where it fires, so lazy ≡ eager on every program (the golden digests
do not move).

Interpreters honoring the plan: the pure-JAX `execute_schedule`
(core/schedule.py), the fused Pallas keystream kernel
(kernels/keystream/keystream.py), and the bound-carrying mrmc/matvec
variants they share (kernels/mrmc/mrmc.py, crypto/modmath.py).  The
depth-tracked FV transcipher interprets ciphertexts, not uint32 state,
so reduction scheduling does not apply there.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

from repro.core import schedule as S

#: the two reduction-scheduling modes every engine/tuner knob accepts
REDUCTION_MODES = ("eager", "lazy")
DEFAULT_REDUCTION = "lazy"

#: per-op execution-choice flags (see module docstring)
DEFER_OUT = "defer-out"
LAZY_ACCUMULATE = "lazy-accumulate"
LAZY_DENSE = "lazy-dense"
FOLD_MIX = "fold-mix"


@dataclasses.dataclass(frozen=True)
class OpPlan:
    """Reduction schedule for one op: exclusive worst-case value bounds on
    its input/output state plus the execution flags the interpreters
    honor.  Bounds are multiples of q as plain ints (q = fully reduced)."""

    index: int
    in_bound: int
    out_bound: int
    flags: Tuple[str, ...] = ()

    def has(self, flag: str) -> bool:
        return flag in self.flags


@dataclasses.dataclass(frozen=True)
class ReductionPlan:
    """A complete per-program reduction schedule (one OpPlan per op)."""

    schedule: str          # Schedule.name the plan was derived for
    mode: str              # "eager" | "lazy"
    q: int
    ops: Tuple[OpPlan, ...]

    def op(self, index: int) -> OpPlan:
        return self.ops[index]

    def terminal_sites(self, sched: S.Schedule) -> Tuple[tuple, ...]:
        """(op_index | None, description, bound) for every point the
        terminal-reduction law constrains: the input of each TRUNCATE and
        AGN, and the program's final output.  Shared by
        :meth:`validate`, lint rule SA111, and the bounds prover."""
        sites = []
        for i, op in enumerate(sched.ops):
            if isinstance(op, (S.TRUNCATE, S.AGN)):
                kind = type(op).__name__
                sites.append((i, f"{kind} input", self.ops[i].in_bound))
        if self.ops:
            sites.append((None, "program output", self.ops[-1].out_bound))
        return tuple(sites)

    def validate(self, sched: S.Schedule) -> "ReductionPlan":
        """Enforce the terminal-reduction law (SA111): state must be fully
        reduced (< q) before TRUNCATE/AGN and at program end under ANY
        plan.  Raises ValueError on an over-deferred plan."""
        if len(self.ops) != len(sched.ops):
            raise ValueError(
                f"plan for {self.schedule} has {len(self.ops)} op entries, "
                f"schedule {sched.name} has {len(sched.ops)} ops")
        for idx, what, bound in self.terminal_sites(sched):
            if bound > self.q:
                where = f"ops[{idx}]" if idx is not None else "end"
                raise ValueError(
                    f"terminal-reduction law violated at {where} "
                    f"({sched.name}): {what} bound {bound} > q={self.q} — "
                    "the plan defers a reduce past the output boundary")
        return self

    def describe(self) -> str:
        lines = [f"reduction plan {self.schedule} [{self.mode}]"]
        for p in self.ops:
            flags = ",".join(p.flags) or "-"
            lines.append(f"  ops[{p.index:2d}]  in<{p.in_bound // self.q}q "
                         f"out<{p.out_bound // self.q}q  {flags}")
        return "\n".join(lines)


def _lazy_rows_fit(mod, mat, in_bound: int) -> bool:
    """True iff every row of the small mix matrix survives the lazy
    accumulate walk at the given operand bound — checked against the same
    site enumeration the overflow proof discharges."""
    return all(
        site.ok
        for row in mat
        for site in mod.accumulate_sites(row, in_bound=in_bound, lazy=True)
    )


@functools.lru_cache(maxsize=None)
def plan_reductions(params, schedule: S.Schedule | None = None,
                    mode: str = DEFAULT_REDUCTION) -> ReductionPlan:
    """Derive the reduction plan for one (preset, variant) program.

    ``mode="eager"`` yields the legacy everything-reduced plan (all bounds
    q, no flags — interpreters honoring it emit the pre-pass graphs).
    ``mode="lazy"`` applies the policy in the module docstring, deferring
    only where the corresponding `Modulus` feasibility check discharges.
    The result is deterministic in (params, schedule, mode) — engines
    thread the *mode string* across jit boundaries and rebuild the plan
    inside, so plans never need to be hashable inputs.
    """
    if mode not in REDUCTION_MODES:
        raise ValueError(f"unknown reduction mode {mode!r}; "
                         f"expected one of {REDUCTION_MODES}")
    if schedule is None:
        schedule = S.build_schedule(params)
    mod = params.mod
    q = mod.q
    ops_in = schedule.ops
    if mode == "eager":
        plan_ops = tuple(OpPlan(i, q, q) for i in range(len(ops_in)))
        return ReductionPlan(schedule=schedule.name, mode=mode, q=q,
                             ops=plan_ops).validate(schedule)

    mat = params.mix_matrix()
    plan_ops = []
    bound = q                       # initial state (ic or key) is reduced
    for i, op in enumerate(ops_in):
        in_b = bound
        flags = []
        out_b = q                   # default: op emits reduced state
        if isinstance(op, S.ARK):
            nxt = ops_in[i + 1] if i + 1 < len(ops_in) else None
            if (isinstance(nxt, S.MRMC) and not nxt.streams_matrix
                    and _lazy_rows_fit(mod, mat, in_b + q)):
                # x (< in_b) + k·rc (< q) flows raw into the shift-add
                # MRMC accumulator with recomputed thresholds
                flags.append(DEFER_OUT)
                out_b = in_b + q
        elif isinstance(op, S.MRMC):
            if op.streams_matrix:
                # deferred products are < 3q < 2^30, always chunkable; a
                # relaxed state bound must clear the limb multiply
                if mod.mul_fits(q, in_b):
                    flags.append(LAZY_DENSE)
                if op.mix_branches:
                    mix_in = 2 * q if op.has_rc else q
                    if 3 * mix_in < 2**32:
                        flags.append(FOLD_MIX)
            elif _lazy_rows_fit(mod, mat, in_b):
                flags.append(LAZY_ACCUMULATE)
        # NONLINEAR / TRUNCATE / AGN execute eagerly on reduced state:
        # relaxed Feistel squares cost more limb-internal reduce steps
        # than the deferred adds save (DESIGN.md §14), and the terminal
        # ops are constrained by the terminal-reduction law anyway.
        if in_b > q and not flags:
            raise AssertionError(
                f"reduction planner deferred {in_b} into ops[{i}] of "
                f"{schedule.name} without a feasible lazy policy")
        plan_ops.append(OpPlan(i, in_b, out_b, tuple(flags)))
        bound = out_b
    return ReductionPlan(schedule=schedule.name, mode=mode, q=q,
                         ops=tuple(plan_ops)).validate(schedule)
