"""RtF transciphering scaffold — the *server* side of HHE (paper §II).

In the full RtF framework the server homomorphically evaluates the cipher's
decryption circuit under FV, then runs CKKS HalfBoot.  Reproducing FV/CKKS
is its own paper-scale system and explicitly out of scope (the paper under
reproduction is the client-side accelerator).  What we build here is the
part that constrains cipher design and that the paper reasons about:

  * evaluation of the keystream circuit *as an arithmetic circuit* over Z_q
    with multiplicative-depth tracking (`DepthTracked`) — this verifies the
    paper's central claim that Rubato's Feistel (depth 1/round) is much
    shallower than HERA's Cube (depth 2/round), which is what makes the
    server-side FV evaluation cheap; PASTA sits between them ((r−1)
    Feistel rounds + one Cube = depth r+1: 4 for pasta-128l);
  * the transciphering consistency contract: server-side keystream == the
    client's, so (c − z) recovers the encoded message slots that HalfBoot
    would carry into CKKS.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.core import rounds as R
from repro.core import schedule as S
from repro.core.cipher import Cipher
from repro.core.params import CipherParams


@dataclasses.dataclass
class DepthTracked:
    """A Z_q value paired with its multiplicative depth.

    Mirrors FV noise-budget accounting: plaintext·ciphertext products (the
    k ⊙ rc key schedule) and additions are depth-free; ciphertext×ciphertext
    multiplies take max(depth_a, depth_b) + 1.
    """

    value: Any
    depth: int = 0


class CircuitMod:
    """Adapter exposing the Modulus interface over DepthTracked values."""

    def __init__(self, params: CipherParams):
        self.params = params
        self.mod = params.mod

    def add(self, a: DepthTracked, b: DepthTracked) -> DepthTracked:
        return DepthTracked(self.mod.add(a.value, b.value), max(a.depth, b.depth))

    def mul_ct(self, a: DepthTracked, b: DepthTracked) -> DepthTracked:
        return DepthTracked(
            self.mod.mul(a.value, b.value), max(a.depth, b.depth) + 1
        )

    def mul_pt(self, a: DepthTracked, pt) -> DepthTracked:
        """Plaintext multiply — depth-free in the FV accounting we mirror."""
        return DepthTracked(self.mod.mul(a.value, pt), a.depth)


def evaluate_decryption_circuit(cipher: Cipher, block_ctrs):
    """Evaluate the stream-key circuit with depth tracking.

    Interprets the SAME ``build_schedule(params)`` program the client
    executors run (core/schedule.py), with DepthTracked values — the server
    circuit cannot drift from the cipher because both are one schedule.
    The normal-orientation variant is used: orientation is a client-side
    layout concern; the FV circuit is slot-order agnostic.

    Returns (keystream, mult_depth).  HERA Par-128a: depth 2 per Cube × 5
    nonlinear layers = 10.  Rubato Par-128L: depth 1 per Feistel × 2 = 2.
    PASTA: the FV-encrypted key is the initial state, the affine layers
    (matrix, +rc, branch mix) are depth-free, and (r−1) Feistels + one
    Cube give depth r+1 (4 for pasta-128l) — between the other two.
    """
    p = cipher.params
    sched = S.build_schedule(p)
    consts = cipher.round_constant_stream(block_ctrs)
    cm = CircuitMod(p)
    mod = p.mod

    key = jnp.broadcast_to(cipher.key, block_ctrs.shape + (p.n,))
    # the key is the FV-encrypted input; everything derived from it carries depth
    k = DepthTracked(key, 0)
    if sched.init == "key":
        x = DepthTracked(key, 0)                 # PASTA: keyed permutation
    else:
        ic = jnp.broadcast_to(
            jnp.asarray(R.ic_vector(p)), block_ctrs.shape + (p.n,)
        )
        x = DepthTracked(ic, 0)

    def cube(x):
        sq = cm.mul_ct(x, x)
        return cm.mul_ct(sq, x)

    def feistel(x):
        b = p.branches
        val = x.value.reshape(x.value.shape[:-1] + (b, x.value.shape[-1] // b))
        head = DepthTracked(val[..., :-1], x.depth)
        sq = cm.mul_ct(head, head)
        shifted = jnp.concatenate(
            [jnp.zeros_like(val[..., :1]), sq.value], axis=-1
        )
        out = mod.add(val, shifted).reshape(x.value.shape)
        return DepthTracked(out, max(x.depth, sq.depth))

    rc = consts["rc"]
    for op in sched.ops:
        if isinstance(op, S.ARK):
            a, b = op.rc_slice
            kt = DepthTracked(k.value[..., : op.key_len], k.depth)
            x = cm.add(x, DepthTracked(
                mod.mul(kt.value, rc[..., a:b]), kt.depth))
        elif isinstance(op, S.MRMC):
            if op.streams_matrix:
                # stream-sourced dense affine layer: the matrix is *public*
                # per-block randomness (plaintext), so the t×t matvec is
                # plaintext-multiply + adds — depth-free, exactly like the
                # static circulant path
                ma, mb = op.mat_slice
                m = consts["mats"][..., ma:mb]
                t = p.n // p.branches
                M = m.reshape(m.shape[:-1] + (p.branches, t, t))
                X = x.value.reshape(x.value.shape[:-1] + (p.branches, t))
                val = mod.matvec_dense(M, X).reshape(x.value.shape)
            else:
                val = R.mrmc(p, x.value)         # plaintext linear
            if op.has_rc:
                a, b = op.rc_slice
                val = mod.add(val, rc[..., a:b])  # plaintext add: depth-free
            if op.mix_branches:
                val = R.branch_mix(p, val)       # ct+ct adds: depth-free
            x = DepthTracked(val, x.depth)
        elif isinstance(op, S.NONLINEAR):
            x = cube(x) if op.kind == "cube" else feistel(x)
        elif isinstance(op, S.TRUNCATE):
            x = DepthTracked(x.value[..., : op.keep], x.depth)
        elif isinstance(op, S.AGN):
            # AGN noise is added by the *client*; the server's circuit stops
            # here — the noise rides along inside the symmetric ciphertext
            # (that is the point of Rubato: the cipher's own noise doubles
            # as HE noise).
            pass
    return x.value, x.depth


def measured_depth(params: CipherParams, seed: int = 0) -> int:
    """Multiplicative depth the depth-tracked circuit ACTUALLY accumulates,
    measured by running :func:`evaluate_decryption_circuit` on one block.

    The executable half of the depth cross-check: `repro.analysis.bounds`
    derives the same number statically from the schedule program (2 per
    Cube, 1 per Feistel layer) and CI fails if the two ever disagree —
    a drifted executor or a drifted analyzer, either way a real bug.
    """
    from repro.core.cipher import make_cipher

    ci = make_cipher(params.name, seed=seed)
    _, depth = evaluate_decryption_circuit(
        ci, jnp.arange(1, dtype=jnp.uint32))
    return int(depth)


def transcipher(cipher: Cipher, c, block_ctrs, delta: float = 1024.0):
    """Server-side transciphering: symmetric ciphertext -> "CKKS slots".

    Evaluates the decryption circuit (depth-tracked), subtracts the stream
    key, and decodes fixed-point slots — the values HalfBoot would carry
    into a CKKS ciphertext.  Returns (slots, mult_depth).

    Output-shape contract: the circuit yields exactly ``l`` slots per block
    for ALL ciphers, but by different routes — HERA never truncates
    (l == n by construction, enforced in CipherParams), Rubato's final ARK
    feeds Tr_{n,l}, and PASTA's final affine layer feeds Tr to one branch
    (l = n/2).  The ciphertext ``c`` must therefore be (..., l) in every
    case.
    """
    z, depth = evaluate_decryption_circuit(cipher, block_ctrs)
    l = cipher.params.l
    if z.shape[-1] != l:
        raise AssertionError(
            f"decryption circuit produced {z.shape[-1]} slots, expected l={l}"
        )
    if c.shape[-1] != l:
        raise ValueError(f"ciphertext last dim {c.shape[-1]} != l={l}")
    mq = cipher.params.mod.sub(c, z)
    return cipher.decode(mq, delta), depth
