"""Rubato stream-key generation (paper §III-B).

    Rubato(k) = AGN ∘ Fin ∘ RF_{r-1} ∘ ... ∘ RF_1 ∘ ARK(k)   applied to ic
    RF  = ARK ∘ Feistel ∘ MixRows ∘ MixColumns
    Fin = Tr ∘ ARK ∘ MixRows ∘ MixColumns ∘ Feistel ∘ MixRows ∘ MixColumns

Round-constant accounting: r ARKs × n + final ARK × l (truncation makes the
trailing n−l constants of the final ARK dead) = 64+64+60 = 188 for Par-128L,
matching the paper's FIFO-depth discussion.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import rounds as R
from repro.core.params import CipherParams


def rubato_stream_key(params: CipherParams, key, rc, noise_signed, ic=None):
    """Generate keystream blocks.

    key: (..., n) uint32 in Z_q.
    rc:  (..., r*n + l) flat uint32 round constants (decoupled-RNG input).
    noise_signed: (..., l) int32 discrete-Gaussian samples (AGN), or None.
    Returns (..., l) uint32 keystream block.
    """
    n, l, r = params.n, params.l, params.rounds
    if rc.shape[-1] != params.n_round_constants:
        raise ValueError(
            f"rc last dim {rc.shape[-1]} != {params.n_round_constants}"
        )
    if ic is None:
        ic = jnp.asarray(R.ic_vector(params))
    x = jnp.broadcast_to(ic, rc.shape[:-1] + (n,))

    x = R.ark(params, x, key, rc[..., 0:n])
    for j in range(1, r):                      # RF_1 .. RF_{r-1}
        x = R.mrmc(params, x)
        x = R.feistel(params, x)
        x = R.ark(params, x, key, rc[..., j * n : (j + 1) * n])
    # Fin
    x = R.mrmc(params, x)
    x = R.feistel(params, x)
    x = R.mrmc(params, x)
    x = R.truncate(params, x)
    x = R.ark(params, x, key[..., :l], rc[..., r * n : r * n + l])
    if noise_signed is not None and params.sigma > 0:
        x = R.agn(params, x, noise_signed)
    return x
