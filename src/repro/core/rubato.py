"""Rubato stream-key generation (paper §III-B).

    Rubato(k) = AGN ∘ Fin ∘ RF_{r-1} ∘ ... ∘ RF_1 ∘ ARK(k)   applied to ic
    RF  = ARK ∘ Feistel ∘ MixRows ∘ MixColumns
    Fin = Tr ∘ ARK ∘ MixRows ∘ MixColumns ∘ Feistel ∘ MixRows ∘ MixColumns

The round structure is *data*: `core/schedule.py` emits it once
(`build_schedule`) and this module interprets it via `execute_schedule` —
the same program the fused Pallas kernel runs.  Round-constant accounting
(r ARKs × n + final ARK × l, truncation making the trailing n−l constants
of the final ARK dead = 64+64+60 = 188 for Par-128L, the paper's
FIFO-depth discussion) is a property of that program.
"""

from __future__ import annotations

from repro.core.params import CipherParams
from repro.core.schedule import build_schedule, execute_schedule


def rubato_stream_key(params: CipherParams, key, rc, noise_signed, ic=None,
                      variant: str = "normal"):
    """Generate keystream blocks.

    key: (..., n) uint32 in Z_q.
    rc:  (..., r*n + l) flat uint32 round constants (decoupled-RNG input).
    noise_signed: (..., l) int32 discrete-Gaussian samples (AGN), or None.
    Returns (..., l) uint32 keystream block.
    """
    if rc.shape[-1] != params.n_round_constants:
        raise ValueError(
            f"rc last dim {rc.shape[-1]} != {params.n_round_constants}"
        )
    sched = build_schedule(params, variant)
    return execute_schedule(params, sched, key, rc, noise_signed, ic=ic)
