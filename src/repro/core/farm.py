"""Multi-stream keystream farm: depth-configurable producer→consumer windows.

The paper's T3 ("RNG decoupling") separates the XOF/sampler *producer* from
the round-pipeline *consumer* so the two overlap.  The fused Pallas kernel
already does this at kernel level (BlockSpec double buffering, DMA of block
i+1's constants during block i's rounds).  This module lifts the same
structure to system level for the ROADMAP's many-concurrent-sessions
target:

  * a *window* is a fixed-size batch of lanes, each lane an arbitrary
    (session, block-counter) pair from a :class:`repro.core.cipher.
    CipherBatch` pool — one key, many nonces;
  * :class:`KeystreamFarm` runs a window schedule with a configurable
    pipeline ``depth`` (the paper's FIFO-depth knob lifted to window
    granularity): producers for up to ``depth-1`` windows ahead are
    *dispatched* (async on TPU) before the consumer of window i runs, so
    XOF/sampling for upcoming windows hides behind the current window's
    round computation.  depth=2 is classic double buffering (the
    default); depth=1 serializes producer and consumer (the D1 baseline
    shape); deeper FIFOs absorb producer-latency jitter;
  * the *producer* is the pool's pluggable :class:`repro.core.producer.
    ConstantsProducer` (aes / threefry / cached — see that registry), and
    the *consumer* is a pluggable :class:`repro.core.engine.
    KeystreamEngine` — any registered backend or a pre-bound instance;
    "auto" and the legacy `consumer="kernel"` spelling resolve in
    `repro.core.engine`, the one place backend policy lives;
  * the whole (producer, engine, variant, window, depth) tuple can be
    applied at once from a measured :class:`repro.core.tuner.StreamPlan`
    (``plan=``), the autotuner's unit of selection.

Fixed window sizes keep every producer/consumer call shape-stable —
:func:`pack_windows` pads ragged tails by repeating the last real lane
(outputs trimmed on yield), so the farm compiles exactly two XLA programs
regardless of how many sessions, windows, or stragglers it serves.
`serve/hhe_loop.py` packs ragged request traffic into these windows;
`data/encrypted.py` streams training batches through them.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Iterable, Iterator, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.cipher import CipherBatch, decode_fixed, encode_fixed
from repro.core.engine import EngineSpec


@dataclasses.dataclass
class WindowPlan:
    """One farm step: parallel per-lane (session, counter) arrays.

    ``valid`` counts the real lanes; lanes past it are padding (repeats of
    the last real lane — recomputed keystream, discarded on trim, never
    fresh counters).  Defaults to all lanes.
    """

    session_ids: np.ndarray   # (lanes,) int32
    block_ctrs: np.ndarray    # (lanes,) uint32
    meta: Any = None          # opaque caller tag (e.g. request slices)
    valid: Optional[int] = None

    def __post_init__(self):
        self.session_ids = np.asarray(self.session_ids, np.int32).reshape(-1)
        self.block_ctrs = np.asarray(self.block_ctrs, np.uint32).reshape(-1)
        if self.session_ids.shape != self.block_ctrs.shape:
            raise ValueError("session_ids / block_ctrs length mismatch")
        if self.valid is None:
            self.valid = self.session_ids.shape[0]
        if not 0 < self.valid <= self.session_ids.shape[0]:
            raise ValueError(
                f"valid={self.valid} out of range for "
                f"{self.session_ids.shape[0]} lanes")

    @property
    def lanes(self) -> int:
        return self.session_ids.shape[0]


def pack_windows(session_ids, block_ctrs, window: int) -> List[WindowPlan]:
    """THE window slicer: per-lane arrays -> fixed-size `WindowPlan`s.

    Every window has exactly ``window`` lanes: a non-dividing tail is
    padded by repeating its last real lane (the pad+trim idiom
    `keystream_pallas` uses for ragged lanes), with ``plan.valid`` marking
    where the real lanes end — so ragged totals never force a fresh XLA
    compile for a one-off tail shape.  All slicing-into-windows in the
    farm, the serving loop, and the tuner goes through here, so the
    padding rule lives in exactly one place.
    """
    if window <= 0:
        raise ValueError("window must be positive")
    sids = np.asarray(session_ids).reshape(-1)
    ctrs = np.asarray(block_ctrs).reshape(-1)
    if sids.shape != ctrs.shape:
        raise ValueError("session_ids / block_ctrs length mismatch")
    plans = []
    for i in range(0, sids.shape[0], window):
        s, c = sids[i : i + window], ctrs[i : i + window]
        valid = s.shape[0]
        if valid < window:                      # ragged tail: pad + mark
            pad = window - valid
            s = np.concatenate([s, np.full(pad, s[-1], s.dtype)])
            c = np.concatenate([c, np.full(pad, c[-1], c.dtype)])
        plans.append(WindowPlan(s, c, valid=valid))
    return plans


def plan_windows(sessions, blocks_per_session: int, window: int,
                 interleave: bool = True) -> List[WindowPlan]:
    """Reserve ``blocks_per_session`` counters on each session and pack the
    resulting lanes into fixed-size windows.

    interleave=True round-robins sessions across lanes (many short streams
    per window — the serving traffic shape); False keeps each session's
    lanes contiguous (bulk re-keying shape).  A non-dividing total is
    padded to the window size (`pack_windows`), so every window is
    shape-stable; ``plan.valid`` marks the real lanes of the tail.
    """
    pairs = []
    for s in sessions:
        ctrs = s.take_window(blocks_per_session)
        pairs.append(np.stack(
            [np.full(blocks_per_session, s.index, np.int64), ctrs]))
    stacked = np.stack(pairs)                     # (S, 2, B)
    if interleave:
        flat = stacked.transpose(2, 0, 1).reshape(-1, 2)   # ctr-major
    else:
        flat = stacked.transpose(0, 2, 1).reshape(-1, 2)   # session-major
    return pack_windows(flat[:, 0], flat[:, 1], window)


class KeystreamFarm:
    """Depth-configurable producer→consumer pipeline over a CipherBatch pool.

    ``engine`` selects the consumer backend: any name registered in
    `repro.core.engine` ("ref", "jax", "pallas", "pallas-interpret",
    "sharded"), "auto", or an already-bound :class:`KeystreamEngine`
    instance (the pluggable-consumer path).  ``consumer`` is the legacy
    spelling of the same argument and still accepts "kernel" (+ the
    ``interpret`` flag); both resolve through
    :func:`repro.core.engine.resolve_engine`, so unknown names raise a
    ValueError listing the registered engines.  ``variant`` picks the
    schedule-orientation plan the consumer executes (core/schedule.py;
    "auto" = the backend's preferred one; bit-exact either way).

    ``depth`` sets the producer→consumer FIFO depth: producers for up to
    ``depth-1`` windows ahead are dispatched before each consume (2 =
    double buffering, the default; 1 = serialized).  The producer itself
    is the pool's pluggable `repro.core.producer` backend.

    ``matrix_depth`` is the matrix-plane prefetch depth for stream-
    sourced-MRMC presets (PASTA, whose dense affine matrices are a ~t×
    heavier RNG load than the rc plane): with ``matrix_depth >= 2``,
    matrix-plane-only production for up to ``matrix_depth`` windows ahead
    is dispatched through a second FIFO, *independent* of the
    vector-plane/consumer pipeline, so the heavy plane's XOF + rejection
    sampling hides behind more round computation than ``depth`` alone
    buys.  matrix_depth=1 (the default) keeps the single fused produce;
    presets without matrix planes ignore the knob entirely.  Bit-exact at
    every depth (tests/test_farm.py).

    ``plan`` applies a measured :class:`repro.core.tuner.StreamPlan` in
    one shot — producer (rebound on the pool), engine, variant, depth,
    matrix_depth, and reduction mode — with any explicitly-passed
    argument taking precedence.
    """

    def __init__(self, batch: CipherBatch, engine: Optional[EngineSpec] = None,
                 *, consumer: Optional[str] = None, mesh=None,
                 axis: str = "data", interpret: Optional[bool] = None,
                 variant: Optional[str] = None, depth: Optional[int] = None,
                 matrix_depth: Optional[int] = None,
                 reduction: Optional[str] = None, plan=None):
        if engine is not None and consumer is not None:
            raise ValueError("pass engine= or the legacy consumer=, not both")
        self.plan = plan
        self.window: Optional[int] = None
        if plan is not None:
            if engine is None and consumer is None:
                engine = plan.engine
            if variant is None:
                variant = plan.variant
            if depth is None:
                depth = plan.depth
            if matrix_depth is None:
                matrix_depth = getattr(plan, "matrix_depth", 1)
            if reduction is None:
                reduction = getattr(plan, "reduction", None)
            self.window = plan.window
            batch.set_producer(plan.producer)
        spec = consumer if engine is None else engine
        if spec is None:
            spec = "auto"
        depth = 2 if depth is None else int(depth)
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1 (got {depth})")
        self.depth = depth
        matrix_depth = 1 if matrix_depth is None else int(matrix_depth)
        if matrix_depth < 1:
            raise ValueError(
                f"matrix prefetch depth must be >= 1 (got {matrix_depth})")
        self.matrix_depth = matrix_depth
        self.batch = batch
        self.engine = batch.make_engine(spec, mesh=mesh, axis=axis,
                                        interpret=interpret, variant=variant,
                                        reduction=reduction)
        self.consumer = self.engine.name     # backwards-compatible attr
        self.mesh = mesh
        self.axis = axis

    @property
    def _splits_planes(self) -> bool:
        """Whether run() splits vector/matrix plane production: only for
        stream-sourced-MRMC presets with prefetch actually requested."""
        return (self.matrix_depth > 1
                and self.batch.params.n_matrix_constants > 0)

    # ------------------------------------------------------------------
    def produce(self, plan: WindowPlan, plane: str = "all"):
        """Dispatch the (async) producer for one window — the pool's
        pluggable `ConstantsProducer` (memoizing backends short-circuit
        repeated windows here).  ``plane`` narrows the payload ("vector"
        when the matrix FIFO produces matrices separately)."""
        return self.batch.producer.produce(
            self.batch.xof_tables(), plan.session_ids, plan.block_ctrs, plane
        )

    def produce_matrix(self, plan: WindowPlan):
        """Dispatch matrix-plane-only production for one window (the
        prefetch-ahead FIFO's producer half)."""
        return self.batch.producer.produce(
            self.batch.xof_tables(), plan.session_ids, plan.block_ctrs,
            "matrix"
        )

    def consume(self, constants):
        """Run the engine consumer on produced constants."""
        return self.engine(constants)

    # ------------------------------------------------------------------
    def pipeline(self) -> "FarmPipeline":
        """A stateful push/drain view of the producer→consumer FIFO.

        :meth:`run` is this object driven by an iterable; event-driven
        callers (`serve/hhe_loop.py`'s scheduler) hold one long-lived
        pipeline instead and push windows as traffic fires them, so the
        FIFO overlap spans scheduling events, not just one flush call.
        """
        return FarmPipeline(self)

    def run(self, plans: Iterable[WindowPlan]
            ) -> Iterator[Tuple[WindowPlan, jnp.ndarray]]:
        """Yield (plan, keystream) per window, pipeline-depth buffered.

        Producers for up to ``self.depth - 1`` windows ahead are
        dispatched *before* window i's consumer runs — on an async
        backend the XOF/sampling of upcoming windows overlaps the current
        round computation (the paper's T3 FIFO, its depth now a knob,
        lifted to window granularity).  depth=1 degenerates to the
        serialized D1 shape.

        For stream-sourced-MRMC presets with ``matrix_depth >= 2`` the
        matrix plane runs through its own prefetch FIFO: matrix-plane
        production is dispatched up to ``matrix_depth`` windows ahead,
        decoupled from the vector-plane/consumer pipeline, and the two
        planes are merged at consume time.  Lane order and keystream bits
        are identical either way.  Implemented over :meth:`pipeline`, the
        incremental form the event-driven serving scheduler drives.
        """
        pipe = self.pipeline()
        for plan in plans:
            yield from pipe.push(plan)
        yield from pipe.drain()

    def run_one(self, plan: WindowPlan) -> jnp.ndarray:
        """Serialized single-window convenience: produce + consume now."""
        return self.consume(self.produce(plan))

    def keystream(self, session_ids, block_ctrs, window: Optional[int] = None):
        """Convenience: full keystream for per-lane pairs, windowed.

        window=None uses the plan's window when one was applied, else runs
        everything as a single window.  Ragged totals are padded to the
        window size and trimmed on return (`pack_windows`), so every
        dispatch is shape-stable.  Returns (lanes, l) uint32, lane order
        preserved.
        """
        sid = np.asarray(session_ids, np.int64).reshape(-1)
        ctr = np.asarray(block_ctrs, np.int64).reshape(-1)
        if window is None:
            window = self.window or sid.shape[0]
        plans = pack_windows(sid, ctr, window)
        outs = [z[: p.valid] for p, z in self.run(plans)]
        return jnp.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]

    # ------------------------------------------------------------------
    def _payload_stream(self, plans_and_payloads):
        """Split (plan, payload) pairs lazily: feed plans to run(), FIFO the
        payloads alongside.  run() reads at most depth-1 plans ahead, so
        the queue never holds more than ``depth`` payloads — the stream
        stays a stream."""
        payloads: deque = deque()

        def plans():
            for plan, payload in plans_and_payloads:
                payloads.append(payload)
                yield plan

        for plan, z in self.run(plans()):
            yield plan, payloads.popleft(), z

    def encrypt_stream(self, plans_and_msgs, delta: float = 1024.0):
        """Streaming encrypt: iterable of (WindowPlan, (lanes, l) float)
        -> yields (plan, ciphertext).  Keystream pipelined as in run().
        """
        mod = self.batch.params.mod
        for plan, m, z in self._payload_stream(plans_and_msgs):
            yield plan, mod.add(encode_fixed(mod, m, delta), z)

    def decrypt_stream(self, plans_and_cts, delta: float = 1024.0):
        """Streaming decrypt: iterable of (WindowPlan, (lanes, l) u32)
        -> yields (plan, plaintext float32)."""
        mod = self.batch.params.mod
        for plan, ct, z in self._payload_stream(plans_and_cts):
            yield plan, decode_fixed(mod, mod.sub(jnp.asarray(ct), z), delta)


class FarmPipeline:
    """Incremental (push-driven) form of :meth:`KeystreamFarm.run`.

    ``push(plan)`` dispatches the window's producer(s) immediately and
    returns any (plan, keystream) pairs whose consumers fired as the FIFO
    reached its depth; ``drain()`` finishes everything in flight.  Driving
    push over an iterable and then draining reproduces ``run()``'s
    dispatch order *exactly* — same producer/consumer interleaving, same
    bits — which tests/test_serve.py pins.  The point of the split: an
    event-driven caller (the serving scheduler) can keep ONE pipeline
    alive across scheduling events, so windows fired by different submit
    wake-ups still overlap producer-vs-consumer like a batch flush would.

    For stream-sourced-MRMC presets with ``matrix_depth >= 2`` the heavy
    matrix plane runs through its own prefetch FIFO ahead of the
    vector/consumer FIFO (the paper's FIFO decoupling applied to the ~t×
    heavier plane); planes merge at consume time.
    """

    def __init__(self, farm: KeystreamFarm):
        self.farm = farm
        self._fifo: deque = deque()     # (plan, in-flight constants[, mats])
        self._mfifo: deque = deque()    # (plan, in-flight matrix plane)

    def in_flight(self) -> int:
        """Windows dispatched (producer running) but not yet consumed."""
        return len(self._fifo) + len(self._mfifo)

    def _promote(self) -> None:
        """Move the oldest matrix-FIFO window into the vector/consumer
        FIFO, dispatching its vector-plane producer."""
        plan, mats = self._mfifo.popleft()
        self._fifo.append((plan, self.farm.produce(plan, "vector"), mats))

    def _consume_one(self):
        entry = self._fifo.popleft()
        if len(entry) == 3:
            plan, consts, mats = entry
            merged = dict(consts)
            merged["mats"] = mats["mats"]
            return plan, self.farm.consume(merged)
        plan, consts = entry
        return plan, self.farm.consume(consts)

    def push(self, plan: WindowPlan) -> List[Tuple[WindowPlan, jnp.ndarray]]:
        out: List[Tuple[WindowPlan, jnp.ndarray]] = []
        if self.farm._splits_planes:
            self._mfifo.append((plan, self.farm.produce_matrix(plan)))
            if len(self._mfifo) >= self.farm.matrix_depth:
                self._promote()
        else:
            self._fifo.append((plan, self.farm.produce(plan)))
        while len(self._fifo) >= self.farm.depth:
            out.append(self._consume_one())
        return out

    def drain(self) -> List[Tuple[WindowPlan, jnp.ndarray]]:
        out: List[Tuple[WindowPlan, jnp.ndarray]] = []
        while self._mfifo:
            self._promote()
            while len(self._fifo) >= self.farm.depth:
                out.append(self._consume_one())
        while self._fifo:
            out.append(self._consume_one())
        return out
