"""Multi-stream keystream farm: double-buffered producer→consumer windows.

The paper's T3 ("RNG decoupling") separates the XOF/sampler *producer* from
the round-pipeline *consumer* so the two overlap.  The fused Pallas kernel
already does this at kernel level (BlockSpec double buffering, DMA of block
i+1's constants during block i's rounds).  This module lifts the same
structure to system level for the ROADMAP's many-concurrent-sessions
target:

  * a *window* is a fixed-size batch of lanes, each lane an arbitrary
    (session, block-counter) pair from a :class:`repro.core.cipher.
    CipherBatch` pool — one key, many nonces;
  * :class:`KeystreamFarm` runs a window schedule with depth-2 double
    buffering: the jit'd producer for window i+1 is *dispatched* (async on
    TPU) before the consumer of window i runs, so XOF/sampling for the next
    window hides behind the current window's round computation;
  * the consumer is a pluggable :class:`repro.core.engine.KeystreamEngine`
    — any registered backend (ref / jax / pallas / pallas-interpret /
    sharded) or a pre-bound engine instance; "auto" and the legacy
    `consumer="kernel"` spelling resolve in `repro.core.engine`, the one
    place backend policy lives.

Fixed window sizes keep every producer/consumer call shape-stable, so the
farm compiles exactly two XLA programs regardless of how many sessions or
windows it serves.  `serve/hhe_loop.py` packs ragged request traffic into
these windows; `data/encrypted.py` streams training batches through them.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Iterable, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cipher import CipherBatch, decode_fixed, encode_fixed
from repro.core.engine import EngineSpec


@dataclasses.dataclass
class WindowPlan:
    """One farm step: parallel per-lane (session, counter) arrays."""

    session_ids: np.ndarray   # (lanes,) int32
    block_ctrs: np.ndarray    # (lanes,) uint32
    meta: Any = None          # opaque caller tag (e.g. request slices)

    def __post_init__(self):
        self.session_ids = np.asarray(self.session_ids, np.int32).reshape(-1)
        self.block_ctrs = np.asarray(self.block_ctrs, np.uint32).reshape(-1)
        if self.session_ids.shape != self.block_ctrs.shape:
            raise ValueError("session_ids / block_ctrs length mismatch")

    @property
    def lanes(self) -> int:
        return self.session_ids.shape[0]


def plan_windows(sessions, blocks_per_session: int, window: int,
                 interleave: bool = True) -> List[WindowPlan]:
    """Reserve ``blocks_per_session`` counters on each session and pack the
    resulting lanes into fixed-size windows.

    interleave=True round-robins sessions across lanes (many short streams
    per window — the serving traffic shape); False keeps each session's
    lanes contiguous (bulk re-keying shape).  The tail window is NOT padded;
    use a window size dividing the total for shape-stable jits.
    """
    pairs = []
    for s in sessions:
        ctrs = s.take_window(blocks_per_session)
        pairs.append(np.stack(
            [np.full(blocks_per_session, s.index, np.int64), ctrs]))
    stacked = np.stack(pairs)                     # (S, 2, B)
    if interleave:
        flat = stacked.transpose(2, 0, 1).reshape(-1, 2)   # ctr-major
    else:
        flat = stacked.transpose(0, 2, 1).reshape(-1, 2)   # session-major
    return [
        WindowPlan(flat[i : i + window, 0], flat[i : i + window, 1])
        for i in range(0, flat.shape[0], window)
    ]


class KeystreamFarm:
    """Double-buffered producer→consumer pipeline over a CipherBatch pool.

    ``engine`` selects the consumer backend: any name registered in
    `repro.core.engine` ("ref", "jax", "pallas", "pallas-interpret",
    "sharded"), "auto", or an already-bound :class:`KeystreamEngine`
    instance (the pluggable-consumer path).  ``consumer`` is the legacy
    spelling of the same argument and still accepts "kernel" (+ the
    ``interpret`` flag); both resolve through
    :func:`repro.core.engine.resolve_engine`, so unknown names raise a
    ValueError listing the registered engines.  ``variant`` picks the
    schedule-orientation plan the consumer executes (core/schedule.py;
    "auto" = the backend's preferred one; bit-exact either way).
    """

    def __init__(self, batch: CipherBatch, engine: Optional[EngineSpec] = None,
                 *, consumer: Optional[str] = None, mesh=None,
                 axis: str = "data", interpret: Optional[bool] = None,
                 variant: Optional[str] = None):
        if engine is not None and consumer is not None:
            raise ValueError("pass engine= or the legacy consumer=, not both")
        spec = consumer if engine is None else engine
        if spec is None:
            spec = "auto"
        self.batch = batch
        self.engine = batch.make_engine(spec, mesh=mesh, axis=axis,
                                        interpret=interpret, variant=variant)
        self.consumer = self.engine.name     # backwards-compatible attr
        self.mesh = mesh
        self.axis = axis
        self._producer = jax.jit(batch.make_producer_fn())

    # ------------------------------------------------------------------
    def produce(self, plan: WindowPlan):
        """Dispatch the (async) producer for one window."""
        return self._producer(
            self.batch.xof_tables(), plan.session_ids, plan.block_ctrs
        )

    def consume(self, constants):
        """Run the engine consumer on produced constants."""
        return self.engine(constants)

    # ------------------------------------------------------------------
    def run(self, plans: Iterable[WindowPlan]
            ) -> Iterator[Tuple[WindowPlan, jnp.ndarray]]:
        """Yield (plan, keystream) per window, double-buffered.

        The producer for window i+1 is dispatched *before* window i's
        consumer runs — on an async backend the XOF/sampling of the next
        window overlaps the current round computation (depth-2 FIFO, the
        paper's T3 lifted to window granularity).
        """
        it = iter(plans)
        try:
            cur = next(it)
        except StopIteration:
            return
        cur_c = self.produce(cur)
        for nxt in it:
            nxt_c = self.produce(nxt)          # overlaps consume(cur)
            yield cur, self.consume(cur_c)
            cur, cur_c = nxt, nxt_c
        yield cur, self.consume(cur_c)

    def keystream(self, session_ids, block_ctrs, window: Optional[int] = None):
        """Convenience: full keystream for per-lane pairs, windowed.

        window=None runs everything as a single window.  Returns
        (lanes, l) uint32, lane order preserved.
        """
        sid = np.asarray(session_ids, np.int64).reshape(-1)
        ctr = np.asarray(block_ctrs, np.int64).reshape(-1)
        if window is None:
            window = sid.shape[0]
        plans = [
            WindowPlan(sid[i : i + window], ctr[i : i + window])
            for i in range(0, sid.shape[0], window)
        ]
        outs = [z for _, z in self.run(plans)]
        return jnp.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]

    # ------------------------------------------------------------------
    def _payload_stream(self, plans_and_payloads):
        """Split (plan, payload) pairs lazily: feed plans to run(), FIFO the
        payloads alongside.  run() reads at most one plan ahead (the double
        buffer), so the queue never holds more than two payloads — the
        stream stays a stream."""
        payloads: deque = deque()

        def plans():
            for plan, payload in plans_and_payloads:
                payloads.append(payload)
                yield plan

        for plan, z in self.run(plans()):
            yield plan, payloads.popleft(), z

    def encrypt_stream(self, plans_and_msgs, delta: float = 1024.0):
        """Streaming encrypt: iterable of (WindowPlan, (lanes, l) float)
        -> yields (plan, ciphertext).  Keystream double-buffered as in run().
        """
        mod = self.batch.params.mod
        for plan, m, z in self._payload_stream(plans_and_msgs):
            yield plan, mod.add(encode_fixed(mod, m, delta), z)

    def decrypt_stream(self, plans_and_cts, delta: float = 1024.0):
        """Streaming decrypt: iterable of (WindowPlan, (lanes, l) u32)
        -> yields (plan, plaintext float32)."""
        mod = self.batch.params.mod
        for plan, ct, z in self._payload_stream(plans_and_cts):
            yield plan, decode_fixed(mod, mod.sub(jnp.asarray(ct), z), delta)
