"""Unified keystream backend layer: the `KeystreamEngine` registry.

The paper's accelerator is ONE datapath (vectorized modules, decoupled RNG,
FIFO-overlapped rounds); this module makes the reproduction expose it the
same way.  Every consumer that turns (key, round constants[, noise]) into
keystream — the pure-jnp reference, the batched-XLA pipeline, the fused
Pallas kernel in compiled or interpret mode, the shard_map lane-sharded
kernel — is a registered engine with declared capabilities, and *all*
backend policy ("auto" selection, legacy `consumer`/`interpret` flag
spellings, availability checks) lives here and nowhere else.

Registered engines (see `registered_engines()` / `engine_caps()`):

  * ``ref``              — eager pure-jnp round pipeline.  The bit-exactness
                           oracle; always available; no jit.
  * ``jax``              — the same pipeline under `jax.jit` (batched XLA).
                           The CPU/GPU fast path and the "auto" fallback.
  * ``pallas``           — the fused Pallas kernel, compiled.  TPU only.
  * ``pallas-interpret`` — the fused kernel in interpret mode.  Correctness
                           tool (slow!), available everywhere; capped lanes.
  * ``sharded``          — the fused kernel lane-sharded over a mesh data
                           axis via shard_map (multi-device farm path).
                           Needs a mesh.

Usage:

    eng = make_engine("auto", params, key)          # policy decided HERE
    z = eng.keystream_from_constants(rc, noise)     # or eng(constants_dict)

`core/farm.py`, `serve/hhe_loop.py`, `data/encrypted.py`,
`launch/serve.py`, and `benchmarks/keystream_farm_bench.py` all route
keystream materialization through engine instances; `core/cipher.py` binds
a default ``ref`` engine per Cipher/CipherBatch.  docs/DESIGN.md §7
documents the layer.

All engines are bit-exact with ``ref`` by contract (tests/test_engine.py
asserts the full engine × cipher-preset × noise × variant matrix, across
all three cipher kinds — hera / rubato / pasta).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple, Type, Union

import jax
import jax.numpy as jnp

from repro.core.params import CipherParams
from repro.core.redplan import DEFAULT_REDUCTION, REDUCTION_MODES
from repro.core.schedule import VARIANTS, build_schedule
from repro.kernels.keystream.keystream import BLK
from repro.kernels.keystream.ops import (
    keystream_kernel_apply,
    keystream_kernel_sharded,
)
from repro.kernels.keystream.ref import keystream_ref


@dataclasses.dataclass(frozen=True)
class EngineCaps:
    """What one backend can do, queried without instantiating it.

    ``available`` answers "can this engine run on the current JAX backend /
    with the given mesh?"; ``reason`` says why not when it can't.
    ``max_lanes`` is a practical per-call lane bound (None = unbounded) —
    exceeded lanes raise instead of silently running for hours (the
    interpret-mode trap).  ``schedule_variants`` lists which orientation
    plans from `core/schedule.py` the backend can execute, and
    ``preferred_variant`` is what "auto" resolves to — the variant the
    backend runs bubble-free (alternating for the unrolled Pallas datapath,
    normal for XLA executors where an orientation flip is a real transpose).
    """

    name: str
    description: str
    available: bool
    reason: str = ""
    supports_noise: bool = True
    max_lanes: Optional[int] = None
    jitted: bool = True
    schedule_variants: Tuple[str, ...] = VARIANTS
    preferred_variant: str = "normal"


class KeystreamEngine:
    """One way to materialize keystream from (key, constants).

    Subclasses implement `_run(rc, noise)`; the base class owns capability
    validation so every backend enforces the same contract.  Engines are
    bound to (params, key) at construction — the farm's consumer, a
    cipher's default consumer, and the bench's per-engine lap are all just
    instances of these classes.
    """

    name: str = "?"

    def __init__(self, params: CipherParams, key, *, mesh=None,
                 axis: str = "data", interpret: Optional[bool] = None,
                 variant: str = "normal",
                 reduction: str = DEFAULT_REDUCTION):
        self.params = params
        self.key = jnp.asarray(key, jnp.uint32)
        self.mesh = mesh
        self.axis = axis
        self.interpret = interpret   # only 'sharded' consults it (None=auto)
        self.caps = type(self).query_caps(mesh=mesh, axis=axis)
        if variant == "auto":
            variant = self.caps.preferred_variant
        if variant not in self.caps.schedule_variants:
            raise ValueError(
                f"engine {self.name!r} does not support schedule variant "
                f"{variant!r} (supports {self.caps.schedule_variants})"
            )
        self.variant = variant
        if reduction not in REDUCTION_MODES:
            raise ValueError(
                f"unknown reduction mode {reduction!r}; expected one of "
                f"{REDUCTION_MODES}"
            )
        #: reduction-scheduling mode ("lazy" | "eager") — bit-exact either
        #: way (core/redplan.py); engines thread the mode string and the
        #: executors rebuild the cached plan inside their traces
        self.reduction = reduction
        #: the declarative round program this engine executes
        self.schedule = build_schedule(params, variant)

    # -- capability reporting (class-level: no instance needed) ------------
    @classmethod
    def query_caps(cls, *, mesh=None, axis: str = "data") -> EngineCaps:
        raise NotImplementedError

    # -- the consumer ------------------------------------------------------
    def _run(self, rc, noise, mats):
        raise NotImplementedError

    def keystream_from_constants(self, rc, noise=None, mats=None):
        """rc: (lanes, n_round_constants) u32; noise: (lanes, l) i32 | None;
        mats: (lanes, n_matrix_constants) u32 | None — dense matrix planes
        for stream-sourced MRMC schedules (PASTA).  Returns (lanes, l) u32
        keystream — bit-exact across engines."""
        if noise is not None and not self.caps.supports_noise:
            raise ValueError(f"engine {self.name!r} does not support noise")
        if self.caps.max_lanes is not None and rc.shape[0] > self.caps.max_lanes:
            raise ValueError(
                f"engine {self.name!r} caps lanes at {self.caps.max_lanes} "
                f"per call (got {rc.shape[0]}); window the request or pick "
                "an uncapped engine"
            )
        if self.schedule.n_matrix_constants and mats is None:
            raise ValueError(
                f"schedule {self.schedule.name} streams its affine matrices "
                "— pass the producer's mats plane"
            )
        return self._run(rc, noise, mats)

    def __call__(self, constants: dict):
        """Consume a producer's dict(rc=..., noise=..., mats=...) directly."""
        return self.keystream_from_constants(
            constants["rc"], constants.get("noise"), constants.get("mats")
        )

    def __repr__(self):
        return f"<KeystreamEngine {self.name} params={self.params.name}>"


# ==========================================================================
# Registry
# ==========================================================================
_REGISTRY: Dict[str, Type[KeystreamEngine]] = {}


def register_engine(cls: Type[KeystreamEngine]) -> Type[KeystreamEngine]:
    """Class decorator: add an engine to the registry under ``cls.name``."""
    if cls.name in _REGISTRY:
        raise ValueError(f"engine {cls.name!r} already registered")
    _REGISTRY[cls.name] = cls
    return cls


def registered_engines() -> Tuple[str, ...]:
    """Names of all registered engines (available or not), sorted."""
    return tuple(sorted(_REGISTRY))


def engine_caps(*, mesh=None, axis: str = "data") -> Dict[str, EngineCaps]:
    """Capability report for every registered engine."""
    return {
        name: cls.query_caps(mesh=mesh, axis=axis)
        for name, cls in sorted(_REGISTRY.items())
    }


def _tuned_engine(params, mesh, axis: str = "data") -> Optional[str]:
    """Consult the StreamPlan cache for a measured engine choice.

    Lazy import (the tuner sits above this module); returns None — never
    raises — when no params context is given, no plan is cached for this
    (preset, host), or the cached engine is unavailable here.  Looked up
    with lanes=None (engines are lane-agnostic at bind time): the nearest
    tuned lane count for the preset decides.  Lane-exact plan application
    is the ``plan=`` path on the farm/server.
    """
    if params is None:
        return None
    try:
        from repro.core.tuner import load_plan

        plan = load_plan(params, lanes=None, mesh=mesh, axis=axis)
    except Exception:
        return None
    if plan is None or plan.engine not in _REGISTRY:
        return None
    caps = _REGISTRY[plan.engine].query_caps(mesh=mesh, axis=axis)
    return plan.engine if caps.available else None


def resolve_engine(spec: str, *, interpret: Optional[bool] = None,
                   mesh=None, params=None, axis: str = "data") -> str:
    """THE single place backend auto-selection lives.

    ``spec`` is an engine name, "auto", or a legacy farm consumer spelling:

      * "auto"   -> with a ``params`` context, the measured `StreamPlan`
        from the tuner cache (`repro.core.tuner.load_plan`) when one
        exists for this (preset, host); otherwise the static preference —
        the fused kernel on TPU ("sharded" when a mesh is given, else
        "pallas"), "jax" elsewhere;
      * "kernel" -> the fused kernel: "sharded" when a mesh is given,
        "pallas" when compiled Pallas can run (TPU, or interpret
        explicitly False), else "pallas-interpret" — exactly the old
        KeystreamFarm(consumer="kernel", mesh=..., interpret=...)
        behavior;
      * "pallas" with interpret=True -> "pallas-interpret".

    Unknown names raise ValueError listing the registered engines.
    """
    if spec == "auto":
        spec = (_tuned_engine(params, mesh, axis)
                or ("kernel" if jax.default_backend() == "tpu" else "jax"))
    if spec == "kernel":  # legacy farm consumer name
        on_tpu = jax.default_backend() == "tpu"
        if mesh is not None:
            spec = "sharded"
        elif interpret is False or (interpret is None and on_tpu):
            spec = "pallas"
        else:
            spec = "pallas-interpret"
    elif spec == "pallas" and interpret is True:
        spec = "pallas-interpret"
    if spec not in _REGISTRY:
        raise ValueError(
            f"unknown keystream engine {spec!r}; registered engines: "
            f"{list(registered_engines())} (plus 'auto' and the legacy "
            "'kernel' alias)"
        )
    return spec


EngineSpec = Union[str, KeystreamEngine]


def make_engine(spec: EngineSpec, params: CipherParams, key, *, mesh=None,
                axis: str = "data", interpret: Optional[bool] = None,
                variant: Optional[str] = None,
                reduction: Optional[str] = None) -> KeystreamEngine:
    """Resolve ``spec`` and bind it to (params, key).

    ``spec`` may already be a KeystreamEngine instance (passed through —
    the pluggable-consumer path), but only if it is bound to the SAME
    (params, key): a consumer keyed differently from the producer would
    emit keystream no session cipher can match, silently.  Raises
    RuntimeError when the resolved engine is not available here (e.g.
    "pallas" off-TPU), with the backend's own reason and a pointer to the
    registry table (``python -m repro.core.engine``).

    ``variant`` picks the schedule orientation plan ("normal" |
    "alternating" | "auto" = the backend's preferred variant; see
    core/schedule.py) — all variants are bit-exact, so this is purely a
    scheduling choice.  None (the default) means "unspecified": newly
    constructed engines get "normal", and a pre-bound instance is accepted
    with whatever plan it already executes; an *explicit* variant that
    contradicts a pre-bound instance raises instead of being silently
    ignored.

    ``reduction`` picks the reduction-scheduling mode ("lazy" | "eager",
    core/redplan.py) with the same None-means-unspecified semantics —
    newly constructed engines default to "lazy"; an explicit mode that
    contradicts a pre-bound instance raises.  Both modes are bit-exact.
    """
    if isinstance(spec, KeystreamEngine):
        if spec.params != params or not bool(
                (spec.key == jnp.asarray(key, jnp.uint32)).all()):
            raise ValueError(
                f"engine {spec.name!r} is bound to different (params, key) "
                f"(engine has {spec.params.name}); rebind it with "
                "make_engine for this pool"
            )
        if variant is not None and variant != "auto" \
                and variant != spec.variant:
            raise ValueError(
                f"engine {spec.name!r} already executes the "
                f"{spec.variant!r} schedule variant; requested {variant!r} "
                "— rebind with make_engine instead of passing the instance"
            )
        if reduction is not None and reduction != spec.reduction:
            raise ValueError(
                f"engine {spec.name!r} already runs the {spec.reduction!r} "
                f"reduction schedule; requested {reduction!r} — rebind "
                "with make_engine instead of passing the instance"
            )
        return spec
    name = resolve_engine(spec, interpret=interpret, mesh=mesh,
                          params=params, axis=axis)
    cls = _REGISTRY[name]
    caps = cls.query_caps(mesh=mesh, axis=axis)
    if not caps.available:
        raise RuntimeError(
            f"keystream engine {name!r} unavailable here: {caps.reason} "
            "(run `python -m repro.core.engine` for the full registry "
            "table)"
        )
    return cls(params, key, mesh=mesh, axis=axis, interpret=interpret,
               variant=variant if variant is not None else "normal",
               reduction=reduction if reduction is not None
               else DEFAULT_REDUCTION)


# ==========================================================================
# Backends
# ==========================================================================
@register_engine
class RefEngine(KeystreamEngine):
    """Eager pure-jnp round pipeline — the oracle every backend must match."""

    name = "ref"

    @classmethod
    def query_caps(cls, *, mesh=None, axis="data") -> EngineCaps:
        return EngineCaps(
            name=cls.name,
            description="eager pure-jnp reference (bit-exactness oracle)",
            available=True,
            jitted=False,
        )

    def _run(self, rc, noise, mats):
        return keystream_ref(self.params, self.key, rc, noise,
                             variant=self.variant, mats=mats,
                             reduction=self.reduction)


@register_engine
class JaxEngine(KeystreamEngine):
    """The reference pipeline under jax.jit: one fused XLA program."""

    name = "jax"

    def __init__(self, params, key, *, mesh=None, axis="data",
                 interpret=None, variant="normal",
                 reduction=DEFAULT_REDUCTION):
        super().__init__(params, key, mesh=mesh, axis=axis,
                         interpret=interpret, variant=variant,
                         reduction=reduction)
        # params/variant/reduction via partial => static; key/rc/noise
        # traced (noise=None is a valid empty pytree, so one jit covers
        # both arities)
        self._fn = jax.jit(functools.partial(keystream_ref, params,
                                             variant=self.variant,
                                             reduction=self.reduction))

    @classmethod
    def query_caps(cls, *, mesh=None, axis="data") -> EngineCaps:
        return EngineCaps(
            name=cls.name,
            description="batched XLA round pipeline (CPU/GPU fast path)",
            available=True,
        )

    def _run(self, rc, noise, mats):
        return self._fn(self.key, rc, noise, mats=mats)


class _PallasBase(KeystreamEngine):
    _interpret: Optional[bool] = None   # None = kernel-side auto

    def _run(self, rc, noise, mats):
        if noise is not None and not self.params.n_noise:
            noise = None    # kernel's 2-input variant
        return keystream_kernel_apply(
            self.params, self.key, rc, noise, interpret=self._interpret,
            variant=self.variant, mats=mats, reduction=self.reduction,
        )


@register_engine
class PallasEngine(_PallasBase):
    """The fused Pallas kernel, compiled — the paper's datapath on TPU."""

    name = "pallas"
    _interpret = False

    @classmethod
    def query_caps(cls, *, mesh=None, axis="data") -> EngineCaps:
        backend = jax.default_backend()
        ok = backend == "tpu"
        return EngineCaps(
            name=cls.name,
            description="fused Pallas kernel, compiled (TPU)",
            available=ok,
            reason="" if ok else (
                f"compiled Pallas needs a TPU backend (have {backend!r}); "
                "use 'pallas-interpret' for correctness or 'jax' for speed"
            ),
            # the unrolled kernel flips orientation for free (Eq. 2): the
            # paper's bubble-free alternating schedule is its native mode
            preferred_variant="alternating",
        )


@register_engine
class PallasInterpretEngine(_PallasBase):
    """The fused kernel in interpret mode: runs anywhere, slowly.

    A correctness tool, not a fast path — lanes are capped so a stray
    "auto" can never turn a serving window into an hour-long interpret run.
    """

    name = "pallas-interpret"
    _interpret = True
    MAX_LANES = 64 * BLK

    @classmethod
    def query_caps(cls, *, mesh=None, axis="data") -> EngineCaps:
        return EngineCaps(
            name=cls.name,
            description="fused Pallas kernel, interpret mode (slow, "
                        "portable correctness tool)",
            available=True,
            max_lanes=cls.MAX_LANES,
            jitted=False,
            preferred_variant="alternating",
        )


@register_engine
class ShardedEngine(KeystreamEngine):
    """Fused kernel with the lane axis shard_map'd over ``mesh[axis]``.

    Key replicated, constants split, no cross-device traffic.  On a 1-wide
    axis this degrades to the plain kernel apply (same numerics), so the
    only hard requirement is a mesh that names the axis.
    """

    name = "sharded"

    @classmethod
    def query_caps(cls, *, mesh=None, axis="data") -> EngineCaps:
        if mesh is None:
            return EngineCaps(
                name=cls.name,
                description="shard_map lane-sharded fused kernel",
                available=False,
                reason="needs a mesh (pass mesh=/axis= to make_engine)",
                preferred_variant="alternating",
            )
        if axis not in mesh.shape:
            return EngineCaps(
                name=cls.name,
                description="shard_map lane-sharded fused kernel",
                available=False,
                reason=f"mesh has no axis {axis!r} (axes: "
                       f"{tuple(mesh.shape)})",
                preferred_variant="alternating",
            )
        return EngineCaps(
            name=cls.name,
            description=f"shard_map lane-sharded fused kernel "
                        f"({mesh.shape[axis]} device(s) on {axis!r})",
            available=True,
            preferred_variant="alternating",
        )

    def _run(self, rc, noise, mats):
        if noise is not None and not self.params.n_noise:
            noise = None
        return keystream_kernel_sharded(
            self.params, self.key, rc, noise, mesh=self.mesh,
            axis=self.axis, interpret=self.interpret, variant=self.variant,
            mats=mats, reduction=self.reduction,
        )


# ==========================================================================
# Introspection CLI: `python -m repro.core.engine`
# ==========================================================================
def describe(*, mesh=None, axis: str = "data") -> str:
    """The engine registry as a table: one row per backend, with
    availability (and the reason when unavailable), schedule variants,
    lane caps, and the "auto" resolution on this host."""
    caps = engine_caps(mesh=mesh, axis=axis)
    rows = [("engine", "available", "variants (pref)", "max lanes",
             "description / reason")]
    for name, c in caps.items():
        variants = "/".join(c.schedule_variants) + f" ({c.preferred_variant})"
        lanes = str(c.max_lanes) if c.max_lanes is not None else "-"
        detail = c.description if c.available else f"UNAVAILABLE: {c.reason}"
        rows.append((name, "yes" if c.available else "no", variants, lanes,
                     detail))
    widths = [max(len(r[i]) for r in rows) for i in range(4)]
    lines = []
    for i, r in enumerate(rows):
        lines.append("  ".join(r[j].ljust(widths[j]) for j in range(4))
                     + "  " + r[4])
        if i == 0:
            lines.append("  ".join("-" * w for w in widths) + "  " + "-" * 24)
    lines.append("")
    lines.append(f"backend: {jax.default_backend()}   "
                 f"auto resolves to: {resolve_engine('auto')!r}   "
                 "(legacy alias 'kernel' also accepted)")
    return "\n".join(lines)


if __name__ == "__main__":
    print(describe())
