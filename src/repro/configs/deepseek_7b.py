"""deepseek-7b [dense]: 30L d4096 32H (MHA: kv=32) ff11008 v102400 —
llama-arch.  [arXiv:2401.02954; hf]"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    kv_heads=32,
    d_ff=11008,
    vocab=102400,
)

SMOKE = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    kv_heads=4,
    d_ff=128,
    vocab=512,
    remat=False,
)

register(FULL, SMOKE)
