"""mixtral-8x7b [moe]: 32L d4096 32H (GQA kv=8) ff14336 v32000 — 8 experts
top-2, sliding-window attention (4096).  [arXiv:2401.04088; hf]"""

from repro.configs.base import LayerSpec, ModelConfig, register

FULL = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    kv_heads=8,
    d_ff=14336,
    vocab=32000,
    rope_theta=1e6,
    group=(LayerSpec(window=4096, moe=True),),
    num_experts=8,
    top_k=2,
)

SMOKE = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    kv_heads=2,
    d_ff=128,
    vocab=512,
    rope_theta=1e6,
    group=(LayerSpec(window=16, moe=True),),
    num_experts=4,
    top_k=2,
    remat=False,
)

register(FULL, SMOKE)
