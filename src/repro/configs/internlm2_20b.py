"""internlm2-20b [dense]: 48L d6144 48H (GQA kv=8) ff16384 v92544.
[arXiv:2403.17297; hf]"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    kv_heads=8,
    d_ff=16384,
    vocab=92544,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    kv_heads=2,
    d_ff=128,
    vocab=512,
    rope_theta=1e6,
    remat=False,
)

register(FULL, SMOKE)
