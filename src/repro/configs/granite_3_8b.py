"""granite-3-8b [dense]: 40L d4096 32H (GQA kv=8) ff12800 v49155; tied
embeddings.  [hf:ibm-granite/granite-3.0-2b-base; hf]"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    kv_heads=8,
    d_ff=12800,
    vocab=49155,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    kv_heads=2,
    d_ff=128,
    vocab=515,        # deliberately non-multiple-of-128 (tests padding)
    tie_embeddings=True,
    remat=False,
)

register(FULL, SMOKE)
