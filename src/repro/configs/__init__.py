"""Architecture registry: 10 assigned archs, full + smoke variants, plus the
paper's own cipher workload configs (presto_cipher)."""

from repro.configs.base import ModelConfig, LayerSpec, get_config, list_archs

__all__ = ["ModelConfig", "LayerSpec", "get_config", "list_archs"]
