"""mamba2-2.7b [ssm]: 64L d2560, attention-free (SSD), ssm_state=128,
v50280.  Runs long_500k (sub-quadratic).  [arXiv:2405.21060; unverified]"""

from repro.configs.base import LayerSpec, ModelConfig, register

FULL = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    kv_heads=0,
    d_ff=0,
    vocab=50280,
    group=(LayerSpec(kind="mamba"),),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    rope_kind="none",
)

SMOKE = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=0,
    kv_heads=0,
    d_ff=0,
    vocab=512,
    group=(LayerSpec(kind="mamba"),),
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    conv_width=4,
    ssm_chunk=32,
    rope_kind="none",
    remat=False,
)

register(FULL, SMOKE)
