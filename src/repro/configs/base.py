"""Model configuration system.

One `ModelConfig` describes any of the 10 assigned architectures (dense /
MoE / SSM / hybrid / encoder-only / VLM-backbone).  Layer heterogeneity
(gemma2's local/global alternation, jamba's 1-attn-per-8 + MoE-every-2) is
expressed as a repeating *group* of `LayerSpec`s; the model scans over
groups with stacked parameters, keeping HLO size O(1) in depth.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer slot inside the repeating group."""

    kind: str = "attn"        # "attn" | "mamba"
    window: int = 0           # sliding-window size; 0 = full attention
    moe: bool = False         # MoE FFN instead of dense FFN


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int            # 0 for attn-free archs
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0         # 0 => d_model // num_heads

    # attention
    rope_theta: float = 1e4
    rope_kind: str = "std"    # "std" | "mrope" | "none"
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    causal: bool = True       # False = encoder-only (hubert)

    # layer group structure
    group: Tuple[LayerSpec, ...] = (LayerSpec(),)

    # moe
    num_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25

    # ssm (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256

    # ffn
    mlp_gated: bool = True         # SwiGLU (False: plain GELU, hubert)

    # norms / embeddings
    norm_eps: float = 1e-5
    sandwich_norm: bool = False    # gemma2 pre+post block norms
    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma: scale embeddings by sqrt(d)

    # modality frontend stub (audio frames / vision patches)
    frontend: str = "none"         # "none" | "audio" | "vision"
    frontend_dim: int = 0          # stub embedding dim fed by input_specs()

    # numerics
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "float32"   # master params ("bfloat16" for >=398B)
    opt_8bit: bool = False         # 8-bit Adam moments (arctic/jamba)
    remat: bool = True
    # roofline probes: unroll inner lax.scans (attention KV loop, SSD
    # chunks, FFN chunks) so XLA cost_analysis counts every iteration —
    # while-loop bodies are otherwise counted ONCE (launch/roofline.py)
    probe_unroll: bool = False

    # ----- derived -------------------------------------------------------
    def __post_init__(self):
        if self.num_layers % len(self.group) != 0:
            raise ValueError(
                f"{self.name}: num_layers {self.num_layers} not divisible by "
                f"group size {len(self.group)}"
            )
        if self.num_heads and self.kv_heads:
            hd = self.head_dim or self.d_model // self.num_heads
            if self.num_heads % self.kv_heads:
                raise ValueError("num_heads must be divisible by kv_heads")

    @property
    def resolved_head_dim(self) -> int:
        if self.num_heads == 0:
            return 0
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_groups(self) -> int:
        return self.num_layers // len(self.group)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 128 for clean 16-way TP sharding."""
        return ((self.vocab + 127) // 128) * 128

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_dim(self) -> int:
        # mamba2 conv runs over [x, B, C] channels (ngroups=1)
        return self.d_inner + 2 * self.ssm_state

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS and memory budgets)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_padded
        hd = self.resolved_head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        if self.frontend != "none":
            total += self.frontend_dim * d
        total += d  # final norm
        for spec in self.group:
            n = self.num_groups
            if spec.kind == "attn":
                attn = d * self.num_heads * hd + 2 * d * self.kv_heads * hd \
                    + self.num_heads * hd * d
                total += n * attn
            else:
                di, st = self.d_inner, self.ssm_state
                h = self.ssm_heads
                total += n * (
                    d * (2 * di + 2 * st + h)   # in_proj (x, z, B, C, dt)
                    + self.conv_width * self.conv_dim
                    + 2 * h                      # A_log, D
                    + di * d                     # out_proj
                )
            mats = 3 if self.mlp_gated else 2
            if spec.moe:
                total += n * (self.num_experts * 3 * d * f + d * self.num_experts)
                if self.dense_residual:
                    total += n * mats * d * f
            elif f > 0:
                total += n * mats * d * f
            total += n * 2 * d  # norms
        return total

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k of num_experts)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive = 0
        for spec in self.group:
            if spec.moe:
                inactive += self.num_groups * (self.num_experts - self.top_k) * 3 * d * f
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg_full: ModelConfig, cfg_smoke: ModelConfig):
    _REGISTRY[cfg_full.name] = (cfg_full, cfg_smoke)
    return cfg_full


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name][1 if smoke else 0]


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    # import for side effect of register() calls
    from repro.configs import (  # noqa: F401
        internlm2_20b, granite_3_8b, deepseek_7b, gemma2_9b, qwen2_vl_7b,
        hubert_xlarge, mamba2_2_7b, mixtral_8x7b, arctic_480b,
        jamba_1_5_large,
    )
