"""gemma2-9b [dense]: 42L d3584 16H (GQA kv=8, head_dim 256) ff14336
v256000 — local(4096)/global alternating, attn softcap 50, final softcap 30,
sandwich norms, tied embeddings, sqrt(d) embed scale.  [arXiv:2408.00118; hf]
"""

from repro.configs.base import LayerSpec, ModelConfig, register

FULL = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    group=(LayerSpec(window=4096), LayerSpec(window=0)),
    attn_softcap=50.0,
    final_softcap=30.0,
    sandwich_norm=True,
    tie_embeddings=True,
    embed_scale=True,
)

SMOKE = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    kv_heads=2,
    head_dim=32,
    d_ff=128,
    vocab=512,
    group=(LayerSpec(window=16), LayerSpec(window=0)),
    attn_softcap=50.0,
    final_softcap=30.0,
    sandwich_norm=True,
    tie_embeddings=True,
    embed_scale=True,
    remat=False,
)

register(FULL, SMOKE)
