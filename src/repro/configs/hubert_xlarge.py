"""hubert-xlarge [audio]: 48L d1280 16H (kv=16) ff5120 v504 — encoder-only
(no causal mask, no decode shapes), plain-GELU FFN, conv-feature frontend is
a STUB (input_specs feeds precomputed frame embeddings, dim 512).
[arXiv:2106.07447; unverified]"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    rope_kind="none",
    mlp_gated=False,
    frontend="audio",
    frontend_dim=512,
)

SMOKE = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    kv_heads=4,
    d_ff=128,
    vocab=64,
    causal=False,
    rope_kind="none",
    mlp_gated=False,
    frontend="audio",
    frontend_dim=32,
    remat=False,
)

register(FULL, SMOKE)
