"""qwen2-vl-7b [vlm backbone]: 28L d3584 28H (GQA kv=4) ff18944 v152064 —
M-RoPE (sections 16/24/24), dynamic-resolution vision frontend is a STUB per
assignment (input_specs feeds precomputed patch embeddings, dim 1280).
[arXiv:2409.12191; hf]"""

from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    kv_heads=4,
    d_ff=18944,
    vocab=152064,
    rope_theta=1e6,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    frontend="vision",
    frontend_dim=1280,
)

SMOKE = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=2,
    d_model=96,
    num_heads=6,     # head_dim 16 -> sections must sum to 8
    kv_heads=2,
    d_ff=192,
    vocab=512,
    rope_theta=1e6,
    rope_kind="mrope",
    mrope_sections=(4, 2, 2),
    frontend="vision",
    frontend_dim=32,
    remat=False,
)

register(FULL, SMOKE)
