"""arctic-480b [moe]: 35L d7168 56H (GQA kv=8) ff4864 v32000 — 128 experts
top-2 PLUS a dense-FFN residual branch on every layer.  bf16 params + 8-bit
Adam moments (HBM budget at 512 chips).  [hf:Snowflake/snowflake-arctic-base]
"""

from repro.configs.base import LayerSpec, ModelConfig, register

FULL = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    kv_heads=8,
    d_ff=4864,
    vocab=32000,
    group=(LayerSpec(moe=True),),
    num_experts=128,
    top_k=2,
    dense_residual=True,
    param_dtype="bfloat16",
    opt_8bit=True,
)

SMOKE = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    kv_heads=2,
    d_ff=96,
    vocab=512,
    group=(LayerSpec(moe=True),),
    num_experts=4,
    top_k=2,
    dense_residual=True,
    param_dtype="bfloat16",
    opt_8bit=True,
    remat=False,
)

register(FULL, SMOKE)
