"""jamba-1.5-large (398b) [hybrid]: 72L d8192 64H (GQA kv=8) ff24576
v65536 — Mamba+attention 1:7 interleave (attention at slot 3 of each
8-layer block), MoE 16 experts top-2 every other layer.  SSM: state 16
(Jamba's Mamba-1 selective scan realized in the SSD formulation — see
docs/DESIGN.md §8).  bf16 params + 8-bit Adam.  Runs long_500k (sub-quadratic).
[arXiv:2403.19887; hf]"""

from repro.configs.base import LayerSpec, ModelConfig, register


def _group(window=0):
    slots = []
    for i in range(8):
        kind = "attn" if i == 3 else "mamba"
        slots.append(LayerSpec(kind=kind, window=window, moe=(i % 2 == 1)))
    return tuple(slots)


FULL = ModelConfig(
    name="jamba-1.5-large",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    kv_heads=8,
    d_ff=24576,
    vocab=65536,
    group=_group(),
    num_experts=16,
    top_k=2,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    ssm_chunk=128,   # halves the intra-chunk decay tensors at 8192 d_model
    param_dtype="bfloat16",
    opt_8bit=True,
)

SMOKE = ModelConfig(
    name="jamba-1.5-large",
    family="hybrid",
    num_layers=8,
    d_model=64,
    num_heads=4,
    kv_heads=2,
    d_ff=128,
    vocab=512,
    group=_group(),
    num_experts=4,
    top_k=2,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    conv_width=4,
    ssm_chunk=32,
    param_dtype="bfloat16",
    opt_8bit=True,
    remat=False,
)

register(FULL, SMOKE)
