"""Version-compatibility shims for the pinned toolchain in the image.

`shard_map` graduated from `jax.experimental.shard_map` to the `jax`
namespace after 0.4.x, and its replication-check kwarg was renamed
`check_rep` -> `check_vma` in the move.  The image pins jax 0.4.37 (old
location, old kwarg); call sites are written against the new API and routed
through this wrapper so they work on either side of the migration.
"""

from __future__ import annotations

import inspect

import jax

try:
    _shard_map = jax.shard_map
except AttributeError:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_HAS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map).parameters


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    if check_vma is not None:
        kwargs["check_vma" if _HAS_CHECK_VMA else "check_rep"] = check_vma
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


__all__ = ["shard_map"]
