"""Pallas TPU kernels for the compute hot-spots the paper accelerates.

Layout convention (docs/DESIGN.md §2): kernels are *lane-major* — the keystream
lane/batch dimension is the trailing (128-wide vector lane) axis, and the
small cipher-state dimension n ∈ {16, 36, 64} lives on sublanes.  This is
the TPU analogue of the paper's "8 parallel lanes": state elements map to
functional units (sublanes, unrolled), lanes map to SIMD width.

Each kernel directory has:
  <name>.py — pl.pallas_call with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (padding, layout, dtype handling)
  ref.py    — pure-jnp oracle the kernel is validated against (interpret=True)
"""

from repro.kernels.mrmc.ops import mrmc_kernel_apply
from repro.kernels.keystream.ops import keystream_kernel_apply
from repro.kernels.aes.ops import aes_ctr_kernel_apply

__all__ = [
    "mrmc_kernel_apply",
    "keystream_kernel_apply",
    "aes_ctr_kernel_apply",
]
