"""Pure-jnp oracle for the fused keystream kernel: the core cipher itself.

Delegates to the SAME `build_schedule(params)` program the Pallas kernel
interprets (core/schedule.py) — the oracle and the kernel cannot drift
because they execute one shared cipher description.
"""

from __future__ import annotations

from repro.core.params import CipherParams
from repro.core.redplan import DEFAULT_REDUCTION
from repro.core.schedule import build_schedule, execute_schedule


def keystream_ref(params: CipherParams, key, rc, noise=None,
                  variant: str = "normal", mats=None,
                  reduction: str = DEFAULT_REDUCTION):
    """key: (n,) u32; rc: (lanes, n_round_constants) u32; noise: (lanes, l)
    int32 or None; mats: (lanes, n_matrix_constants) u32 or None (the
    stream-sourced dense affine matrices of a matrix-plane schedule).
    Returns (lanes, l) u32 keystream blocks.

    ``variant`` picks the schedule orientation plan ("normal" |
    "alternating") — bit-exact by Eq. 2, property-tested in
    tests/test_schedule.py.  ``reduction`` picks the reduction-scheduling
    mode ("lazy" | "eager", core/redplan.py) — bit-exact as well.
    """
    sched = build_schedule(params, variant)
    return execute_schedule(params, sched, key, rc, noise, mats=mats,
                            reduction=reduction)
