"""Pure-jnp oracle for the fused keystream kernel: the core cipher itself."""

from __future__ import annotations

from repro.core.hera import hera_stream_key
from repro.core.params import CipherParams
from repro.core.rubato import rubato_stream_key


def keystream_ref(params: CipherParams, key, rc, noise=None):
    """key: (n,) u32; rc: (lanes, n_round_constants) u32; noise: (lanes, l)
    int32 or None.  Returns (lanes, l) u32 keystream blocks."""
    if params.kind == "hera":
        rcs = rc.reshape(rc.shape[:-1] + (params.n_arks, params.n))
        return hera_stream_key(params, key, rcs)
    return rubato_stream_key(params, key, rc, noise)
