"""Public jit'd wrappers for the fused keystream kernel.

`keystream_kernel_apply` — kernel consumer with explicit constants (matches
ref.py signature).  `presto_keystream` — the full D3 pipeline: pure-JAX XOF
producer (decoupled RNG) feeding the fused Pallas consumer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.cipher import Cipher
from repro.core.params import CipherParams
from repro.kernels.keystream.keystream import BLK, keystream_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("params", "interpret"))
def keystream_kernel_apply(params: CipherParams, key, rc, noise=None,
                           interpret: bool | None = None):
    """key: (n,) u32; rc: (lanes, n_round_constants) u32; noise: (lanes, l)
    int32 or None.  Returns (lanes, l) u32 keystream blocks."""
    if interpret is None:
        interpret = _auto_interpret()
    lanes = rc.shape[0]
    pad = (-lanes) % BLK
    rc_p = jnp.pad(rc, ((0, pad), (0, 0))).T          # (n_consts, lanes_p)
    noise_p = None
    if noise is not None and params.n_noise:
        noise_p = jnp.pad(noise, ((0, pad), (0, 0))).T  # (l, lanes_p)
    out = keystream_pallas(
        params, key[:, None], rc_p, noise_p, interpret=interpret
    )
    return out.T[:lanes]


def presto_keystream(cipher: Cipher, block_ctrs, interpret: bool | None = None):
    """Full accelerator pipeline: XOF producer -> fused kernel consumer."""
    consts = cipher.round_constant_stream(block_ctrs)
    return keystream_kernel_apply(
        cipher.params, cipher.key, consts["rc"], consts["noise"],
        interpret=interpret,
    )
