"""Public jit'd wrappers for the fused keystream kernel.

`keystream_kernel_apply` — kernel consumer with explicit constants (matches
ref.py signature).  `keystream_kernel_sharded` — the same consumer with its
lane axis sharded over a mesh data axis via shard_map (the farm's
multi-device path: each device runs the fused kernel on its lane slice, key
replicated, no cross-device traffic).  `presto_keystream` — the full D3
pipeline: pure-JAX XOF producer (decoupled RNG) feeding the fused Pallas
consumer.

These wrappers are the *mechanism*; backend *policy* (which consumer runs
where, interpret-or-compiled, lane sharding) lives in one place:
`repro.core.engine`.  Callers that want a consumer should go through an
engine instance rather than passing interpret flags around.
"""

from __future__ import annotations

import functools
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.params import CipherParams
from repro.core.redplan import DEFAULT_REDUCTION
from repro.core.schedule import build_schedule
from repro.kernels.keystream.keystream import keystream_pallas

if TYPE_CHECKING:  # annotation only — core.engine imports this module
    from repro.core.cipher import Cipher


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("params", "interpret", "variant",
                                             "reduction"))
def keystream_kernel_apply(params: CipherParams, key, rc, noise=None,
                           interpret: bool | None = None,
                           variant: str = "normal", mats=None,
                           reduction: str = DEFAULT_REDUCTION):
    """key: (n,) u32; rc: (lanes, n_round_constants) u32; noise: (lanes, l)
    int32 or None; mats: (lanes, n_matrix_constants) u32 or None (dense
    matrix planes for stream-sourced MRMC schedules).  Returns (lanes, l)
    u32 keystream blocks.

    ``variant`` selects the schedule orientation plan ("normal" |
    "alternating", see core/schedule.py) — bit-exact either way.
    ``reduction`` selects the reduction-scheduling mode ("lazy" | "eager",
    core/redplan.py) — also bit-exact; it is a static jit argument, so the
    plan is rebuilt (cached) inside the trace.  Ragged lane counts are
    padded/trimmed inside :func:`keystream_pallas`.
    """
    if interpret is None:
        interpret = _auto_interpret()
    sched = build_schedule(params, variant)
    rc_p = rc.T                                       # (n_consts, lanes)
    noise_p = None
    if noise is not None and params.n_noise:
        noise_p = noise.T                             # (l, lanes)
    mats_p = None
    if mats is not None and sched.n_matrix_constants:
        mats_p = mats.T                               # (n_mat, lanes)
    out = keystream_pallas(
        params, key[:, None], rc_p, noise_p, interpret=interpret,
        schedule=sched, mats_ml=mats_p, reduction=reduction,
    )
    return out.T


def keystream_kernel_sharded(params: CipherParams, key, rc, noise=None, *,
                             mesh=None, axis: str = "data",
                             interpret: bool | None = None,
                             variant: str = "normal", mats=None,
                             reduction: str = DEFAULT_REDUCTION):
    """Lane-sharded fused consumer: rc/noise/mats split over ``mesh[axis]``.

    Same signature/semantics as :func:`keystream_kernel_apply`; lanes are
    padded to a multiple of the axis size, each device runs the fused kernel
    on its slice (key replicated), and the padding is stripped on the way
    out.  With no mesh (or a 1-wide axis) this is the plain kernel apply.
    """
    if mesh is None or mesh.shape.get(axis, 1) == 1:
        return keystream_kernel_apply(params, key, rc, noise,
                                      interpret=interpret, variant=variant,
                                      mats=mats, reduction=reduction)
    ndev = mesh.shape[axis]
    lanes = rc.shape[0]
    pad = (-lanes) % ndev
    rc_p = jnp.pad(rc, ((0, pad), (0, 0)))
    args = [key, rc_p]
    in_specs = [P(), P(axis, None)]
    with_noise = noise is not None and params.n_noise
    if with_noise:
        args.append(jnp.pad(noise, ((0, pad), (0, 0))))
        in_specs.append(P(axis, None))
    with_mats = mats is not None and params.n_matrix_constants
    if with_mats:
        args.append(jnp.pad(mats, ((0, pad), (0, 0))))
        in_specs.append(P(axis, None))

    def shard_fn(key_s, rc_s, *extra):
        extra = list(extra)
        noise_s = extra.pop(0) if with_noise else None
        mats_s = extra.pop(0) if with_mats else None
        return keystream_kernel_apply(
            params, key_s, rc_s, noise_s,
            interpret=interpret, variant=variant, mats=mats_s,
            reduction=reduction,
        )

    out = shard_map(
        shard_fn, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=P(axis, None), check_vma=False,
    )(*args)
    return out[:lanes]


def presto_keystream(cipher: Cipher, block_ctrs, interpret: bool | None = None):
    """Full accelerator pipeline: XOF producer -> fused kernel consumer.

    Backend selection is engine-routed: ``interpret`` picks between the
    registered "pallas" and "pallas-interpret" engines (None = whatever the
    current backend supports; see :func:`repro.core.engine.resolve_engine`).
    """
    from repro.core.engine import make_engine  # runtime: engine imports us

    if interpret is None:
        interpret = _auto_interpret()
    eng = make_engine("pallas-interpret" if interpret else "pallas",
                      cipher.params, cipher.key)
    consts = cipher.round_constant_stream(block_ctrs)
    return eng.keystream_from_constants(consts["rc"], consts["noise"],
                                        consts.get("mats"))
