from repro.kernels.keystream.ops import keystream_kernel_apply, presto_keystream

__all__ = ["keystream_kernel_apply", "presto_keystream"]
