"""Pallas kernel: fully fused HERA/Rubato/PASTA stream-key generation.

This is the accelerator itself (paper §IV), re-architected for TPU — the
T1–T4 technique mapping below is documented in docs/DESIGN.md §3:

  * T1 (vectorization + function overlapping) → the *entire* r-round cipher
    is one kernel; the state lives in VMEM/vregs from initial ARK to final
    output.  Between "functional modules" (ARK, MRMC, Cube/Feistel) there is
    no HBM traffic at all — the strongest possible form of the paper's
    module-overlap: on TPU, modules are fused ops on register-resident data.
  * T2 (MRMC transposition-invariance) → MixColumns/MixRows execute as one
    algebraic unit M_v·X·M_vᵀ with no transpose materialization or relayout
    (see kernels/mrmc/mrmc.py, shared implementation).
  * T3 (RNG decoupling) → round constants are an *input* streamed through
    `BlockSpec` grid pipelining.  Pallas double-buffers input blocks: while
    block i computes, block i+1's constants are DMA'd HBM→VMEM — the FIFO
    between the AES producer and the round consumer, depth 2, in hardware.
  * T4 (shift-add) → no integer multiply in the linear layers; the modular
    multiplies that remain (key schedule, Cube/Feistel) use the 14-bit limb
    scheme, uint32 only.

The kernel body is a *schedule interpreter*: it executes the declarative
round program from `core/schedule.py` — the same `build_schedule(params)`
ops the pure-JAX reference interprets — so there is ONE code path for all
three ciphers (HERA, Rubato, PASTA) and any future scheme is a schedule,
not a new kernel.  PASTA exercises the IR's generalizations: key-initial
state (the key column broadcast across lanes replaces the iota ic), the
affine MRMC (per-branch matrix + additive storage-order constants + the
two-branch mix), and per-branch Feistel.  Orientation handling (the
paper's alternating MixColumns/MixRows order, Eq. 2):

  * a transposed-orientation MRMC is the identical shift-add datapath with
    the output stacking relabeled (`mrmc_matrix_apply(transpose_out=...)`)
    — no relayout, the TPU bubble elimination;
  * transposed ARKs read constants the wrapper pre-permuted into storage
    order (`Schedule.rc_storage_perm`) — the RNG FIFO delivers constants in
    exactly the order the datapath consumes them — and a second, permuted
    key column rides along in the (n, 2) key input;
  * transposed Feistel is a static row/column shift of the (v, v, BLK)
    view (logical neighbors sit one sublane-row up).

Layout: lane-major (state dim on sublanes, keystream lanes on vector lanes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import redplan as RP
from repro.core import schedule as S
from repro.core.params import CipherParams
from repro.core.schedule import Schedule, build_schedule, state_transpose_perm
from repro.crypto.modmath import Modulus
from repro.kernels.mrmc.mrmc import mrmc_dense_apply, mrmc_matrix_apply

BLK = 128  # keystream lanes per grid step


def _feistel(mod: Modulus, x):
    """y_1 = x_1; y_i = x_i + x_{i-1}^2 — on (n, BLK) lane-major state."""
    sq = mod.mul(x[:-1], x[:-1])
    shifted = jnp.concatenate([jnp.zeros_like(x[:1]), sq], axis=0)
    return mod.add(x, shifted)


def _feistel_transposed(mod: Modulus, v: int, x):
    """Feistel on transposed-stored (n, BLK) state via static shifts of the
    (v, v, BLK) view: stored row c*v+r holds logical element r*v+c, so the
    logical predecessor is one view-row up, wrapping to (v-1, r-1)."""
    sq = mod.mul(x, x).reshape(v, v, -1)          # axes (c, r, lane)
    row0 = jnp.concatenate(
        [jnp.zeros_like(sq[:1, :1]), sq[v - 1:, : v - 1]], axis=1
    )
    shifted = jnp.concatenate([row0, sq[: v - 1]], axis=0).reshape(x.shape)
    return mod.add(x, shifted)


def _keystream_kernel(params: CipherParams, sched: Schedule, plan,
                      with_noise: bool, with_mats: bool, *refs):
    """One grid step: interpret the schedule program on a (n, BLK) block.

    ``plan`` is the `core.redplan.ReductionPlan` for this program — the
    kernel honors the same per-op reduce deferrals the pure-JAX
    interpreter does (bit-exact either way; only the conditional-subtract
    placement moves)."""
    refs = list(refs)
    key_ref, rc_ref = refs[:2]
    o_ref = refs[-1]
    extra = refs[2:-1]
    noise_ref = extra.pop(0) if with_noise else None
    mats_ref = extra.pop(0) if with_mats else None

    p = params
    mod = p.mod
    mat = p.mix_matrix()
    n, v = p.n, p.v
    nb = sched.branches
    t = n // nb

    key2 = key_ref[...]         # (n, 2): col 0 normal, col 1 transposed
    rc = rc_ref[...]            # (n_round_constants, BLK), STORAGE order
    if sched.init == "key":
        # PASTA: the keyed permutation — the key column IS the state
        x = jnp.broadcast_to(key2[:, :1], (n, rc.shape[-1]))
    else:
        # ic = (1, ..., n) built in-kernel (n < q, so no reduction needed);
        # programs always start in normal orientation
        x = jax.lax.broadcasted_iota(
            jnp.uint32, (n, rc.shape[-1]), 0
        ) + jnp.uint32(1)

    for oi, op in enumerate(sched.ops):
        p_i = plan.ops[oi]
        if isinstance(op, S.ARK):
            a, b = op.rc_slice
            col = 1 if op.orientation == S.TRANSPOSED else 0
            k = key2[:, col : col + 1][: op.key_len]
            m_ = mod.mul(k, rc[a:b])
            # defer-out: the raw sum (< in_bound + q) flows into the next
            # MRMC's lazy shift-add accumulator
            x = x + m_ if p_i.has(RP.DEFER_OUT) else mod.add(x, m_)
        elif isinstance(op, S.MRMC):
            if op.streams_matrix:
                # dense per-lane matrix plane, delivered storage-permuted
                # (`mat_storage_perm`): stored-state in -> stored-state out,
                # so there is no flip handling here at all
                ma, _ = op.mat_slice
                mats = mats_ref[...]
                lazy_d = p_i.has(RP.LAZY_DENSE)
                x = jnp.concatenate([
                    mrmc_dense_apply(
                        mod,
                        mats[ma + i * t * t : ma + (i + 1) * t * t].reshape(
                            t, t, -1),
                        x[i * t : (i + 1) * t],
                        x_bound=p_i.in_bound if lazy_d else None,
                        lazy=lazy_d,
                    )
                    for i in range(nb)
                ], axis=0)
            else:
                flip = op.orientation != op.out_orientation
                lazy_a = p_i.has(RP.LAZY_ACCUMULATE)
                x = jnp.concatenate([
                    mrmc_matrix_apply(
                        mod, mat, x[i * t : (i + 1) * t].reshape(v, v, -1),
                        transpose_out=flip, in_bound=p_i.in_bound,
                        lazy=lazy_a,
                    ).reshape(t, -1)
                    for i in range(nb)
                ], axis=0) if nb > 1 else mrmc_matrix_apply(
                    mod, mat, x.reshape(v, v, -1), transpose_out=flip,
                    in_bound=p_i.in_bound, lazy=lazy_a,
                ).reshape(n, -1)
            fold = p_i.has(RP.FOLD_MIX)
            if op.has_rc:
                a, b = op.rc_slice
                # storage order: already oriented; fold-mix keeps the sum
                # raw (< 2q) and defers into the mix's terminal reduce
                x = x + rc[a:b] if fold else mod.add(x, rc[a:b])
            if op.mix_branches:
                L, R_ = x[:t], x[t:]
                if fold:
                    mix_in = mod.q * (2 if op.has_rc else 1)
                    s = L + R_                      # < 2·mix_in
                    x = mod.reduce(
                        jnp.concatenate([s + L, s + R_], axis=0),
                        3 * mix_in)                 # ONE terminal reduce
                else:
                    s = mod.add(L, R_)  # (2L + R, L + 2R) = (s + L, s + R)
                    x = jnp.concatenate([mod.add(s, L), mod.add(s, R_)],
                                        axis=0)
        elif isinstance(op, S.NONLINEAR):
            if op.kind == "cube":
                x = mod.cube(x)
            elif op.orientation == S.TRANSPOSED:
                x = jnp.concatenate([
                    _feistel_transposed(mod, v, x[i * t : (i + 1) * t])
                    for i in range(nb)
                ], axis=0)
            else:
                x = jnp.concatenate([
                    _feistel(mod, x[i * t : (i + 1) * t]) for i in range(nb)
                ], axis=0)
        elif isinstance(op, S.TRUNCATE):
            x = x[: op.keep]
        elif isinstance(op, S.AGN) and noise_ref is not None:
            # the signed->canonical fold already lands in [0, q) (|e| < q),
            # so the one bounded add is the only reduce this path needs
            e = noise_ref[...]
            x = mod.add(x, jnp.where(
                e < 0, e + jnp.int32(mod.q), e).astype(jnp.uint32))
    o_ref[...] = x


def keystream_pallas(params: CipherParams, key_n1, rc_cl, noise_ll=None, *,
                     interpret: bool, schedule: Schedule | None = None,
                     mats_ml=None, reduction: str = RP.DEFAULT_REDUCTION,
                     plan=None):
    """key_n1: (n, 1) u32; rc_cl: (n_consts, lanes) u32 in logical order;
    noise_ll: (l, lanes) int32 or None; mats_ml: (n_matrix_constants,
    lanes) u32 or None — dense matrix planes in logical order for
    stream-sourced MRMC schedules (PASTA).  Returns (l, lanes) u32
    keystream (lane-major).

    Ragged lane counts are padded up to a BLK multiple and trimmed on the
    way out, so any farm window size compiles (the pad lanes compute junk
    keystream that is discarded).  ``schedule`` defaults to the normal
    variant of ``build_schedule(params)``.  ``reduction`` picks the
    reduction-scheduling mode ("lazy"/"eager", core/redplan.py; bit-exact
    either way); an explicit ``plan`` overrides it and is validated
    against the terminal-reduction law first.
    """
    p = params
    if schedule is None:
        schedule = build_schedule(p)
    if plan is None:
        plan = RP.plan_reductions(p, schedule, reduction)
    plan.validate(schedule)
    n_mat = schedule.n_matrix_constants
    if n_mat and (mats_ml is None or mats_ml.shape[0] != n_mat):
        got = None if mats_ml is None else mats_ml.shape[0]
        raise ValueError(
            f"schedule {schedule.name} streams its affine matrices: "
            f"mats_ml first dim {got} != {n_mat}"
        )
    lanes = rc_cl.shape[-1]
    pad = (-lanes) % BLK
    if pad:
        rc_cl = jnp.pad(rc_cl, ((0, 0), (0, pad)))
        if noise_ll is not None:
            noise_ll = jnp.pad(noise_ll, ((0, 0), (0, pad)))
        if n_mat:
            mats_ml = jnp.pad(mats_ml, ((0, 0), (0, pad)))
    padded = lanes + pad
    nc = p.n_round_constants

    # deliver constants in storage order (transposed ARK slices pre-permuted
    # — the RNG-FIFO ordering the datapath consumes) and both key
    # orientations; static gathers on tiny host-visible arrays, outside the
    # kernel
    rc_perm = schedule.rc_storage_perm()
    if rc_perm is not None:
        rc_cl = rc_cl[rc_perm]
    # matrix planes ride the same storage-order FIFO: each stream op's
    # (t, t) blocks are pre-permuted so stored-state in -> stored-state out
    if n_mat:
        mat_perm = schedule.mat_storage_perm()
        if mat_perm is not None:
            mats_ml = mats_ml[mat_perm]
    key_n2 = jnp.concatenate(
        [key_n1,
         key_n1[np.asarray(state_transpose_perm(p.v, schedule.branches))]],
        axis=1,
    )

    with_noise = noise_ll is not None
    with_mats = bool(n_mat)
    grid = (padded // BLK,)

    in_specs = [
        pl.BlockSpec((p.n, 2), lambda i: (0, 0)),       # key: replicated
        pl.BlockSpec((nc, BLK), lambda i: (0, i)),      # constants: streamed
    ]
    args = [key_n2, rc_cl]
    if with_noise:
        in_specs.append(pl.BlockSpec((p.l, BLK), lambda i: (0, i)))
        args.append(noise_ll)
    if with_mats:
        # matrix planes: streamed per grid step exactly like rc — the
        # double-buffered constants FIFO, ~t× deeper
        in_specs.append(pl.BlockSpec((n_mat, BLK), lambda i: (0, i)))
        args.append(mats_ml)

    kernel = functools.partial(_keystream_kernel, p, schedule, plan,
                               with_noise, with_mats)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((p.l, BLK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((p.l, padded), jnp.uint32),
        interpret=interpret,
    )(*args)
    return out[:, :lanes] if pad else out
