"""Pallas kernel: fully fused HERA/Rubato stream-key generation.

This is the accelerator itself (paper §IV), re-architected for TPU — the
T1–T4 technique mapping below is documented in docs/DESIGN.md §3:

  * T1 (vectorization + function overlapping) → the *entire* r-round cipher
    is one kernel; the state lives in VMEM/vregs from initial ARK to final
    output.  Between "functional modules" (ARK, MRMC, Cube/Feistel) there is
    no HBM traffic at all — the strongest possible form of the paper's
    module-overlap: on TPU, modules are fused ops on register-resident data.
  * T2 (MRMC transposition-invariance) → MixColumns/MixRows execute as one
    algebraic unit M_v·X·M_vᵀ with no transpose materialization or relayout
    (see kernels/mrmc/mrmc.py, shared implementation).
  * T3 (RNG decoupling) → round constants are an *input* streamed through
    `BlockSpec` grid pipelining.  Pallas double-buffers input blocks: while
    block i computes, block i+1's constants are DMA'd HBM→VMEM — the FIFO
    between the AES producer and the round consumer, depth 2, in hardware.
  * T4 (shift-add) → no integer multiply in the linear layers; the modular
    multiplies that remain (key schedule, Cube/Feistel) use the 14-bit limb
    scheme, uint32 only.

Layout: lane-major (state dim on sublanes, keystream lanes on vector lanes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.params import CipherParams

from repro.crypto.modmath import Modulus
from repro.kernels.mrmc.mrmc import mrmc_matrix_apply

BLK = 128  # keystream lanes per grid step


def _feistel(mod: Modulus, x):
    """y_1 = x_1; y_i = x_i + x_{i-1}^2 — on (n, BLK) lane-major state."""
    sq = mod.mul(x[:-1], x[:-1])
    shifted = jnp.concatenate([jnp.zeros_like(x[:1]), sq], axis=0)
    return mod.add(x, shifted)


def _keystream_kernel(params: CipherParams, with_noise: bool, *refs):
    if with_noise:
        key_ref, rc_ref, noise_ref, o_ref = refs
    else:
        key_ref, rc_ref, o_ref = refs
        noise_ref = None

    p = params
    mod = p.mod
    mat = p.mix_matrix()
    n, l, v, r = p.n, p.l, p.v, p.rounds

    key = key_ref[...]          # (n, 1) — broadcasts against (n, BLK)
    rc = rc_ref[...]            # (n_round_constants, BLK)
    # ic = (1, ..., n) built in-kernel (n < q, so no reduction needed)
    x = jax.lax.broadcasted_iota(
        jnp.uint32, (n, rc.shape[-1]), 0
    ) + jnp.uint32(1)

    def ark(x, rc_slice, keyv):
        return mod.add(x, mod.mul(keyv, rc_slice))

    def mrmc(x):
        X = x.reshape(v, v, -1)
        return mrmc_matrix_apply(mod, mat, X).reshape(n, -1)

    if p.kind == "hera":
        rcs = [rc[i * n : (i + 1) * n] for i in range(p.n_arks)]
        x = ark(x, rcs[0], key)
        for j in range(1, r):
            x = mrmc(x)
            x = mod.cube(x)
            x = ark(x, rcs[j], key)
        x = mrmc(x)
        x = mod.cube(x)
        x = mrmc(x)
        x = ark(x, rcs[r], key)
        o_ref[...] = x
        return

    # rubato
    x = ark(x, rc[0:n], key)
    for j in range(1, r):
        x = mrmc(x)
        x = _feistel(mod, x)
        x = ark(x, rc[j * n : (j + 1) * n], key)
    x = mrmc(x)
    x = _feistel(mod, x)
    x = mrmc(x)
    x = x[:l]
    x = ark(x, rc[r * n : r * n + l], key[:l])
    if noise_ref is not None:
        e = noise_ref[...]
        x = mod.add(x, mod.reduce(
            jnp.where(e < 0, e + jnp.int32(mod.q), e).astype(jnp.uint32),
            2 * mod.q,
        ))
    o_ref[...] = x


def keystream_pallas(params: CipherParams, key_n1, rc_cl, noise_ll=None, *,
                     interpret: bool):
    """key_n1: (n, 1) u32; rc_cl: (n_consts, lanes) u32;
    noise_ll: (l, lanes) int32 or None.  lanes % BLK == 0.
    Returns (l, lanes) u32 keystream (lane-major)."""
    p = params
    lanes = rc_cl.shape[-1]
    assert lanes % BLK == 0, lanes
    nc = p.n_round_constants
    with_noise = noise_ll is not None
    grid = (lanes // BLK,)

    in_specs = [
        pl.BlockSpec((p.n, 1), lambda i: (0, 0)),       # key: replicated
        pl.BlockSpec((nc, BLK), lambda i: (0, i)),      # constants: streamed
    ]
    args = [key_n1, rc_cl]
    if with_noise:
        in_specs.append(pl.BlockSpec((p.l, BLK), lambda i: (0, i)))
        args.append(noise_ll)

    kernel = functools.partial(_keystream_kernel, p, with_noise)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((p.l, BLK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((p.l, lanes), jnp.uint32),
        interpret=interpret,
    )(*args)
