from repro.kernels.aes.ops import aes_ctr_kernel_apply

__all__ = ["aes_ctr_kernel_apply"]
