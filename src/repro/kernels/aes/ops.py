"""Public jit'd wrapper for the AES-CTR Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.aes.aes import BLK, aes_ctr_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def aes_ctr_kernel_apply(round_keys, nonce12, counters,
                         interpret: bool | None = None):
    """round_keys: (11,16) u8/u32; nonce12: (12,) u8/u32; counters: (lanes,)
    u32.  Returns (lanes, 16) uint8 keystream blocks."""
    if interpret is None:
        interpret = _auto_interpret()
    rk = jnp.asarray(round_keys, jnp.uint32)[..., None]      # (11,16,1)
    nonce = jnp.asarray(nonce12, jnp.uint32)[:, None]        # (12,1)
    counters = jnp.asarray(counters, jnp.uint32)
    lanes = counters.shape[0]
    pad = (-lanes) % BLK
    c = jnp.pad(counters, (0, pad))[None, :]                 # (1, lanes_p)
    out = aes_ctr_pallas(rk, nonce, c, interpret=interpret)  # (16, lanes_p)
    return out.T[:lanes].astype(jnp.uint8)
