"""Pallas kernel: batched AES-128-CTR keystream — the XOF producer.

Hardware adaptation of the paper's §IV-D choice (AES over SHAKE256 for
throughput): on TPU, the byte-table S-box lookup is the hostile operation
(gathers don't vectorize on the VPU), so SubBytes is re-expressed as a
one-hot × table **matmul on the MXU** — exact, because both the one-hot
matrix and the table values (≤255) are exactly representable in f32.
ShiftRows is a static sublane permutation; MixColumns is xtime bitwise
algebra in uint32 lanes; AddRoundKey is an XOR against a replicated round
key.  Counter-mode blocks are built in-kernel from the lane counter.

Layout: lane-major (16 state bytes on sublanes, CTR lanes on vector lanes).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.crypto.aes import _SBOX_NP, _SHIFTROWS_PERM

BLK = 128  # counters per grid step


def _sub_bytes_mxu(state, sbox):
    """S-box via one-hot matmul: state (16, BLK) u32, sbox (256,) f32."""
    idx = state.astype(jnp.int32)
    # one-hot (16, BLK, 256) f32; contraction over the 256 axis on the MXU
    iota = jax.lax.broadcasted_iota(jnp.int32, (16, state.shape[1], 256), 2)
    onehot = (iota == idx[..., None]).astype(jnp.float32)
    out = jax.lax.dot_general(
        onehot, sbox,
        dimension_numbers=(((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return out.astype(jnp.uint32)


def _xtime(x):
    m = jnp.uint32(0xFF)
    hi = (x & jnp.uint32(0x80)) != 0
    return ((x << 1) & m) ^ jnp.where(hi, jnp.uint32(0x1B), jnp.uint32(0))


def _shift_rows(state):
    rows = [state[int(i)] for i in _SHIFTROWS_PERM]
    return jnp.stack(rows, axis=0)


def _mix_columns(state):
    cols = []
    for c in range(4):
        a0, a1, a2, a3 = (state[4 * c + r] for r in range(4))
        x0, x1, x2, x3 = _xtime(a0), _xtime(a1), _xtime(a2), _xtime(a3)
        cols += [
            x0 ^ (x1 ^ a1) ^ a2 ^ a3,
            a0 ^ x1 ^ (x2 ^ a2) ^ a3,
            a0 ^ a1 ^ x2 ^ (x3 ^ a3),
            (x0 ^ a0) ^ a1 ^ a2 ^ x3,
        ]
    return jnp.stack(cols, axis=0)


def _aes_kernel(rk_ref, nonce_ref, sbox_ref, ctr_ref, o_ref):
    rk = rk_ref[...]        # (11, 16, 1) u32
    nonce = nonce_ref[...]  # (12, 1) u32
    sbox = sbox_ref[...][:, 0]  # (256,) f32
    ctr = ctr_ref[...]      # (1, BLK) u32

    blk = ctr.shape[-1]
    ctr_rows = jnp.concatenate(
        [
            (ctr >> 24) & jnp.uint32(0xFF),
            (ctr >> 16) & jnp.uint32(0xFF),
            (ctr >> 8) & jnp.uint32(0xFF),
            ctr & jnp.uint32(0xFF),
        ],
        axis=0,
    )                                           # (4, BLK)
    state = jnp.concatenate(
        [jnp.broadcast_to(nonce, (12, blk)), ctr_rows], axis=0
    )                                           # (16, BLK)

    state = state ^ rk[0]
    for rnd in range(1, 10):
        state = _sub_bytes_mxu(state, sbox)
        state = _shift_rows(state)
        state = _mix_columns(state)
        state = state ^ rk[rnd]
    state = _sub_bytes_mxu(state, sbox)
    state = _shift_rows(state)
    o_ref[...] = state ^ rk[10]


def aes_ctr_pallas(rk_u32, nonce_u32, counters, *, interpret: bool):
    """rk_u32: (11,16,1) u32; nonce_u32: (12,1) u32; counters: (1, lanes) u32
    with lanes % BLK == 0.  Returns (16, lanes) u32 keystream bytes."""
    lanes = counters.shape[-1]
    assert lanes % BLK == 0, lanes
    sbox = jnp.asarray(_SBOX_NP.astype(np.float32))[:, None]  # (256, 1)
    return pl.pallas_call(
        _aes_kernel,
        grid=(lanes // BLK,),
        in_specs=[
            pl.BlockSpec((11, 16, 1), lambda i: (0, 0, 0)),
            pl.BlockSpec((12, 1), lambda i: (0, 0)),
            pl.BlockSpec((256, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, BLK), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((16, BLK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((16, lanes), jnp.uint32),
        interpret=interpret,
    )(rk_u32, nonce_u32, sbox, counters)
