"""Pure-jnp oracle for the AES-CTR kernel: the FIPS-validated crypto.aes."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.crypto import aes as aes_mod


def aes_ctr_ref(round_keys, nonce12, counters):
    """round_keys: (11,16) u8; nonce12: (12,) u8; counters: (lanes,) u32.
    Returns (lanes, 16) uint8 keystream blocks (big-endian counter)."""
    counters = jnp.asarray(counters, jnp.uint32)
    lanes = counters.shape[0]
    b = jnp.stack(
        [
            (counters >> 24).astype(jnp.uint8),
            (counters >> 16).astype(jnp.uint8),
            (counters >> 8).astype(jnp.uint8),
            counters.astype(jnp.uint8),
        ],
        axis=-1,
    )
    prefix = jnp.broadcast_to(
        jnp.asarray(np.asarray(nonce12, np.uint8)), (lanes, 12)
    )
    blocks = jnp.concatenate([prefix, b], axis=-1)
    return aes_mod.aes128_encrypt_blocks(blocks, jnp.asarray(round_keys))
