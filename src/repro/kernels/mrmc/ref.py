"""Pure-jnp oracle for the MRMC kernel: delegates to the core round module
(single source of truth for cipher semantics)."""

from __future__ import annotations

from repro.core import rounds as R
from repro.core.params import CipherParams


def mrmc_ref(params: CipherParams, x):
    """x: (lanes, n) uint32 row-major states -> (lanes, n) MRMC output."""
    return R.mrmc(params, x)
