from repro.kernels.mrmc.ops import mrmc_kernel_apply

__all__ = ["mrmc_kernel_apply"]
