"""Public jit'd wrapper for the MRMC kernel: row-major (lanes, n) API,
lane padding, layout transform to/from the kernel's lane-major (v, v, BLK)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.params import CipherParams
from repro.kernels.mrmc.mrmc import BLK, mrmc_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("params", "interpret"))
def mrmc_kernel_apply(params: CipherParams, x, interpret: bool | None = None):
    """x: (lanes, n) uint32 row-major states -> (lanes, n) MRMC output.

    Branch-aware: a multi-branch state (PASTA, n = branches·v²) applies the
    same per-branch matrix, so branches fold into the kernel's lane axis —
    (lanes, b, v, v) becomes a (v, v, lanes·b) lane-major block and the
    kernel is oblivious to where lanes end and branches begin.
    """
    if interpret is None:
        interpret = _auto_interpret()
    lanes, n = x.shape
    v, b = params.v, params.branches
    assert n == params.n
    pad = (-lanes) % BLK
    lp = lanes + pad
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    # (lanes_p, n) -> (v, v, lanes_p·b): row-major branch states onto
    # sublanes, (lane, branch) pairs on the vector lane axis
    x_vvl = xp.reshape(lp, b, v, v).transpose(2, 3, 0, 1).reshape(v, v, -1)
    o = mrmc_pallas(params, x_vvl, interpret=interpret)
    out = o.reshape(v, v, lp, b).transpose(2, 3, 0, 1).reshape(lp, n)
    return out[:lanes]
