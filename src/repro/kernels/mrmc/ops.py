"""Public jit'd wrapper for the MRMC kernel: row-major (lanes, n) API,
lane padding, layout transform to/from the kernel's lane-major (v, v, BLK)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.params import CipherParams
from repro.kernels.mrmc.mrmc import BLK, mrmc_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("params", "interpret"))
def mrmc_kernel_apply(params: CipherParams, x, interpret: bool | None = None):
    """x: (lanes, n) uint32 row-major states -> (lanes, n) MRMC output."""
    if interpret is None:
        interpret = _auto_interpret()
    lanes, n = x.shape
    v = params.v
    assert n == params.n
    pad = (-lanes) % BLK
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    # (lanes_p, n) -> (v, v, lanes_p): row-major state onto sublanes
    x_vvl = xp.reshape(lanes + pad, v, v).transpose(1, 2, 0)
    o = mrmc_pallas(params, x_vvl, interpret=interpret)
    out = o.transpose(2, 0, 1).reshape(lanes + pad, n)
    return out[:lanes]
