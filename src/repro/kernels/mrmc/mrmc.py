"""Pallas kernel: fused MixRows∘MixColumns (MRMC) = M_v · X · M_vᵀ mod q.

The paper's T2+T4 in kernel form:

  * T2 (transposition-invariance / bubble elimination): MixColumns and
    MixRows execute back-to-back on a VMEM-resident state — there is no
    transpose materialization, relayout, or HBM round-trip between them
    (the FPGA design's "bubble" maps to exactly those on TPU).
  * T4 (shift-add): M_v entries ∈ {1,2,3}, so every "multiplication" is an
    add chain with branchless conditional-subtract reduction — the kernel
    contains no integer multiply at all.

Layout: lane-major — state block is (v, v, BLK) uint32 with the keystream
lane on the 128-wide vector lane axis, state rows/cols unrolled on sublanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.params import CipherParams
from repro.crypto.modmath import Modulus

BLK = 128  # keystream lanes per grid step (one full vector-lane width)


def _scale_small(mod: Modulus, x, c: int, in_bound: int | None = None,
                 reduce_out: bool = True):
    """c·x mod q for c ∈ {0..3} as adds + conditional subtract (no multiply).

    ``reduce_out=False`` keeps the raw add chain (< c·in_bound) for a lazy
    accumulator; ``in_bound`` (default q) is the operand's exclusive bound.
    """
    b = mod.q if in_bound is None else in_bound
    if c == 0:
        return jnp.zeros_like(x)
    acc = x
    for _ in range(c - 1):
        acc = acc + x
    return mod.reduce(acc, c * b) if reduce_out else acc


def _combine(mod: Modulus, terms, bounds=None):
    """Sum of terms with interleaved reduction and ONE terminal reduce.

    ``bounds`` gives each term's exclusive static bound (default: already
    reduced, < q each — the eager policy; the reduction plan's lazy
    policy passes the raw c·in_bound term bounds instead)."""
    acc, bound = None, 0
    for i, t in enumerate(terms):
        tb = mod.q if bounds is None else bounds[i]
        if acc is None:
            acc, bound = t, tb
        else:
            if bound + tb >= 2**32:
                acc = mod.reduce(acc, bound)
                bound = mod.q
            acc = acc + t
            bound += tb
    return mod.reduce(acc, bound)


def mrmc_matrix_apply(mod: Modulus, mat: np.ndarray, x,
                      transpose_out: bool = False,
                      in_bound: int | None = None, lazy: bool = False):
    """Apply M·X·Mᵀ to x of shape (v, v, ...) — shared by this kernel and
    the fused keystream kernel (state stays wherever it lives; VMEM here).

    ``transpose_out=True`` emits (M·X·Mᵀ)ᵀ instead — the schedule IR's
    orientation flip (core/schedule.py).  Because the state dims are fully
    unrolled, the flip is a static relabeling of the output stacking axis:
    zero extra compute, no relayout — the TPU form of the paper's Eq. 2
    bubble elimination (MRMC commutes with transposition, so either
    orientation runs the identical shift-add datapath).

    ``lazy=True`` is the reduction plan's lazy-accumulate policy
    (core/redplan.py): shift-add terms stay raw and each row fires one
    terminal reduce, with MixColumns accepting operands up to
    ``in_bound`` (MixRows always sees the reduced MixColumns output).
    Same policy, hence same proof, as `Modulus.matvec_small(lazy=True)`.
    """
    v = mat.shape[0]
    if lazy:
        ib = mod.q if in_bound is None else in_bound
        a = [
            _combine(mod,
                     [_scale_small(mod, x[j], int(mat[i, j]), in_bound=ib,
                                   reduce_out=False) for j in range(v)],
                     bounds=[int(mat[i, j]) * ib for j in range(v)])
            for i in range(v)
        ]
        a = jnp.stack(a, axis=0)  # (v, v, ...), reduced
        y = [
            _combine(mod,
                     [_scale_small(mod, a[:, j], int(mat[c, j]),
                                   reduce_out=False) for j in range(v)],
                     bounds=[int(mat[c, j]) * mod.q for j in range(v)])
            for c in range(v)
        ]
        return jnp.stack(y, axis=0 if transpose_out else 1)
    # MixColumns: a[i] = Σ_j M[i,j] · x[j]   (x[j] is state row j: (v, ...))
    a = [
        _combine(mod, [_scale_small(mod, x[j], int(mat[i, j])) for j in range(v)])
        for i in range(v)
    ]
    a = jnp.stack(a, axis=0)  # (v, v, ...)
    # MixRows: y[:, c] = Σ_j M[c,j] · a[:, j]
    y = [
        _combine(mod, [_scale_small(mod, a[:, j], int(mat[c, j])) for j in range(v)])
        for c in range(v)
    ]
    # y[c] is the c-th *column* of M·X·Mᵀ: stacking on axis 1 lays columns
    # out as columns (normal); axis 0 lays them out as rows (transposed)
    return jnp.stack(y, axis=0 if transpose_out else 1)


def mrmc_dense_apply(mod: Modulus, m_ttl, x_tl,
                     x_bound: int | None = None, lazy: bool = False):
    """Per-lane dense matvec: y[i, lane] = Σ_j M[i, j, lane]·x[j, lane] mod q.

    The stream-sourced MRMC datapath (PASTA's per-block random affine
    matrices, docs/DESIGN.md §8.7): each keystream lane carries its own
    (t, t) matrix, delivered through the constants FIFO in storage order
    (`Schedule.mat_storage_perm`), so unlike the circulant path there is
    no shared host matrix and the multiplies are full modmuls.

    m_ttl: (t, t, lanes) uint32 matrix plane, entries < q;
    x_tl:  (t, lanes) uint32 state, entries < q.  Returns (t, lanes).

    Accumulation mirrors `Modulus.matvec_dense` (the lane-minor sibling):
    products < q sum raw in uint32 in `Modulus.dense_chunk_schedule`
    chunks (a reshape, one fused sum per level) with one reduce per
    chunk, then one raw fold of the reduced partials — the ONE shared
    overflow policy `Modulus.dense_accumulate_sites` proves safe.
    ``lazy=True`` is the reduction plan's lazy-dense policy: each
    product's final reduce is deferred (raw values < 3q) and the chunk
    width shrinks to match; ``x_bound`` relaxes the state-operand
    contract through the limb multiply.  Output is reduced either way.
    """
    t = x_tl.shape[0]
    if lazy:
        prods = mod.mul(m_ttl, x_tl[None, :, :], y_bound=x_bound,
                        reduce_out=False)             # (t, t, lanes), < 3q
        pb = 3 * mod.q
    else:
        prods = mod.mul(m_ttl, x_tl[None, :, :])      # (t, t, lanes), < q
        pb = mod.q
    ch, nch = mod.dense_chunk_schedule(t, pb)
    lanes = prods.shape[-1]
    s = jnp.sum(prods.reshape(t, nch, ch, lanes), axis=2,
                dtype=jnp.uint32)                     # (t, nch, lanes)
    s = mod.reduce(s, ch * pb)                        # each < q
    if nch == 1:
        return s[:, 0]
    return mod.reduce(jnp.sum(s, axis=1, dtype=jnp.uint32), nch * mod.q)


def _mrmc_kernel(mat: np.ndarray, q: int, x_ref, o_ref):
    mod = Modulus(q)
    o_ref[...] = mrmc_matrix_apply(mod, mat, x_ref[...])


def mrmc_pallas(params: CipherParams, x_vvl, *, interpret: bool):
    """x_vvl: (v, v, lanes) uint32, lanes % BLK == 0.  Returns same shape."""
    v = params.v
    lanes = x_vvl.shape[-1]
    if lanes % BLK != 0:
        raise ValueError(
            f"mrmc_pallas needs lanes % {BLK} == 0 (got {lanes}); use "
            "mrmc_kernel_apply, which pads and trims ragged lane counts"
        )
    grid = (lanes // BLK,)
    kernel = functools.partial(_mrmc_kernel, params.mix_matrix(), params.mod.q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((v, v, BLK), lambda i: (0, 0, i))],
        out_specs=pl.BlockSpec((v, v, BLK), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((v, v, lanes), jnp.uint32),
        interpret=interpret,
    )(x_vvl)
