"""Pallas kernel: fused MixRows∘MixColumns (MRMC) = M_v · X · M_vᵀ mod q.

The paper's T2+T4 in kernel form:

  * T2 (transposition-invariance / bubble elimination): MixColumns and
    MixRows execute back-to-back on a VMEM-resident state — there is no
    transpose materialization, relayout, or HBM round-trip between them
    (the FPGA design's "bubble" maps to exactly those on TPU).
  * T4 (shift-add): M_v entries ∈ {1,2,3}, so every "multiplication" is an
    add chain with branchless conditional-subtract reduction — the kernel
    contains no integer multiply at all.

Layout: lane-major — state block is (v, v, BLK) uint32 with the keystream
lane on the 128-wide vector lane axis, state rows/cols unrolled on sublanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.params import CipherParams
from repro.crypto.modmath import Modulus

BLK = 128  # keystream lanes per grid step (one full vector-lane width)


def _scale_small(mod: Modulus, x, c: int):
    """c·x mod q for c ∈ {0..3} as adds + conditional subtract (no multiply)."""
    if c == 0:
        return jnp.zeros_like(x)
    acc = x
    for _ in range(c - 1):
        acc = acc + x
    return mod.reduce(acc, c * mod.q)


def _combine(mod: Modulus, terms):
    """Sum of already-reduced terms (< q each) with interleaved reduction."""
    acc, bound = None, 0
    for t in terms:
        if acc is None:
            acc, bound = t, mod.q
        else:
            if bound + mod.q >= 2**32:
                acc = mod.reduce(acc, bound)
                bound = mod.q
            acc = acc + t
            bound += mod.q
    return mod.reduce(acc, bound)


def mrmc_matrix_apply(mod: Modulus, mat: np.ndarray, x,
                      transpose_out: bool = False):
    """Apply M·X·Mᵀ to x of shape (v, v, ...) — shared by this kernel and
    the fused keystream kernel (state stays wherever it lives; VMEM here).

    ``transpose_out=True`` emits (M·X·Mᵀ)ᵀ instead — the schedule IR's
    orientation flip (core/schedule.py).  Because the state dims are fully
    unrolled, the flip is a static relabeling of the output stacking axis:
    zero extra compute, no relayout — the TPU form of the paper's Eq. 2
    bubble elimination (MRMC commutes with transposition, so either
    orientation runs the identical shift-add datapath).
    """
    v = mat.shape[0]
    # MixColumns: a[i] = Σ_j M[i,j] · x[j]   (x[j] is state row j: (v, ...))
    a = [
        _combine(mod, [_scale_small(mod, x[j], int(mat[i, j])) for j in range(v)])
        for i in range(v)
    ]
    a = jnp.stack(a, axis=0)  # (v, v, ...)
    # MixRows: y[:, c] = Σ_j M[c,j] · a[:, j]
    y = [
        _combine(mod, [_scale_small(mod, a[:, j], int(mat[c, j])) for j in range(v)])
        for c in range(v)
    ]
    # y[c] is the c-th *column* of M·X·Mᵀ: stacking on axis 1 lays columns
    # out as columns (normal); axis 0 lays them out as rows (transposed)
    return jnp.stack(y, axis=0 if transpose_out else 1)


def mrmc_dense_apply(mod: Modulus, m_ttl, x_tl):
    """Per-lane dense matvec: y[i, lane] = Σ_j M[i, j, lane]·x[j, lane] mod q.

    The stream-sourced MRMC datapath (PASTA's per-block random affine
    matrices, docs/DESIGN.md §8.7): each keystream lane carries its own
    (t, t) matrix, delivered through the constants FIFO in storage order
    (`Schedule.mat_storage_perm`), so unlike the circulant path there is
    no shared host matrix and the multiplies are full modmuls.

    m_ttl: (t, t, lanes) uint32 matrix plane, entries < q;
    x_tl:  (t, lanes) uint32 state, entries < q.  Returns (t, lanes).

    Accumulation mirrors `Modulus.matvec_dense` (the lane-minor sibling):
    products < q sum raw in uint32 in chunks of `Modulus.dense_chunk()`
    with one reduce per chunk — the ONE shared overflow policy
    `Modulus.dense_accumulate_sites` proves safe.
    """
    t = x_tl.shape[0]
    prods = mod.mul(m_ttl, x_tl[None, :, :])          # (t, t, lanes), < q
    chunk = mod.dense_chunk()
    acc = None
    for a in range(0, t, chunk):
        b = min(t, a + chunk)
        s = jnp.sum(prods[:, a:b], axis=1, dtype=jnp.uint32)
        s = mod.reduce(s, (b - a) * mod.q)
        acc = s if acc is None else mod.reduce(acc + s, 2 * mod.q)
    return acc


def _mrmc_kernel(mat: np.ndarray, q: int, x_ref, o_ref):
    mod = Modulus(q)
    o_ref[...] = mrmc_matrix_apply(mod, mat, x_ref[...])


def mrmc_pallas(params: CipherParams, x_vvl, *, interpret: bool):
    """x_vvl: (v, v, lanes) uint32, lanes % BLK == 0.  Returns same shape."""
    v = params.v
    lanes = x_vvl.shape[-1]
    if lanes % BLK != 0:
        raise ValueError(
            f"mrmc_pallas needs lanes % {BLK} == 0 (got {lanes}); use "
            "mrmc_kernel_apply, which pads and trims ragged lane counts"
        )
    grid = (lanes // BLK,)
    kernel = functools.partial(_mrmc_kernel, params.mix_matrix(), params.mod.q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((v, v, BLK), lambda i: (0, 0, i))],
        out_specs=pl.BlockSpec((v, v, BLK), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((v, v, lanes), jnp.uint32),
        interpret=interpret,
    )(x_vvl)
