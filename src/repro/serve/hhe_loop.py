"""HHE request loop: event-driven window scheduling over the keystream farm.

The serving shape the ROADMAP targets: many concurrent client sessions
(HHEML-style batched PPML traffic), each submitting encrypt/decrypt/
keystream requests of arbitrary block counts.  The server holds ONE
symmetric key (the enclave role from `data/encrypted.py`) and a
:class:`repro.core.cipher.CipherBatch` session pool; requests are packed
lane-by-lane into fixed-size windows and run through the depth-buffered
:class:`repro.core.farm.KeystreamFarm` pipeline — so an 11-block request
from session A and a 3-block request from session B share one jit'd
dispatch, and the XOF producer for the next window overlaps the current
window's round computation.

Scheduling is EVENT-DRIVEN (PR 10's refactor away from the pull-based
`_flush_queue`): ``submit`` wakes the batcher, and a window fires the
moment the lane buffer fills (``fire_on_fill``) or when the oldest queued
lane crosses the ``deadline_s`` age bound (:meth:`HHEServer.service`, the
timer edge the async front end in `serve/server.py` drives).  Fired
windows flow through ONE long-lived :class:`repro.core.farm.FarmPipeline`,
so producer/consumer overlap spans scheduling events — two windows fired
by different submit wake-ups still double-buffer against each other.
``flush()`` remains the synchronous drain for in-process callers
(launch/serve.py) and returns responses in submission order; the window
packing (and therefore the served bytes) is identical to the old
whole-queue flush because both carve lanes through `core/farm.
pack_windows`' padding rule at the same boundaries.

Admission control: ``max_pending_lanes`` bounds the un-materialized lane
backlog (buffered + in-flight).  Over the bound, policy "reject" raises
:class:`HHEServerSaturated` (the client sees an error and can retry) and
"shed" drops the request before reserving counters (counted, invisible
to the farm).  Queue-depth, shed/reject, and fire-cause counters ride in
:meth:`latency_stats`, which now always returns a fully-populated dict —
a server that served zero windows reports zeroed percentiles instead of
raising.

Latency accounting: a request completes when the window holding its last
lane is materialized; `latency_stats` reports p50/p99 over completed
requests, the numbers `benchmarks/serve_load_bench.py` replays against.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cipher import (
    CipherBatch,
    StreamSession,
    decode_fixed,
    encode_fixed,
)
from repro.core.farm import KeystreamFarm, WindowPlan, pack_windows

OPS = ("keystream", "encrypt", "decrypt", "encrypt_tokens", "decrypt_tokens")

#: admission-control policies when the pending-lane bound is hit
OVERLOAD_POLICIES = ("reject", "shed")


class HHEServerSaturated(RuntimeError):
    """Raised by submit() under the "reject" overload policy: the pending
    window queue is at its configured bound.  Clients should back off and
    retry; nothing was reserved (no counters consumed)."""


@dataclasses.dataclass
class HHERequest:
    """One client request: ``blocks`` keystream blocks on one session.

    op="encrypt":  payload (blocks, l) float32 -> ciphertext (blocks, l) u32.
    op="decrypt":  payload (blocks, l) uint32  -> plaintext (blocks, l) f32.
    op="keystream": no payload -> raw keystream (the transciphering feed).
    op="encrypt_tokens": payload (blocks, l) int token ids (< q) ->
        ciphertext (blocks, l) u32 — exact Z_q encryption, no fixed-point
        encoding (the `launch/serve.py --encrypted` prompt/response path).
    op="decrypt_tokens": payload (blocks, l) u32 -> token ids (blocks, l)
        int32, exact.
    """

    session_id: int
    op: str = "keystream"
    payload: Optional[np.ndarray] = None
    blocks: Optional[int] = None
    delta: float = 1024.0

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown op {self.op!r}; have {OPS}")
        if self.payload is not None:
            self.payload = np.asarray(self.payload)
            if self.blocks is None:
                self.blocks = self.payload.shape[0]
            if self.payload.shape[0] != self.blocks:
                raise ValueError("payload rows != blocks")
        if self.blocks is None or self.blocks <= 0:
            raise ValueError("request needs blocks > 0 (or a payload)")


@dataclasses.dataclass
class HHEResponse:
    request: HHERequest
    result: np.ndarray        # per-op result, (blocks, l)
    block_ctrs: np.ndarray    # counters consumed (client needs these)
    latency_s: float
    seq: int = 0              # submission sequence (flush() sorts on it)


@dataclasses.dataclass
class _Entry:
    """Book-keeping for one submitted request until its last lane lands."""

    seq: int
    req: HHERequest
    ctrs: np.ndarray
    t_submit: float
    rows: np.ndarray          # (blocks, l) u32, filled window by window
    remaining: int
    # sessions can rotate while a request is queued on the OLD nonce; the
    # response must report the nonce its counters were reserved under
    nonce: bytes = b""
    generation: int = 0


class HHEServer:
    """Single-key HHE endpoint: session pool + event-driven window scheduler.

    ``engine`` picks the farm's consumer backend (any registered
    `repro.core.engine` name or instance); ``consumer``/``interpret`` are
    the legacy spellings; ``depth`` sets the farm's producer→consumer FIFO
    depth.  ``plan`` applies a measured :class:`repro.core.tuner.
    StreamPlan` in one shot — producer, engine, variant, depth, and (when
    ``window`` is not given) window size.  With ``auto_rotate`` (default),
    a session whose counter space cannot fit an incoming request is
    rotated to a fresh nonce (pending lanes on the old nonce materialize
    first), so long-running streams survive counter exhaustion without
    keystream reuse; clients observe rotations via
    ``StreamSession.generation`` and the session's current nonce.

    Scheduler knobs (all optional — defaults reproduce the classic
    submit-then-flush shape):

    * ``fire_on_fill`` (default True): a full window dispatches inside the
      submit that filled it, through the persistent farm pipeline.
    * ``deadline_s``: age bound on the oldest un-materialized lane; when
      it trips, :meth:`service` fires the part-full window (padded via
      `pack_windows`) and drains the pipeline, so tail requests are never
      parked behind an un-filled window.  None = no deadline (drain via
      ``flush``).
    * ``max_pending_lanes`` + ``overload``: admission control — over the
      bound, "reject" raises :class:`HHEServerSaturated`, "shed" drops
      the request (counted in ``latency_stats()["shed"]``) before any
      counters are reserved.
    """

    DEFAULT_WINDOW = 256

    def __init__(self, batch: CipherBatch, window: Optional[int] = None,
                 engine=None, *, consumer: Optional[str] = None, mesh=None,
                 axis: str = "data", interpret: Optional[bool] = None,
                 variant: Optional[str] = None, depth: Optional[int] = None,
                 plan=None, auto_rotate: bool = True,
                 fire_on_fill: bool = True,
                 deadline_s: Optional[float] = None,
                 max_pending_lanes: Optional[int] = None,
                 overload: str = "reject"):
        if window is None:
            window = plan.window if plan is not None else self.DEFAULT_WINDOW
        if window <= 0:
            raise ValueError("window must be positive")
        if overload not in OVERLOAD_POLICIES:
            raise ValueError(
                f"unknown overload policy {overload!r}; "
                f"have {OVERLOAD_POLICIES}")
        if max_pending_lanes is not None and max_pending_lanes < window:
            raise ValueError(
                f"max_pending_lanes={max_pending_lanes} below one window "
                f"({window}): no request could ever complete")
        if deadline_s is not None and deadline_s < 0:
            raise ValueError("deadline_s must be >= 0")
        self.batch = batch
        self.window = window
        self.auto_rotate = auto_rotate
        self.fire_on_fill = fire_on_fill
        self.deadline_s = deadline_s
        self.max_pending_lanes = max_pending_lanes
        self.overload = overload
        self.farm = KeystreamFarm(batch, engine=engine, consumer=consumer,
                                  mesh=mesh, axis=axis, interpret=interpret,
                                  variant=variant, depth=depth, plan=plan)
        # ONE long-lived pipeline: windows fired by different scheduling
        # events still overlap producer-vs-consumer across the FIFO
        self._pipe = self.farm.pipeline()
        # undispatched lanes: [entry, ctrs int64 array, consumed offset]
        self._frags: Deque[list] = deque()
        self._buffered = 0                # lanes in _frags
        self._inflight = 0                # valid lanes dispatched, unmaterialized
        self._pending_windows: Deque[WindowPlan] = deque()
        self._completed: List[HHEResponse] = []
        self._seq = 0
        self.latencies: List[float] = []
        self.windows_served = 0
        self.fill_fires = 0
        self.deadline_fires = 0
        self.shed_count = 0
        self.rejected_count = 0
        # submit may run on the event-loop thread while service/flush run
        # in an executor (serve/server.py) — one reentrant lock serializes
        # every scheduler mutation
        self._lock = threading.RLock()

    # ------------------------------------------------------------------
    def open_session(self, nonce=None) -> StreamSession:
        return self.batch.add_session(nonce)

    def pending_lanes(self) -> int:
        """Lanes submitted but not yet materialized (buffered + in-flight)."""
        return self._buffered + self._inflight

    def busy(self) -> bool:
        """Whether eviction/teardown would lose work: lanes pending or
        completed responses not yet collected."""
        with self._lock:
            return self.pending_lanes() > 0 or bool(self._completed)

    def warmup(self):
        """Compile the window-size programs before taking traffic (one dummy
        window re-deriving session 0's counter 0 — consumes no counters).
        Compiles against the CURRENT session-pool size; growing the pool
        afterwards retraces the producer on its next dispatch."""
        if not self.batch.sessions:
            raise RuntimeError("open a session before warmup")
        plan = WindowPlan(np.zeros(self.window, np.int64),
                          np.zeros(self.window, np.int64))
        jax.block_until_ready(self.farm.run_one(plan))

    # ------------------------------------------------------------------
    def submit(self, req: HHERequest) -> Optional[np.ndarray]:
        """Admit + queue a request; counters are reserved immediately (the
        client learns them synchronously and can pre-share them).  Returns
        the reserved counters, or None when the request was shed.  If the
        request fills one or more windows and ``fire_on_fill`` is set,
        they dispatch before submit returns — the submit IS the wake-up
        event."""
        with self._lock:
            entry = self.submit_entry(req)
            return None if entry is None else entry.ctrs

    def submit_entry(self, req: HHERequest) -> Optional[_Entry]:
        """submit(), but returns the internal entry (the async front end
        correlates responses by ``entry.seq``)."""
        with self._lock:
            if not 0 <= req.session_id < len(self.batch.sessions):
                raise KeyError(
                    f"unknown session {req.session_id} (pool has "
                    f"{len(self.batch.sessions)}; open_session() first)"
                )
            # admission control BEFORE any counter reservation: a shed or
            # rejected request must leave no trace in the counter space
            if (self.max_pending_lanes is not None
                    and self.pending_lanes() + req.blocks
                    > self.max_pending_lanes):
                if self.overload == "shed":
                    self.shed_count += 1
                    return None
                self.rejected_count += 1
                raise HHEServerSaturated(
                    f"pending lanes {self.pending_lanes()} + {req.blocks} "
                    f"exceed max_pending_lanes={self.max_pending_lanes}; "
                    "back off and retry")
            sess = self.batch.sessions[req.session_id]
            # fresh-session space, via the cursor so a monkeypatched
            # SESSION_CTR_LIMIT (tests) is honored
            capacity = sess.next_ctr + sess.remaining()
            # Auto-rotation is only sound for server-originated keystream:
            # decrypt payloads are bound to the OLD (nonce, counter) space,
            # so rotating would subtract fresh-nonce keystream and return
            # garbage — for those, fall through and let take_window refuse
            # loudly.
            if (self.auto_rotate and req.blocks > sess.remaining()
                    and req.op not in ("decrypt", "decrypt_tokens")
                    and req.blocks <= capacity):
                # old-nonce lanes must materialize before the table row is
                # replaced — rotation is a materialization boundary; the
                # forced responses surface via flush()/pop_completed()
                self._fire_full()
                self._fire_partial()
                self._drain()
                sess = self.batch.rotate_session(req.session_id)
            ctrs = sess.take_window(req.blocks)
            entry = _Entry(
                seq=self._seq, req=req, ctrs=ctrs,
                t_submit=time.perf_counter(),
                rows=np.empty((req.blocks, self.batch.params.l), np.uint32),
                remaining=req.blocks,
                nonce=bytes(sess.nonce), generation=sess.generation,
            )
            self._seq += 1
            self._frags.append([entry, ctrs.astype(np.int64), 0])
            self._buffered += req.blocks
            if self.fire_on_fill:
                self._fire_full()
            return entry

    # ------------------------------------------------------------------
    # window carving and firing
    # ------------------------------------------------------------------
    def _carve(self, count: int) -> WindowPlan:
        """Pop ``count`` buffered lanes into one WindowPlan (padded via
        pack_windows when part-full), tagging per-lane owners in meta."""
        sids = np.empty(count, np.int64)
        ctrs = np.empty(count, np.int64)
        owners = []
        filled = 0
        while filled < count:
            frag = self._frags[0]
            entry, ectrs, off = frag
            take = min(count - filled, ectrs.shape[0] - off)
            sids[filled:filled + take] = entry.req.session_id
            ctrs[filled:filled + take] = ectrs[off:off + take]
            owners.extend((entry, off + j) for j in range(take))
            filled += take
            if off + take == ectrs.shape[0]:
                self._frags.popleft()
            else:
                frag[2] = off + take
        self._buffered -= count
        (plan,) = pack_windows(sids, ctrs, self.window)
        plan.meta = owners
        return plan

    def _push(self, plan: WindowPlan) -> None:
        self._inflight += plan.valid
        self._pending_windows.append(plan)
        for p, z in self._pipe.push(plan):
            self._materialize(p, z)

    def _fire_full(self) -> int:
        """Dispatch every FULL buffered window (the fill event)."""
        fired = 0
        while self._buffered >= self.window:
            self._push(self._carve(self.window))
            self.fill_fires += 1
            fired += 1
        return fired

    def _fire_partial(self) -> bool:
        """Dispatch the part-full tail window, padded (deadline/flush/
        rotation edges).  No-ops when nothing is buffered — the empty-
        window dispatch the old pull loop could make is structurally
        impossible here."""
        if not self._buffered:
            return False
        self._push(self._carve(self._buffered))
        return True

    def _drain(self) -> None:
        for p, z in self._pipe.drain():
            self._materialize(p, z)

    def _materialize(self, plan: WindowPlan, z) -> None:
        z = np.asarray(jax.block_until_ready(z))
        t_now = time.perf_counter()
        self._pending_windows.popleft()
        self._inflight -= plan.valid
        self.windows_served += 1
        for j in range(plan.valid):
            entry, row = plan.meta[j]
            entry.rows[row] = z[j]
            entry.remaining -= 1
            if entry.remaining == 0:
                self._completed.append(self._respond(entry, t_now))

    def _respond(self, entry: _Entry, t_done: float) -> HHEResponse:
        req, z = entry.req, jnp.asarray(entry.rows)
        mod = self.batch.params.mod
        if req.op == "keystream":
            result = entry.rows
        elif req.op == "encrypt":
            result = np.asarray(mod.add(
                encode_fixed(mod, req.payload, req.delta), z))
        elif req.op == "encrypt_tokens":        # exact Z_q, no encoding
            result = np.asarray(mod.add(
                jnp.asarray(req.payload, jnp.uint32), z))
        elif req.op == "decrypt_tokens":
            result = np.asarray(mod.sub(
                jnp.asarray(req.payload, jnp.uint32), z
            ).astype(jnp.int32))
        else:  # decrypt
            mq = mod.sub(jnp.asarray(req.payload, jnp.uint32), z)
            result = np.asarray(decode_fixed(mod, mq, req.delta))
        lat = t_done - entry.t_submit
        self.latencies.append(lat)
        return HHEResponse(request=req, result=result,
                           block_ctrs=entry.ctrs, latency_s=lat,
                           seq=entry.seq)

    # ------------------------------------------------------------------
    # scheduler edges
    # ------------------------------------------------------------------
    def _oldest_pending_t(self) -> Optional[float]:
        if self._pending_windows:
            return self._pending_windows[0].meta[0][0].t_submit
        if self._frags:
            return self._frags[0][0].t_submit
        return None

    def next_due(self) -> Optional[float]:
        """perf_counter() time the deadline edge next trips, or None."""
        with self._lock:
            if self.deadline_s is None:
                return None
            t = self._oldest_pending_t()
            return None if t is None else t + self.deadline_s

    def service(self, now: Optional[float] = None) -> List[HHEResponse]:
        """The timer edge: fire any full windows (for schedulers running
        with ``fire_on_fill=False``), then — if the oldest un-materialized
        lane is older than ``deadline_s`` — fire the part-full window and
        drain the pipeline so everything pending lands.  Returns newly
        completed responses (submission-ordered)."""
        with self._lock:
            self._fire_full()
            if self.deadline_s is not None:
                t = self._oldest_pending_t()
                now = time.perf_counter() if now is None else now
                if t is not None and now - t >= self.deadline_s:
                    self._fire_partial()
                    self._drain()
                    self.deadline_fires += 1
            return self.pop_completed()

    def flush(self) -> List[HHEResponse]:
        """Force everything pending through the farm; returns responses in
        submission order (including any materialized early by fill or
        deadline fires).  Short-circuits the window dispatch when no lanes
        are pending — a drained server never runs an empty window."""
        with self._lock:
            self.quiesce()
            return self.pop_completed()

    def quiesce(self) -> None:
        """Materialize everything pending WITHOUT collecting responses —
        they stay queued for the next pop_completed()/flush().  The
        rotation/eviction boundary for callers (serve/tenants.py) that
        don't own response delivery."""
        with self._lock:
            if self._buffered:
                self._fire_full()
                self._fire_partial()
            self._drain()

    def pop_completed(self) -> List[HHEResponse]:
        """Collect responses completed since the last collection, in
        submission order."""
        with self._lock:
            out, self._completed = self._completed, []
            out.sort(key=lambda r: r.seq)
            return out

    # ------------------------------------------------------------------
    def latency_stats(self) -> dict:
        """Always fully populated — zeroed percentiles before any window
        has served (the empty-percentile crash is gone), plus scheduler/
        admission counters."""
        with self._lock:
            stats = {"count": 0, "p50_ms": 0.0, "p99_ms": 0.0,
                     "mean_ms": 0.0}
            if self.latencies:
                lat = np.asarray(self.latencies)
                stats = {
                    "count": int(lat.size),
                    "p50_ms": float(np.percentile(lat, 50) * 1e3),
                    "p99_ms": float(np.percentile(lat, 99) * 1e3),
                    "mean_ms": float(lat.mean() * 1e3),
                }
            stats.update(
                queue_depth_lanes=self._buffered,
                inflight_lanes=self._inflight,
                windows_served=self.windows_served,
                fill_fires=self.fill_fires,
                deadline_fires=self.deadline_fires,
                shed=self.shed_count,
                rejected=self.rejected_count,
            )
            return stats
