"""HHE request loop: ragged multi-session traffic over the keystream farm.

The serving shape the ROADMAP targets: many concurrent client sessions
(HHEML-style batched PPML traffic), each submitting encrypt/decrypt/
keystream requests of arbitrary block counts.  The server holds ONE
symmetric key (the enclave role from `data/encrypted.py`) and a
:class:`repro.core.cipher.CipherBatch` session pool; requests are packed
lane-by-lane into fixed-size windows and run through the double-buffered
:class:`repro.core.farm.KeystreamFarm` pipeline — so an 11-block request
from session A and a 3-block request from session B share one jit'd
dispatch, and the XOF producer for the next window overlaps the current
window's round computation.

Fixed windows mean the server compiles exactly two XLA programs total, no
matter how ragged the traffic; the tail window is padded with repeated
lanes (recomputed keystream, discarded — never fresh counters, so the
counter space stays dense).

Latency accounting: a request completes when the window holding its last
lane is materialized; `latency_stats` reports p50/p99 over completed
requests, the numbers `benchmarks/keystream_farm_bench.py` tabulates.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cipher import (
    CipherBatch,
    StreamSession,
    decode_fixed,
    encode_fixed,
)
from repro.core.farm import KeystreamFarm, WindowPlan, pack_windows

OPS = ("keystream", "encrypt", "decrypt", "encrypt_tokens", "decrypt_tokens")


@dataclasses.dataclass
class HHERequest:
    """One client request: ``blocks`` keystream blocks on one session.

    op="encrypt":  payload (blocks, l) float32 -> ciphertext (blocks, l) u32.
    op="decrypt":  payload (blocks, l) uint32  -> plaintext (blocks, l) f32.
    op="keystream": no payload -> raw keystream (the transciphering feed).
    op="encrypt_tokens": payload (blocks, l) int token ids (< q) ->
        ciphertext (blocks, l) u32 — exact Z_q encryption, no fixed-point
        encoding (the `launch/serve.py --encrypted` prompt/response path).
    op="decrypt_tokens": payload (blocks, l) u32 -> token ids (blocks, l)
        int32, exact.
    """

    session_id: int
    op: str = "keystream"
    payload: Optional[np.ndarray] = None
    blocks: Optional[int] = None
    delta: float = 1024.0

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown op {self.op!r}; have {OPS}")
        if self.payload is not None:
            self.payload = np.asarray(self.payload)
            if self.blocks is None:
                self.blocks = self.payload.shape[0]
            if self.payload.shape[0] != self.blocks:
                raise ValueError("payload rows != blocks")
        if self.blocks is None or self.blocks <= 0:
            raise ValueError("request needs blocks > 0 (or a payload)")


@dataclasses.dataclass
class HHEResponse:
    request: HHERequest
    result: np.ndarray        # per-op result, (blocks, l)
    block_ctrs: np.ndarray    # counters consumed (client needs these)
    latency_s: float


class HHEServer:
    """Single-key HHE endpoint: session pool + windowed farm pipeline.

    ``engine`` picks the farm's consumer backend (any registered
    `repro.core.engine` name or instance); ``consumer``/``interpret`` are
    the legacy spellings; ``depth`` sets the farm's producer→consumer FIFO
    depth.  ``plan`` applies a measured :class:`repro.core.tuner.
    StreamPlan` in one shot — producer, engine, variant, depth, and (when
    ``window`` is not given) window size.  With ``auto_rotate`` (default),
    a session whose counter space cannot fit an incoming request is
    rotated to a fresh nonce (pending lanes on the old nonce are flushed
    first), so long-running streams survive counter exhaustion without
    keystream reuse; clients observe rotations via
    ``StreamSession.generation`` and the session's current nonce.
    """

    DEFAULT_WINDOW = 256

    def __init__(self, batch: CipherBatch, window: Optional[int] = None,
                 engine=None, *, consumer: Optional[str] = None, mesh=None,
                 axis: str = "data", interpret: Optional[bool] = None,
                 variant: Optional[str] = None, depth: Optional[int] = None,
                 plan=None, auto_rotate: bool = True):
        if window is None:
            window = plan.window if plan is not None else self.DEFAULT_WINDOW
        if window <= 0:
            raise ValueError("window must be positive")
        self.batch = batch
        self.window = window
        self.auto_rotate = auto_rotate
        self.farm = KeystreamFarm(batch, engine=engine, consumer=consumer,
                                  mesh=mesh, axis=axis, interpret=interpret,
                                  variant=variant, depth=depth, plan=plan)
        self._queue: List[tuple] = []     # (request, ctrs, t_submit)
        self._done: List[HHEResponse] = []   # rotation-forced early flushes
        self.latencies: List[float] = []

    # ------------------------------------------------------------------
    def open_session(self, nonce=None) -> StreamSession:
        return self.batch.add_session(nonce)

    def submit(self, req: HHERequest) -> np.ndarray:
        """Queue a request; counters are reserved immediately (the client
        learns them synchronously and can pre-share them)."""
        if not 0 <= req.session_id < len(self.batch.sessions):
            raise KeyError(
                f"unknown session {req.session_id} "
                f"(pool has {len(self.batch.sessions)}; open_session() first)"
            )
        sess = self.batch.sessions[req.session_id]
        # fresh-session space, via the cursor so a monkeypatched
        # SESSION_CTR_LIMIT (tests) is honored
        capacity = sess.next_ctr + sess.remaining()
        # Auto-rotation is only sound for server-originated keystream:
        # decrypt payloads are bound to the OLD (nonce, counter) space, so
        # rotating would subtract fresh-nonce keystream and return garbage
        # — for those, fall through and let take_window refuse loudly.
        if (self.auto_rotate and req.blocks > sess.remaining()
                and req.op not in ("decrypt", "decrypt_tokens")
                and req.blocks <= capacity):
            # old-nonce lanes must materialize before the table row is
            # replaced — rotation is a flush boundary.  The forced flush's
            # responses are buffered and handed out by the next flush().
            self._done.extend(self._flush_queue())
            sess = self.batch.rotate_session(req.session_id)
        ctrs = sess.take_window(req.blocks)
        self._queue.append((req, ctrs, time.perf_counter()))
        return ctrs

    def pending_lanes(self) -> int:
        return sum(req.blocks for req, _, _ in self._queue)

    def warmup(self):
        """Compile the window-size programs before taking traffic (one dummy
        window re-deriving session 0's counter 0 — consumes no counters).
        Compiles against the CURRENT session-pool size; growing the pool
        afterwards retraces the producer on its next dispatch."""
        if not self.batch.sessions:
            raise RuntimeError("open a session before warmup")
        plan = WindowPlan(np.zeros(self.window, np.int64),
                          np.zeros(self.window, np.int64))
        jax.block_until_ready(self.farm.consume(self.farm.produce(plan)))

    # ------------------------------------------------------------------
    @staticmethod
    def _pack(queue):
        """Flatten queued requests into lane arrays + per-lane owner map."""
        sids, ctrs, owners = [], [], []
        for ridx, (req, rctrs, _) in enumerate(queue):
            sids.append(np.full(req.blocks, req.session_id, np.int64))
            ctrs.append(rctrs.astype(np.int64))
            owners.append(
                np.stack([np.full(req.blocks, ridx, np.int64),
                          np.arange(req.blocks, dtype=np.int64)], axis=1))
        return (np.concatenate(sids), np.concatenate(ctrs),
                np.concatenate(owners))

    def flush(self) -> List[HHEResponse]:
        """Run all queued requests through the farm; returns responses in
        submission order (including any materialized early by a rotation-
        forced flush)."""
        done, self._done = self._done, []
        return done + self._flush_queue()

    def _flush_queue(self) -> List[HHEResponse]:
        if not self._queue:
            return []
        queue, self._queue = self._queue, []
        sids, ctrs, owners = self._pack(queue)

        # ragged tails pad + trim in ONE place (core/farm.pack_windows);
        # plan.valid marks where the real lanes end
        plans = pack_windows(sids, ctrs, self.window)

        l = self.batch.params.l
        rows = [np.empty((req.blocks, l), np.uint32) for req, _, _ in queue]
        remaining = [req.blocks for req, _, _ in queue]
        done_t = [0.0] * len(queue)
        for widx, (plan, z) in enumerate(self.farm.run(plans)):
            z = np.asarray(jax.block_until_ready(z))
            t_now = time.perf_counter()
            lo = widx * self.window
            for j in range(plan.valid):
                ridx, row = owners[lo + j]
                rows[ridx][row] = z[j]
                remaining[ridx] -= 1
                if remaining[ridx] == 0:
                    done_t[ridx] = t_now

        mod = self.batch.params.mod
        out = []
        for (req, rctrs, t_sub), zreq, t_done in zip(queue, rows, done_t):
            z = jnp.asarray(zreq)
            if req.op == "keystream":
                result = zreq
            elif req.op == "encrypt":
                result = np.asarray(mod.add(
                    encode_fixed(mod, req.payload, req.delta), z))
            elif req.op == "encrypt_tokens":    # exact Z_q, no encoding
                result = np.asarray(mod.add(
                    jnp.asarray(req.payload, jnp.uint32), z))
            elif req.op == "decrypt_tokens":
                result = np.asarray(mod.sub(
                    jnp.asarray(req.payload, jnp.uint32), z
                ).astype(jnp.int32))
            else:  # decrypt
                mq = mod.sub(jnp.asarray(req.payload, jnp.uint32), z)
                result = np.asarray(decode_fixed(mod, mq, req.delta))
            lat = t_done - t_sub
            self.latencies.append(lat)
            out.append(HHEResponse(request=req, result=result,
                                   block_ctrs=rctrs, latency_s=lat))
        return out

    # ------------------------------------------------------------------
    def latency_stats(self) -> dict:
        if not self.latencies:
            return {"count": 0}
        lat = np.asarray(self.latencies)
        return {
            "count": int(lat.size),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "mean_ms": float(lat.mean() * 1e3),
        }
