"""Per-tenant key registry for the multi-tenant serving plane.

The single-key `HHEServer` is the right shape for one enclave, but the
"millions of users" story (ROADMAP) needs isolation between *tenants*:
each tenant owns its own symmetric key — a whole
:class:`repro.core.cipher.CipherBatch` pool plus an event-driven
:class:`repro.serve.hhe_loop.HHEServer` — and inside a tenant, per-client
*sessions* own (nonce, counter) spaces with live rotation via
`CipherBatch.rotate_session`.  A cross-tenant key leak is structurally
impossible: tenants never share a CipherBatch, an engine binding, or a
farm pipeline.

The registry is bounded: ``capacity`` caps live tenants, and creating one
past the cap evicts the least-recently-active *idle* tenant first.  A
tenant with un-materialized lanes or uncollected responses is never
evicted (``HHEServer.busy()``), so load spikes grow the registry past
capacity rather than dropping in-flight work — the overflow is visible in
:meth:`TenantRegistry.stats`.  Eviction destroys the tenant's key: a
re-attached tenant id gets a FRESH key (deterministically derived from
``tenant_id`` + registry seed, so tests and the load harness can predict
it), and ciphertexts from the evicted incarnation are unrecoverable by
design — the client-facing contract is "idle tenants must re-provision".

`serve/server.py` fronts this registry over TCP; `scripts/ci.sh`'s
serve-smoke stage drives two tenants through it end to end.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.core.cipher import CipherBatch, StreamSession
from repro.core.params import get_params
from repro.serve.hhe_loop import HHEServer


def derive_tenant_key(cipher: str, tenant_id: str, seed: int) -> np.ndarray:
    """Deterministic per-tenant key: SHA-256(tenant_id, seed) seeds the
    key sampler, so a tenant's key differs from every other tenant's and
    from the registry seed alone, while tests/benches can reconstruct it."""
    params = get_params(cipher)
    digest = hashlib.sha256(
        f"{cipher}|{tenant_id}|{seed}".encode()).digest()
    rng = np.random.default_rng(np.frombuffer(digest, np.uint64))
    return rng.integers(1, params.mod.q, size=(params.n,), dtype=np.uint32)


@dataclasses.dataclass
class Tenant:
    """One tenant's serving state: its key's pool + event-driven server."""

    tenant_id: str
    batch: CipherBatch
    server: HHEServer
    created_t: float
    last_active_t: float
    generation: int = 0       # bumped when an evicted id is re-created

    def touch(self) -> None:
        self.last_active_t = time.monotonic()


class TenantRegistry:
    """tenant_id -> :class:`Tenant`, LRU-bounded, eviction-safe for
    in-flight work.

    All per-tenant servers share the scheduler configuration given here
    (window, engine, deadline, admission bound/policy); keys never shared.
    Thread-safe: the async front end touches it from executor threads.
    """

    def __init__(self, cipher: str = "hera-80", *, capacity: int = 8,
                 window: Optional[int] = None, engine=None,
                 variant: Optional[str] = None, depth: Optional[int] = None,
                 fire_on_fill: bool = True,
                 deadline_s: Optional[float] = None,
                 max_pending_lanes: Optional[int] = None,
                 overload: str = "reject", seed: int = 0,
                 warmup: bool = False):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.cipher = cipher
        self.params = get_params(cipher)
        self.capacity = capacity
        self.seed = seed
        self.warmup = warmup
        self._server_kw = dict(
            window=window, engine=engine, variant=variant, depth=depth,
            fire_on_fill=fire_on_fill, deadline_s=deadline_s,
            max_pending_lanes=max_pending_lanes, overload=overload,
        )
        self._tenants: "OrderedDict[str, Tenant]" = OrderedDict()
        self._generations: dict = {}
        self.evictions = 0
        self.busy_overflows = 0   # creations past capacity with no evictable
        self._lock = threading.RLock()

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._tenants)

    def __contains__(self, tenant_id: str) -> bool:
        return tenant_id in self._tenants

    def tenant_ids(self):
        with self._lock:
            return list(self._tenants)

    def peek(self, tenant_id: str) -> Tenant:
        """Fetch WITHOUT LRU-touching — for pollers (the serving plane's
        deadline ticker) whose visits must not count as tenant activity."""
        with self._lock:
            t = self._tenants.get(tenant_id)
            if t is None:
                raise KeyError(f"unknown tenant {tenant_id!r}")
            return t

    def get(self, tenant_id: str, create: bool = True) -> Tenant:
        """Fetch (and LRU-touch) a tenant, creating it on first sight.

        Creation past ``capacity`` evicts the least-recently-active IDLE
        tenant; if every tenant is busy (in-flight lanes or uncollected
        responses) the registry grows instead — dropping live work to
        honor a size bound would corrupt client streams.
        """
        with self._lock:
            t = self._tenants.get(tenant_id)
            if t is not None:
                self._tenants.move_to_end(tenant_id)
                t.touch()
                return t
            if not create:
                raise KeyError(f"unknown tenant {tenant_id!r}")
            if len(self._tenants) >= self.capacity:
                self._evict_one_idle()
            t = self._create(tenant_id)
            self._tenants[tenant_id] = t
            return t

    def _create(self, tenant_id: str) -> Tenant:
        key = derive_tenant_key(self.cipher, tenant_id, self.seed)
        batch = CipherBatch(self.params, key=key,
                            seed=self.seed ^ (hash(tenant_id) & 0x7FFFFFFF))
        server = HHEServer(batch, **self._server_kw)
        if self.warmup:
            batch.add_session()
            server.warmup()
        gen = self._generations.get(tenant_id, -1) + 1
        self._generations[tenant_id] = gen
        now = time.monotonic()
        return Tenant(tenant_id=tenant_id, batch=batch, server=server,
                      created_t=now, last_active_t=now, generation=gen)

    def _evict_one_idle(self) -> bool:
        """Drop the least-recently-active tenant with NO in-flight work.
        Returns False (and counts an overflow) when everyone is busy."""
        for tid, t in self._tenants.items():      # OrderedDict = LRU order
            if not t.server.busy():
                del self._tenants[tid]
                self.evictions += 1
                return True
        self.busy_overflows += 1
        return False

    def evict(self, tenant_id: str, force: bool = False) -> bool:
        """Explicit eviction; refuses on a busy tenant unless ``force``."""
        with self._lock:
            t = self._tenants.get(tenant_id)
            if t is None:
                return False
            if t.server.busy() and not force:
                raise RuntimeError(
                    f"tenant {tenant_id!r} has in-flight work "
                    f"({t.server.pending_lanes()} lanes); flush first or "
                    "force=True")
            del self._tenants[tenant_id]
            self.evictions += 1
            return True

    # ------------------------------------------------------------------
    # per-tenant conveniences the front end calls
    # ------------------------------------------------------------------
    def open_session(self, tenant_id: str) -> StreamSession:
        t = self.get(tenant_id)
        return t.server.open_session()

    def rotate_session(self, tenant_id: str, session_id: int
                       ) -> StreamSession:
        """Live key-material rotation under traffic: materialize the
        tenant's pending lanes (old nonce), then swap in a fresh nonce via
        `CipherBatch.rotate_session` — the same flush-boundary rule the
        server's auto-rotation follows."""
        t = self.get(tenant_id, create=False)
        t.touch()
        # hold the server lock ACROSS quiesce + swap: a submit slipping in
        # between would buffer old-nonce lanes that then materialize under
        # the new nonce — garbled keystream.  quiesce (not flush) so the
        # responses stay queued for whoever owns delivery (the front end's
        # future resolution).
        with t.server._lock:
            t.server.quiesce()
            return t.batch.rotate_session(session_id)

    def stats(self) -> dict:
        with self._lock:
            return {
                "cipher": self.cipher,
                "capacity": self.capacity,
                "tenants": len(self._tenants),
                "evictions": self.evictions,
                "busy_overflows": self.busy_overflows,
                "per_tenant": {
                    tid: t.server.latency_stats()
                    for tid, t in self._tenants.items()
                },
            }
