"""Prefill / decode step factories under pjit.

`decode_*` / `long_*` dry-run cells lower exactly these: one new token
against a KV (or SSM-state) cache of seq_len.  For long_500k (batch=1) the
policy shards the *sequence* dimension of the cache across the data axis
(flash-decode-style distributed attention); otherwise batch shards over dp
and heads over tp_a."""

from __future__ import annotations


import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.sharding import ShardingPolicy
from repro.train.train_loop import act_shardings, batch_specs, _shard


def make_prefill_step(cfg: ModelConfig, policy: ShardingPolicy, max_len: int):
    mesh = policy.mesh
    pspecs = M.param_specs(cfg, policy)
    bspecs = batch_specs(cfg, policy, train=False)
    cspecs = M.cache_specs(cfg, policy)
    acts = act_shardings(cfg, policy)

    def fn(params, batch):
        return M.prefill(cfg, params, batch, max_len, shardings=acts)

    jitted = jax.jit(
        fn,
        in_shardings=(_shard(mesh, pspecs), _shard(mesh, bspecs)),
        out_shardings=(
            NamedSharding(mesh, P(policy.dp if not policy.seq_shard_data else None)),
            _shard(mesh, cspecs),
            NamedSharding(mesh, P()),
        ),
    )
    return jitted


def make_decode_step(cfg: ModelConfig, policy: ShardingPolicy):
    mesh = policy.mesh
    pspecs = M.param_specs(cfg, policy)
    cspecs = M.cache_specs(cfg, policy)
    tok_spec = P(policy.dp, None) if not policy.seq_shard_data else P(None, None)
    acts = act_shardings(cfg, policy)
    if policy.seq_shard_data:
        # batch=1 decode: logits (1,1,V) — shard vocab only
        acts = {"acts": None,
                "logits": NamedSharding(mesh, P(None, None, policy.tp_full))}

    def fn(params, cache, tokens, cur_len):
        return M.decode_step(cfg, params, cache, tokens, cur_len,
                             shardings=acts)

    jitted = jax.jit(
        fn,
        in_shardings=(
            _shard(mesh, pspecs),
            _shard(mesh, cspecs),
            NamedSharding(mesh, tok_spec),
            NamedSharding(mesh, P()),
        ),
        out_shardings=(
            NamedSharding(mesh, tok_spec),
            _shard(mesh, cspecs),
        ),
        donate_argnums=(1,),   # cache updated in place
    )
    return jitted
