"""Serving substrate: KV/SSM cache management, prefill and decode step
factories with production shardings, and the encrypted serving plane —

* `hhe_loop.py`: event-driven single-key HHE scheduler (fill/deadline
  window firing, admission control) over the double-buffered farm;
* `tenants.py`: LRU-bounded per-tenant key registry with live session
  rotation and eviction protection for in-flight work;
* `server.py`: asyncio TCP front end (length-prefixed msgpack/JSON
  frames) plus the matching :class:`~repro.serve.server.ServeClient` —
  ``python -m repro.serve.server`` runs it standalone.
"""
