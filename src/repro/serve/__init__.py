"""Serving substrate: KV/SSM cache management, prefill and decode step
factories with production shardings."""
