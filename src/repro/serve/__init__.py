"""Serving substrate: KV/SSM cache management, prefill and decode step
factories with production shardings, and the HHE request loop
(`hhe_loop.py`: many client sessions' encrypt/decrypt/keystream traffic
packed into fixed windows over the double-buffered keystream farm)."""
