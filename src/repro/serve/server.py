"""Network-native encrypted serving plane: asyncio TCP front end over the
multi-tenant :class:`repro.serve.tenants.TenantRegistry`.

    PYTHONPATH=src python -m repro.serve.server --cipher hera-80 --port 7733

Wire protocol (a schema decouples clients from the farm loop):

  * every message is a length-prefixed frame: a 5-byte header
    ``struct('>IB')`` = (body length, codec id), then the body;
  * codec 1 is msgpack (preferred when importable), codec 0 is JSON;
    ndarray payloads ride as ``{"__nd__": {dtype, shape, data}}`` with raw
    bytes under msgpack and base64 under JSON — the server answers in
    whatever codec the request used, so mixed-codec clients coexist;
  * requests are dicts with an ``op`` and a client-chosen correlation
    ``id``; responses echo ``id``.  Submit responses complete OUT OF
    ORDER on purpose — a submit only resolves when the window holding its
    last lane materializes, so a pipelined client keeps many ids in
    flight while windows fill.

Request ops:

  ``hello``        {tenant, cipher?} -> params + the tenant's key (the
                   trusted-provisioning stand-in: this repo's enclave
                   model already holds client keys server-side, see
                   `data/encrypted.py`; a production deployment would
                   swap this one response for an attested channel)
  ``open_session`` {tenant} -> {session, nonce, generation}
  ``rotate``       {tenant, session} -> fresh {nonce, generation}
                   (live rotation: pending old-nonce lanes materialize
                   first — `tenants.rotate_session`)
  ``submit``       {tenant, session, hhe_op, payload?/blocks?, delta?}
                   -> {result, ctrs, nonce, generation, latency_ms}; may
                   instead answer {error: "saturated"} (reject policy) or
                   {shed: true} (shed policy)
  ``stats``        {tenant?} -> registry/tenant scheduler stats
  ``ping``         {} -> {pong: true}

Scheduling and ordering: ALL farm-touching work (submits, rotations, the
deadline tick, stats) runs on ONE dedicated worker thread per plane.
That single worker is what makes the client's predict-the-counters
encrypt path sound — frames on a connection reach the executor queue in
read order, and a single worker reserves counters in queue order, so a
session driven by one connection sees exactly the counter sequence its
client mirrored.  It also keeps the event loop responsive: a window
dispatch (fill-fire inside a submit, or the ticker's deadline
`HHEServer.service`) blocks only the worker, never frame parsing.
Responses resolve through per-(tenant, generation, seq) futures; a
response that lands before its waiter is registered parks in an
unclaimed map until the registration catches up.
"""

from __future__ import annotations

import argparse
import asyncio
import base64
import concurrent.futures
import struct
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.serve.hhe_loop import HHERequest, HHEServerSaturated
from repro.serve.tenants import TenantRegistry

try:
    import msgpack  # type: ignore
except ImportError:          # hermetic image without msgpack: JSON only
    msgpack = None

HEADER = struct.Struct(">IB")
CODEC_JSON, CODEC_MSGPACK = 0, 1
#: refuse absurd frames before allocating (64 MiB covers any sane window)
MAX_FRAME = 64 << 20
DEFAULT_PORT = 7733


# ==========================================================================
# Frame codec
# ==========================================================================
def _nd_pack(obj, *, binary: bool):
    if isinstance(obj, np.ndarray):
        data = obj.tobytes()
        return {"__nd__": {
            "dtype": str(obj.dtype), "shape": list(obj.shape),
            "data": data if binary else base64.b64encode(data).decode(),
        }}
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, dict):
        return {k: _nd_pack(v, binary=binary) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_nd_pack(v, binary=binary) for v in obj]
    return obj


def _nd_unpack(obj):
    if isinstance(obj, dict):
        nd = obj.get("__nd__")
        if nd is not None and set(nd) >= {"dtype", "shape", "data"}:
            data = nd["data"]
            if isinstance(data, str):
                data = base64.b64decode(data)
            arr = np.frombuffer(data, dtype=np.dtype(nd["dtype"]))
            return arr.reshape(nd["shape"]).copy()
        return {k: _nd_unpack(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_nd_unpack(v) for v in obj]
    return obj


def preferred_codec() -> int:
    return CODEC_MSGPACK if msgpack is not None else CODEC_JSON


def encode_frame(msg: dict, codec: Optional[int] = None) -> bytes:
    codec = preferred_codec() if codec is None else codec
    if codec == CODEC_MSGPACK:
        if msgpack is None:
            raise RuntimeError("msgpack codec requested but not importable")
        body = msgpack.packb(_nd_pack(msg, binary=True), use_bin_type=True)
    elif codec == CODEC_JSON:
        import json
        body = json.dumps(_nd_pack(msg, binary=False)).encode()
    else:
        raise ValueError(f"unknown codec {codec}")
    if len(body) > MAX_FRAME:
        raise ValueError(f"frame of {len(body)} bytes exceeds {MAX_FRAME}")
    return HEADER.pack(len(body), codec) + body


def decode_body(body: bytes, codec: int) -> dict:
    if codec == CODEC_MSGPACK:
        if msgpack is None:
            raise ValueError("peer sent msgpack but msgpack is unavailable")
        return _nd_unpack(msgpack.unpackb(body, raw=False))
    if codec == CODEC_JSON:
        import json
        return _nd_unpack(json.loads(body.decode()))
    raise ValueError(f"unknown codec {codec}")


async def read_frame(reader: asyncio.StreamReader) -> Tuple[dict, int]:
    """One frame off the stream -> (message, codec it used)."""
    head = await reader.readexactly(HEADER.size)
    length, codec = HEADER.unpack(head)
    if length > MAX_FRAME:
        raise ValueError(f"frame of {length} bytes exceeds {MAX_FRAME}")
    body = await reader.readexactly(length)
    return decode_body(body, codec), codec


# ==========================================================================
# Server
# ==========================================================================
class ServePlane:
    """The asyncio front end: connections in, tenant-registry windows out.

    One instance owns one :class:`TenantRegistry` and one farm-worker
    thread.  Responses to submits resolve through per-(tenant_id,
    tenant_generation, seq) futures: whichever worker call materializes a
    window (a fill-fire inside some submit, the deadline ticker, or a
    rotation quiesce) collects the completed responses and resolves every
    waiter — cross-connection, since a tenant batches lanes from all its
    clients into shared windows.
    """

    def __init__(self, registry: TenantRegistry, host: str = "127.0.0.1",
                 port: int = 0, tick_s: float = 0.005):
        self.registry = registry
        self.host, self.port = host, port
        self.tick_s = tick_s
        self._server: Optional[asyncio.AbstractServer] = None
        self._ticker: Optional[asyncio.Task] = None
        # ONE worker: counter-reservation order == executor queue order ==
        # per-connection frame order (see module docstring)
        self._exec = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="hhe-farm")
        # (tenant_id, tenant_generation, seq) -> future for a submit
        self._waiters: Dict[tuple, asyncio.Future] = {}
        # responses that materialized before their waiter registered
        self._unclaimed: Dict[tuple, object] = {}
        self.connections = 0
        self.frames = 0

    # ------------------------------------------------------------------
    async def start(self) -> Tuple[str, int]:
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._ticker = asyncio.get_running_loop().create_task(
            self._tick_deadlines())
        return self.host, self.port

    async def stop(self) -> None:
        if self._ticker is not None:
            self._ticker.cancel()
            try:
                await self._ticker
            except asyncio.CancelledError:
                pass
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self._exec.shutdown(wait=True)
        for fut in self._waiters.values():
            if not fut.done():
                fut.cancel()
        self._waiters.clear()
        self._unclaimed.clear()

    async def serve_forever(self) -> None:
        assert self._server is not None, "start() first"
        async with self._server:
            await self._server.serve_forever()

    async def _farm(self, fn, *args):
        """Run farm-touching work on the plane's single worker thread."""
        return await asyncio.get_running_loop().run_in_executor(
            self._exec, fn, *args)

    # ------------------------------------------------------------------
    # waiter plumbing (every method here runs on the event-loop thread)
    # ------------------------------------------------------------------
    def _resolve(self, tenant, responses) -> None:
        """Resolve futures for responses a worker call just collected;
        park responses whose waiter isn't registered yet."""
        base = (tenant.tenant_id, tenant.generation)
        for resp in responses:
            key = (*base, resp.seq)
            fut = self._waiters.pop(key, None)
            if fut is None:
                self._unclaimed[key] = resp
            elif not fut.done():
                fut.set_result(resp)

    def _register_waiter(self, tenant, seq: int) -> asyncio.Future:
        key = (tenant.tenant_id, tenant.generation, seq)
        fut = asyncio.get_running_loop().create_future()
        resp = self._unclaimed.pop(key, None)
        if resp is not None:
            fut.set_result(resp)
        else:
            self._waiters[key] = fut
        return fut

    async def _tick_deadlines(self) -> None:
        """The timer edge: each tick, one worker pass services every
        tenant whose deadline may have tripped and collects fill-fired
        completions parked since the last pass."""
        def one_pass():
            out = []
            for tid in self.registry.tenant_ids():
                try:
                    tenant = self.registry.peek(tid)
                except KeyError:
                    continue
                due = tenant.server.next_due()
                if due is not None and time.perf_counter() >= due:
                    done = tenant.server.service()
                else:
                    done = tenant.server.pop_completed()
                if done:
                    out.append((tenant, done))
            return out

        while True:
            await asyncio.sleep(self.tick_s)
            for tenant, done in await self._farm(one_pass):
                self._resolve(tenant, done)

    # ------------------------------------------------------------------
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        self.connections += 1
        write_lock = asyncio.Lock()
        pending = set()
        try:
            while True:
                try:
                    msg, codec = await read_frame(reader)
                except (asyncio.IncompleteReadError, ConnectionResetError):
                    break
                self.frames += 1
                if msg.get("op") == "submit":
                    # submits pipeline: spawn a task so later frames on
                    # this connection are parsed while windows fill.  The
                    # task's synchronous prologue runs in creation order,
                    # so the executor queue still sees frame order.
                    task = asyncio.get_running_loop().create_task(
                        self._submit_and_reply(
                            msg, codec, writer, write_lock))
                    pending.add(task)
                    task.add_done_callback(pending.discard)
                    continue
                reply = await self._dispatch(msg)
                reply["id"] = msg.get("id")
                async with write_lock:
                    writer.write(encode_frame(reply, codec))
                    await writer.drain()
        finally:
            for task in pending:
                task.cancel()
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _dispatch(self, msg: dict) -> dict:
        op = msg.get("op")
        try:
            if op == "ping":
                return {"ok": True, "pong": True}
            if op == "hello":
                return await self._op_hello(msg)
            if op == "open_session":
                return await self._op_open_session(msg)
            if op == "rotate":
                return await self._op_rotate(msg)
            if op == "stats":
                return await self._op_stats(msg)
            return {"ok": False, "error": f"unknown op {op!r}"}
        except (KeyError, ValueError, RuntimeError) as e:
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}

    # ---- ops -----------------------------------------------------------
    async def _op_hello(self, msg: dict) -> dict:
        cipher = msg.get("cipher")
        if cipher is not None and cipher != self.registry.cipher:
            return {"ok": False,
                    "error": f"this plane serves {self.registry.cipher!r}, "
                             f"not {cipher!r}"}
        tenant = await self._farm(self.registry.get, str(msg["tenant"]))
        p = self.registry.params
        return {
            "ok": True, "tenant": tenant.tenant_id,
            "tenant_generation": tenant.generation,
            "cipher": p.name, "l": p.l, "n": p.n, "q": int(p.mod.q),
            "window": tenant.server.window,
            # trusted-provisioning stand-in (see module docstring)
            "key": np.asarray(tenant.batch.key),
        }

    async def _op_open_session(self, msg: dict) -> dict:
        sess = await self._farm(
            self.registry.open_session, str(msg["tenant"]))
        return {"ok": True, "session": sess.index,
                "nonce": sess.nonce, "generation": sess.generation}

    async def _op_rotate(self, msg: dict) -> dict:
        tid, sid = str(msg["tenant"]), int(msg["session"])

        def blocking():
            tenant = self.registry.get(tid, create=False)
            sess = self.registry.rotate_session(tid, sid)
            # the quiesce inside rotate_session may have completed submits
            return tenant, sess, tenant.server.pop_completed()

        tenant, sess, done = await self._farm(blocking)
        self._resolve(tenant, done)
        return {"ok": True, "session": sess.index,
                "nonce": sess.nonce, "generation": sess.generation}

    async def _op_stats(self, msg: dict) -> dict:
        tid = msg.get("tenant")
        if tid is None:
            stats = await self._farm(self.registry.stats)
            return {"ok": True, "stats": stats}
        tenant = self.registry.peek(str(tid))
        stats = await self._farm(tenant.server.latency_stats)
        return {"ok": True, "stats": stats}

    # ---- submit (future-resolved) --------------------------------------
    async def _submit_and_reply(self, msg: dict, codec: int,
                                writer: asyncio.StreamWriter,
                                write_lock: asyncio.Lock) -> None:
        reply = await self._op_submit(msg)
        reply["id"] = msg.get("id")
        try:
            async with write_lock:
                writer.write(encode_frame(reply, codec))
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            pass

    async def _op_submit(self, msg: dict) -> dict:
        try:
            tid = str(msg["tenant"])
            req = HHERequest(
                session_id=int(msg["session"]),
                op=str(msg.get("hhe_op", "keystream")),
                payload=msg.get("payload"),
                blocks=(int(msg["blocks"]) if msg.get("blocks") is not None
                        else None),
                delta=float(msg.get("delta", 1024.0)),
            )
        except (KeyError, ValueError, TypeError) as e:
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}

        def blocking():
            tenant = self.registry.get(tid)
            try:
                entry = tenant.server.submit_entry(req)
            except HHEServerSaturated as e:
                return tenant, "saturated", str(e), []
            except (KeyError, RuntimeError, ValueError) as e:
                return tenant, "error", f"{type(e).__name__}: {e}", []
            done = tenant.server.pop_completed()
            if entry is None:
                return tenant, "shed", None, done
            return tenant, "entry", entry, done

        tenant, kind, value, done = await self._farm(blocking)
        if kind == "saturated":
            self._resolve(tenant, done)
            return {"ok": False, "error": "saturated", "detail": value}
        if kind == "error":
            return {"ok": False, "error": value}
        if kind == "shed":
            self._resolve(tenant, done)
            return {"ok": False, "shed": True}
        entry = value
        # register the waiter BEFORE resolving this batch: the entry may
        # already be inside `done` (its own submit filled the window)
        fut = self._register_waiter(tenant, entry.seq)
        self._resolve(tenant, done)
        resp = await fut
        return {
            "ok": True,
            "result": np.asarray(resp.result),
            "ctrs": np.asarray(resp.block_ctrs),
            "nonce": np.frombuffer(entry.nonce, np.uint8).copy(),
            "generation": entry.generation,
            "latency_ms": resp.latency_s * 1e3,
        }


# ==========================================================================
# Client
# ==========================================================================
class ServeClient:
    """Async client for one tenant: frames out, a local cipher for the
    client half of each round trip (encrypt before submit / decrypt
    after).

    The client mirrors each session's counter cursor so it can encrypt
    BEFORE submitting: the server's single farm worker reserves counters
    in frame order, so as long as ONE connection drives a session and its
    inbound submits are issued in cursor order, the mirror is exact.  The
    outbound direction needs no prediction — it decrypts under the
    (nonce, ctrs) echoed in the response, so it is exact even across
    server-side auto-rotations.
    """

    def __init__(self, host: str, port: int, tenant: str,
                 codec: Optional[int] = None):
        self.host, self.port, self.tenant = host, port, tenant
        self.codec = preferred_codec() if codec is None else codec
        self.reader: Optional[asyncio.StreamReader] = None
        self.writer: Optional[asyncio.StreamWriter] = None
        self.params = None
        self.key = None
        self.hello: dict = {}
        self.sessions: Dict[int, dict] = {}   # session -> {nonce, next_ctr}
        self._rid = 0
        self._waiters: Dict[int, asyncio.Future] = {}
        self._reader_task: Optional[asyncio.Task] = None
        self._write_lock: Optional[asyncio.Lock] = None
        self._ciphers: Dict[bytes, object] = {}

    # ------------------------------------------------------------------
    async def connect(self) -> dict:
        from repro.core.params import get_params

        self.reader, self.writer = await asyncio.open_connection(
            self.host, self.port)
        self._write_lock = asyncio.Lock()
        self._reader_task = asyncio.get_running_loop().create_task(
            self._read_replies())
        hello = await self.call({"op": "hello", "tenant": self.tenant})
        if not hello.get("ok"):
            raise RuntimeError(f"hello failed: {hello}")
        self.hello = hello
        self.params = get_params(hello["cipher"])
        self.key = np.asarray(hello["key"], np.uint32)
        return hello

    async def close(self) -> None:
        if self._reader_task is not None:
            self._reader_task.cancel()
            try:
                await self._reader_task
            except asyncio.CancelledError:
                pass
        if self.writer is not None:
            self.writer.close()
            try:
                await self.writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _read_replies(self) -> None:
        try:
            while True:
                msg, _ = await read_frame(self.reader)
                fut = self._waiters.pop(msg.get("id"), None)
                if fut is not None and not fut.done():
                    fut.set_result(msg)
        except (asyncio.IncompleteReadError, ConnectionResetError,
                asyncio.CancelledError):
            for fut in self._waiters.values():
                if not fut.done():
                    fut.cancel()
            self._waiters.clear()

    async def call(self, msg: dict) -> dict:
        """Send one frame, await its correlated reply."""
        self._rid += 1
        msg = dict(msg, id=self._rid)
        fut = asyncio.get_running_loop().create_future()
        self._waiters[self._rid] = fut
        async with self._write_lock:
            self.writer.write(encode_frame(msg, self.codec))
            await self.writer.drain()
        return await fut

    # ------------------------------------------------------------------
    async def open_session(self) -> int:
        r = await self.call({"op": "open_session", "tenant": self.tenant})
        if not r.get("ok"):
            raise RuntimeError(f"open_session failed: {r}")
        self.sessions[int(r["session"])] = {
            "nonce": np.asarray(r["nonce"], np.uint8), "next_ctr": 0}
        return int(r["session"])

    async def rotate(self, session: int) -> dict:
        """Live rotation: the server materializes pending old-nonce lanes,
        swaps in a fresh nonce, and the mirror cursor restarts at 0."""
        r = await self.call({"op": "rotate", "tenant": self.tenant,
                             "session": session})
        if not r.get("ok"):
            raise RuntimeError(f"rotate failed: {r}")
        self.sessions[session] = {
            "nonce": np.asarray(r["nonce"], np.uint8), "next_ctr": 0}
        return r

    async def stats(self, tenant_scoped: bool = True) -> dict:
        msg = {"op": "stats"}
        if tenant_scoped:
            msg["tenant"] = self.tenant
        r = await self.call(msg)
        if not r.get("ok"):
            raise RuntimeError(f"stats failed: {r}")
        return r["stats"]

    def _cipher(self, nonce: np.ndarray):
        """Per-nonce single-stream Cipher (the ref-engine oracle) — cached
        so pipelined submits on one session reuse the producer binding."""
        from repro.core.cipher import Cipher

        key = np.asarray(nonce, np.uint8).tobytes()
        ci = self._ciphers.get(key)
        if ci is None:
            ci = Cipher(self.params, self.key, nonce)
            self._ciphers[key] = ci
        return ci

    def session_remaining(self, session: int) -> int:
        from repro.core import cipher as _c

        return _c.SESSION_CTR_LIMIT - self.sessions[session]["next_ctr"]

    # ---- round-trip halves ---------------------------------------------
    async def encrypt_to_server(self, session: int, tokens: np.ndarray
                                ) -> dict:
        """Client-side encrypt, server-side decrypt_tokens: the inbound
        (prompt) HHE direction.  ``tokens``: (blocks, l) ints < q.  The
        reply's ``result`` is the server's recovered plaintext.  Rotates
        the session first when the mirror says the counter space cannot
        fit the request (decrypt-direction submits never auto-rotate
        server-side)."""
        import jax.numpy as jnp

        tokens = np.asarray(tokens, np.uint32)
        blocks = tokens.shape[0]
        if blocks > self.session_remaining(session):
            await self.rotate(session)
        st = self.sessions[session]
        ctrs = st["next_ctr"] + np.arange(blocks, dtype=np.uint32)
        st["next_ctr"] += blocks
        z = self._cipher(st["nonce"]).keystream(jnp.asarray(ctrs))
        ct = np.asarray(self.params.mod.add(jnp.asarray(tokens), z))
        r = await self.call({
            "op": "submit", "tenant": self.tenant, "session": session,
            "hhe_op": "decrypt_tokens", "payload": ct,
        })
        if not r.get("ok"):
            # nothing was reserved server-side (shed/reject happen before
            # reservation) — roll the mirror back so the cursors re-align
            st["next_ctr"] -= blocks
        return r

    async def decrypt_from_server(self, session: int, tokens: np.ndarray
                                  ) -> Tuple[dict, Optional[np.ndarray]]:
        """Server-side encrypt_tokens, client-side decrypt: the outbound
        (response) HHE direction.  Returns (reply, recovered_tokens);
        recovery is exact under the echoed (nonce, ctrs) even when the
        server auto-rotated mid-stream."""
        import jax.numpy as jnp

        r = await self.call({
            "op": "submit", "tenant": self.tenant, "session": session,
            "hhe_op": "encrypt_tokens",
            "payload": np.asarray(tokens, np.uint32),
        })
        if not r.get("ok"):
            return r, None
        nonce = np.asarray(r["nonce"], np.uint8)
        ctrs = np.asarray(r["ctrs"], np.uint32)
        z = self._cipher(nonce).keystream(jnp.asarray(ctrs))
        back = np.asarray(self.params.mod.sub(
            jnp.asarray(np.asarray(r["result"], np.uint32)), z))
        # re-sync the mirror from the echo (auto-rotation resets it)
        st = self.sessions[session]
        st["nonce"] = nonce
        st["next_ctr"] = int(ctrs[-1]) + 1
        return r, back


# ==========================================================================
# CLI
# ==========================================================================
def main(argv=None) -> int:
    from repro.core.params import REGISTRY

    ap = argparse.ArgumentParser(
        description="async multi-tenant HHE serving plane")
    ap.add_argument("--cipher", default="hera-80", choices=sorted(REGISTRY))
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=DEFAULT_PORT)
    ap.add_argument("--window", type=int, default=64,
                    help="farm window lanes per tenant")
    ap.add_argument("--engine", default=None,
                    help="farm consumer backend (default: auto-pick)")
    ap.add_argument("--capacity", type=int, default=8,
                    help="live-tenant LRU bound")
    ap.add_argument("--deadline-ms", type=float, default=25.0,
                    help="age bound before a part-full window fires")
    ap.add_argument("--max-pending-lanes", type=int, default=4096,
                    help="admission bound on un-materialized lanes/tenant")
    ap.add_argument("--overload", choices=["reject", "shed"],
                    default="reject")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    registry = TenantRegistry(
        args.cipher, capacity=args.capacity, window=args.window,
        engine=args.engine, deadline_s=args.deadline_ms / 1e3,
        max_pending_lanes=args.max_pending_lanes, overload=args.overload,
        seed=args.seed)

    async def run():
        plane = ServePlane(registry, host=args.host, port=args.port)
        host, port = await plane.start()
        print(f"serving {args.cipher} on {host}:{port} "
              f"(window={args.window}, deadline={args.deadline_ms}ms, "
              f"capacity={args.capacity}, overload={args.overload}, "
              f"codec={'msgpack' if msgpack else 'json'})")
        try:
            await plane.serve_forever()
        except asyncio.CancelledError:
            pass
        finally:
            await plane.stop()

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        print("interrupted; serving plane stopped")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
