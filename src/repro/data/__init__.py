"""Data plane: deterministic shard-aware pipeline + the HHE-encrypted batch
path (the paper's cipher as a first-class framework feature)."""
