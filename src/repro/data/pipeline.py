"""Deterministic, resumable data pipeline.

Production properties this models:
  * determinism: batch t is a pure function of (seed, step) — restart/elastic
    reshard replays identically; no inter-host coordination needed;
  * resumability: iterator state is just the step counter, carried inside
    the checkpoint `extra` dict;
  * shard-awareness: each host materializes only its slice (here: single
    process, full batch).

Two sources: a synthetic LM stream (default; markov-ish so loss decreases)
and a memory-mapped token file.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class PipelineState:
    step: int = 0


class SyntheticLM:
    """Deterministic synthetic token stream with learnable structure.

    Tokens follow a degree-2 additive recurrence over a small alphabet
    window, so even small models show decreasing loss — useful for the
    end-to-end example and convergence tests.
    """

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        B, T, V = self.batch, self.seq_len, self.cfg.vocab
        # learnable bigram structure: a fixed (seed-keyed) permutation with
        # 15% uniform noise — a model only needs embed->unembed to crack it
        perm = np.random.default_rng(self.seed).permutation(V)
        x = np.zeros((B, T + 1), np.int64)
        x[:, 0] = rng.integers(0, V, B)
        noise = rng.random((B, T + 1))
        rand = rng.integers(0, V, (B, T + 1))
        for t in range(1, T + 1):
            nxt = perm[x[:, t - 1]]
            x[:, t] = np.where(noise[:, t] < 0.15, rand[:, t], nxt)
        toks = x[:, :-1].astype(np.int32)
        labels = x[:, 1:].astype(np.int32)
        return {"tokens": toks, "labels": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class TokenFile:
    """Memory-mapped flat token file (uint16/uint32), deterministic strided
    batching keyed by step."""

    def __init__(self, path: str, cfg: ModelConfig, batch: int, seq_len: int,
                 dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len

    def batch_at(self, step: int) -> dict:
        B, T = self.batch, self.seq_len
        n = len(self.tokens) - (T + 1)
        rng = np.random.default_rng(step)
        starts = rng.integers(0, n, B)
        rows = np.stack([self.tokens[s : s + T + 1] for s in starts])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}


def make_source(cfg: ModelConfig, batch: int, seq_len: int, *,
                path: Optional[str] = None, seed: int = 0):
    if path:
        return TokenFile(path, cfg, batch, seq_len)
    return SyntheticLM(cfg, batch, seq_len, seed=seed)


def iterate_batches(source, start_step: int = 0,
                    n_steps: Optional[int] = None):
    """Streaming iterator over any source.

    Sources with their own pipelined `stream` method (e.g.
    `data/encrypted.py::FarmEncryptedSource`, whose keystream producer for
    batch t+1 overlaps batch t) are consumed through it; plain random-access
    sources fall back to `batch_at`.  Resumability is unchanged: restart
    from the checkpointed step via ``start_step``.
    """
    if hasattr(source, "stream"):
        yield from source.stream(start_step, n_steps)
        return
    step = start_step
    while n_steps is None or step < start_step + n_steps:
        yield source.batch_at(step)
        step += 1
