"""HHE-encrypted data plane — the paper's cipher as a framework feature.

Threat model (RtF client-server, paper §I-II): the *client* encrypts
training/serving examples with a CKKS-friendly symmetric cipher (HERA or
Rubato) — cheap, low-expansion — and ships ciphertext.  Here the TPU pod
plays the role of the trusted compute enclave holding the symmetric key:
it regenerates the stream key at line rate (the accelerator this paper
builds) and decrypts by modular subtraction, fused into the input pipeline.
The host/network path never carries plaintext.

Token encryption is exact: token ids are Z_q elements directly (vocab < q).

`EncryptedSource` wraps any pipeline source; `make_decryptor` returns the
on-device decryption function the train step fuses in (see
train_loop.make_train_step(decryptor=...)).  Keystream generation for batch
t+1 is dispatchable concurrently with step t (macro-level RNG decoupling,
DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cipher import Cipher


def _blocks_for(n_tokens: int, l: int) -> int:
    return (n_tokens + l - 1) // l


def encrypt_tokens(cipher: Cipher, tokens: np.ndarray, base_ctr: int):
    """tokens: (B, T) int32 < q.  Returns dict(ct=(B,T) u32, base_ctr)."""
    B, T = tokens.shape
    l = cipher.params.l
    n_tok = B * T
    nblk = _blocks_for(n_tok, l)
    ctrs = jnp.arange(base_ctr, base_ctr + nblk, dtype=jnp.uint32)
    z = cipher.keystream(ctrs).reshape(-1)[:n_tok]          # (n_tok,)
    m = jnp.asarray(tokens.reshape(-1), jnp.uint32)
    ct = cipher.params.mod.add(m, z).reshape(B, T)
    return {"ct": ct, "base_ctr": jnp.asarray(base_ctr, jnp.uint32)}


def make_decryptor(cipher: Cipher, labels_from_tokens: bool = True):
    """Returns fn(batch) -> plaintext batch, run on-device inside the step.

    batch: {"ct": (B,T) u32, "base_ctr": scalar u32} ->
           {"tokens": (B,T) i32, "labels": (B,T) i32}
    """
    p = cipher.params
    l = p.l

    def decrypt(batch):
        ct = batch["ct"]
        B, T = ct.shape
        n_tok = B * T
        nblk = _blocks_for(n_tok, l)
        ctrs = batch["base_ctr"] + jnp.arange(nblk, dtype=jnp.uint32)
        z = cipher.keystream(ctrs).reshape(-1)[:n_tok]
        toks = p.mod.sub(ct.reshape(-1), z).astype(jnp.int32).reshape(B, T)
        out = {"tokens": toks}
        if labels_from_tokens:
            # next-token labels from the recovered stream
            out["labels"] = jnp.concatenate(
                [toks[:, 1:], jnp.full((B, 1), -1, jnp.int32)], axis=1
            )
        elif "labels" in batch:
            out["labels"] = batch["labels"]
        return out

    return decrypt


class EncryptedSource:
    """Wraps a pipeline source: yields HHE-encrypted batches.

    Counter-space management: batch t uses block counters
    [t * blocks_per_batch, (t+1) * blocks_per_batch) — nonce reuse never
    happens across steps, and decryption needs only (key, nonce, t).
    """

    def __init__(self, source, cipher: Cipher):
        self.source = source
        self.cipher = cipher

    def blocks_per_batch(self) -> int:
        b = self.source.batch * self.source.seq_len
        return _blocks_for(b, self.cipher.params.l)

    def batch_at(self, step: int) -> dict:
        plain = self.source.batch_at(step)
        base = step * self.blocks_per_batch()
        enc = encrypt_tokens(self.cipher, plain["tokens"], base)
        return enc
