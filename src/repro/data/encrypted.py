"""HHE-encrypted data plane — the paper's cipher as a framework feature.

Threat model (RtF client-server, paper §I-II): the *client* encrypts
training/serving examples with a CKKS-friendly symmetric cipher (HERA or
Rubato) — cheap, low-expansion — and ships ciphertext.  Here the TPU pod
plays the role of the trusted compute enclave holding the symmetric key:
it regenerates the stream key at line rate (the accelerator this paper
builds) and decrypts by modular subtraction, fused into the input pipeline.
The host/network path never carries plaintext.

Token encryption is exact: token ids are Z_q elements directly (vocab < q).

`EncryptedSource` wraps any pipeline source; `make_decryptor` returns the
on-device decryption function the train step fuses in (see
train_loop.make_train_step(decryptor=...)).  Keystream generation for batch
t+1 is dispatchable concurrently with step t (macro-level RNG decoupling,
docs/DESIGN.md §6).

`FarmEncryptedSource` is the batched-session upgrade: it draws keystream
from a `CipherBatch` session through the double-buffered `KeystreamFarm`
pipeline, so `stream()` actually *dispatches* the XOF producer for batch
t+1 before batch t is encrypted (the macro RNG decoupling made real, not
just dispatchable).  One CipherBatch (one key) can back many sources —
e.g. one session per data shard — and `data/pipeline.py::iterate_batches`
consumes whichever streaming interface a source provides.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.cipher import Cipher, CipherBatch, StreamSession
from repro.core.farm import KeystreamFarm, WindowPlan


def _blocks_for(n_tokens: int, l: int) -> int:
    return (n_tokens + l - 1) // l


def encrypt_tokens(cipher: Cipher, tokens: np.ndarray, base_ctr: int):
    """tokens: (B, T) int32 < q.  Returns dict(ct=(B,T) u32, base_ctr)."""
    B, T = tokens.shape
    l = cipher.params.l
    n_tok = B * T
    nblk = _blocks_for(n_tok, l)
    ctrs = jnp.arange(base_ctr, base_ctr + nblk, dtype=jnp.uint32)
    z = cipher.keystream(ctrs).reshape(-1)[:n_tok]          # (n_tok,)
    m = jnp.asarray(tokens.reshape(-1), jnp.uint32)
    ct = cipher.params.mod.add(m, z).reshape(B, T)
    return {"ct": ct, "base_ctr": jnp.asarray(base_ctr, jnp.uint32)}


def make_decryptor(cipher: Cipher, labels_from_tokens: bool = True):
    """Returns fn(batch) -> plaintext batch, run on-device inside the step.

    batch: {"ct": (B,T) u32, "base_ctr": scalar u32} ->
           {"tokens": (B,T) i32, "labels": (B,T) i32}
    """
    p = cipher.params
    l = p.l

    def decrypt(batch):
        ct = batch["ct"]
        B, T = ct.shape
        n_tok = B * T
        nblk = _blocks_for(n_tok, l)
        ctrs = batch["base_ctr"] + jnp.arange(nblk, dtype=jnp.uint32)
        z = cipher.keystream(ctrs).reshape(-1)[:n_tok]
        toks = p.mod.sub(ct.reshape(-1), z).astype(jnp.int32).reshape(B, T)
        out = {"tokens": toks}
        if labels_from_tokens:
            # next-token labels from the recovered stream
            out["labels"] = jnp.concatenate(
                [toks[:, 1:], jnp.full((B, 1), -1, jnp.int32)], axis=1
            )
        elif "labels" in batch:
            out["labels"] = batch["labels"]
        return out

    return decrypt


class EncryptedSource:
    """Wraps a pipeline source: yields HHE-encrypted batches.

    Counter-space management: batch t uses block counters
    [t * blocks_per_batch, (t+1) * blocks_per_batch) — nonce reuse never
    happens across steps, and decryption needs only (key, nonce, t).
    """

    def __init__(self, source, cipher: Cipher):
        self.source = source
        self.cipher = cipher

    def blocks_per_batch(self) -> int:
        b = self.source.batch * self.source.seq_len
        return _blocks_for(b, self.cipher.params.l)

    def batch_at(self, step: int) -> dict:
        plain = self.source.batch_at(step)
        base = step * self.blocks_per_batch()
        enc = encrypt_tokens(self.cipher, plain["tokens"], base)
        return enc


class FarmEncryptedSource:
    """Encrypted source backed by a CipherBatch session + keystream farm.

    Same counter-space convention as `EncryptedSource` (batch t owns block
    counters [t·bpb, (t+1)·bpb) on this source's session), so decryption
    needs only (key, session nonce, t) — use
    ``make_decryptor(batch.session_cipher(src.session.index))``.

    `batch_at` is random access (produce+consume on demand);  `stream`
    is the pipelined path: the jit'd XOF/sampler producer for batch t+1 is
    dispatched *before* batch t's keystream is consumed, overlapping
    producer and consumer across steps on async backends.

    ``engine`` picks the farm's consumer backend (any registered
    `repro.core.engine` name or instance); ``consumer``/``interpret`` are
    the legacy spellings; ``depth`` sets the farm's producer→consumer
    FIFO depth (how many batches of XOF/sampling `stream` keeps in
    flight).  ``plan`` applies a measured :class:`repro.core.tuner.
    StreamPlan` — producer, engine, variant, depth — in one shot (its
    window field is moot here: each batch is one fixed-size window).
    """

    def __init__(self, source, batch: CipherBatch,
                 session: Optional[StreamSession] = None,
                 engine=None, consumer: Optional[str] = None, mesh=None,
                 interpret: Optional[bool] = None,
                 variant: Optional[str] = None,
                 depth: Optional[int] = None, plan=None):
        self.source = source
        self.batch = batch
        self.session = session if session is not None else batch.add_session()
        self.farm = KeystreamFarm(batch, engine=engine, consumer=consumer,
                                  mesh=mesh, interpret=interpret,
                                  variant=variant, depth=depth, plan=plan)

    @property
    def cipher(self) -> Cipher:
        """Single-stream view (for decryptors / cross-checks)."""
        return self.batch.session_cipher(self.session.index)

    def blocks_per_batch(self) -> int:
        b = self.source.batch * self.source.seq_len
        return _blocks_for(b, self.batch.params.l)

    def _plan(self, step: int) -> WindowPlan:
        bpb = self.blocks_per_batch()
        ctrs = step * bpb + np.arange(bpb, dtype=np.int64)
        return WindowPlan(np.full(bpb, self.session.index, np.int64), ctrs)

    def _encrypt(self, step: int, z) -> dict:
        plain = self.source.batch_at(step)
        toks = plain["tokens"]
        B, T = toks.shape
        zf = z.reshape(-1)[: B * T]
        m = jnp.asarray(toks.reshape(-1), jnp.uint32)
        ct = self.batch.params.mod.add(m, zf).reshape(B, T)
        base = step * self.blocks_per_batch()
        return {"ct": ct, "base_ctr": jnp.asarray(base, jnp.uint32)}

    def batch_at(self, step: int) -> dict:
        plan = self._plan(step)
        z = self.farm.consume(self.farm.produce(plan))
        return self._encrypt(step, z)

    def stream(self, start_step: int = 0, n_steps: Optional[int] = None):
        """Double-buffered batch iterator (see class docstring)."""

        def plans():
            step = start_step
            while n_steps is None or step < start_step + n_steps:
                yield self._plan(step)
                step += 1

        for step, (_, z) in enumerate(self.farm.run(plans()), start_step):
            yield self._encrypt(step, z)
