"""Common layers: RMSNorm, gated MLP, embeddings, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def pdtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def rms_norm(x, scale, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def gated_mlp(x, wi_g, wi_u, wo, unroll: bool = False):
    """SwiGLU MLP.  x: (..., D); wi_*: (D, F); wo: (F, D).

    For very large weights (jamba: 8192x24576) the FFN is computed in
    F-chunks under a scanned, checkpointed body: bounds the residency of
    FSDP-gathered weights and of their pre-reduce-scatter cotangents.
    """
    D, F = wi_g.shape
    n_tokens = 1
    for s in x.shape[:-1]:
        n_tokens *= s
    # chunking bounds FSDP-gather liveness during training; at decode
    # (few tokens) it only adds weight-relayout permutes (§Perf iter B2)
    if D * F <= (1 << 27) or n_tokens <= 1024:
        h = jax.nn.silu(jnp.einsum("...d,df->...f", x, wi_g)) * jnp.einsum(
            "...d,df->...f", x, wi_u
        )
        return jnp.einsum("...f,fd->...d", h, wo)

    n_chunks = 4
    while F % n_chunks:
        n_chunks //= 2

    @jax.checkpoint
    def chunk(acc, ws):
        g, u, o = ws
        h = jax.nn.silu(jnp.einsum("...d,df->...f", x, g)) * jnp.einsum(
            "...d,df->...f", x, u
        )
        return acc + jnp.einsum("...f,fd->...d", h, o).astype(acc.dtype), None

    split = lambda w, ax: jnp.stack(jnp.split(w, n_chunks, axis=ax))
    acc0 = jnp.zeros(x.shape, jnp.float32)
    xs = (split(wi_g, 1), split(wi_u, 1), split(wo, 0))
    if unroll:
        acc = acc0
        for i in range(n_chunks):
            acc, _ = chunk(acc, jax.tree.map(lambda a: a[i], xs))
    else:
        acc, _ = jax.lax.scan(chunk, acc0, xs)
    return acc.astype(x.dtype)


def normal_init(key, shape, dtype, scale: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
