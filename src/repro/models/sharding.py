"""Sharding policy: maps (arch config × mesh) to PartitionSpecs.

Physical production mesh axes: ("data", "model") = (16, 16), multi-pod adds
a leading "pod".  Per-arch we *refine* the model axis into three logical
sub-axes ("tp_a", "tp_b", "sp"):

  tp     = tp_a * tp_b = largest divisor of |model| dividing num_heads
  tp_a   = gcd(kv_heads, tp)   — KV heads shard here
  tp_b   = tp / tp_a           — query groups shard here; KV is *replicated*
                                 across tp_b (Megatron-style GQA replication)
  sp     = |model| / tp        — leftover; joins tp for feature-dim (MLP,
                                 vocab, expert) sharding, and shards the
                                 sequence dim where useful

This guarantees GSPMD divisibility for every assigned arch (verified in
tests): e.g. qwen2-vl (28 heads) gets tp=4, sp=4; arctic (56 heads) tp=8,
sp=2; everything else tp=16, sp=1.

FSDP: when parameters (+ optimizer state) per chip would exceed the HBM
budget, weights are additionally sharded over "data" (ZeRO-3 via GSPMD:
all-gather per scan step in forward, reduce-scatter of grads in backward).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig

HBM_PER_CHIP = 16e9  # TPU v5e-class


def _largest_div(n: int, cap: int) -> int:
    """Largest divisor of ``cap`` (a power of two) that divides n."""
    d = cap
    while d > 1 and n % d:
        d //= 2
    return d


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    mesh: Mesh                     # refined mesh
    has_pod: bool
    tp_a: int
    tp_b: int
    sp: int
    fsdp: bool                     # shard params over "data" too
    seq_shard_data: bool = False   # shard sequence (not batch) over dp
    # decode with huge models: instead of FSDP (re-gathering weights every
    # token!), keep weights STATIONARY by shard­ing their output-feature
    # dims over "data" and psum-ing tiny activations (§Perf iter B1)
    weight_stationary: bool = False

    # ---- axis tuples -----------------------------------------------------
    @property
    def dp(self) -> Tuple[str, ...]:
        return ("pod", "data") if self.has_pod else ("data",)

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp]))

    @property
    def tp_full(self) -> Tuple[str, ...]:
        return ("tp_a", "tp_b", "sp")

    @property
    def tp_heads(self) -> Tuple[str, ...]:
        return ("tp_a", "tp_b")

    @property
    def model_size(self) -> int:
        return self.tp_a * self.tp_b * self.sp

    def _fs(self):
        """The FSDP axis (or None)."""
        return "data" if self.fsdp else None

    # ---- parameter specs ---------------------------------------------------
    def spec(self, role: str, cfg: ModelConfig) -> P:
        fs = self._fs()
        E_axes, F_axes = self._expert_axes(cfg)
        if self.weight_stationary:
            # big matrices: feature dim takes BOTH the tp axes and "data";
            # attention weights stay FSDP (they're small; head layout is
            # delicate).  Contractions produce activation-sized partials
            # that psum over "data" — per-token bytes, not per-weight.
            wide = tuple(self.tp_full) + ("data",)
            f_wide = (tuple(F_axes) if F_axes else ()) + ("data",)
            table = {
                "embed": P(self.tp_full, None),
                "head": P(None, wide),
                "frontend": P(None, wide),
                "wq": P(fs, self.tp_heads, None),
                "wkv": P(fs, "tp_a", None),
                "wo": P(self.tp_heads, None, fs),
                "wi": P(None, wide),
                "wo_mlp": P(wide, None),
                "router": P(None, None),
                "expert_wi": P(E_axes, None, f_wide),
                "expert_wo": P(E_axes, f_wide, None),
                "ssm_in": P(None, wide),
                "ssm_in_state": P(None, self.tp_full),
                "ssm_dt": P(None, self.tp_full),
                "ssm_conv": P(None, None),
                "ssm_vec": P(self.tp_full),
                "ssm_out": P(wide, None),
                "norm": P(None),
                "scalar": P(),
            }
            if role not in table:
                raise KeyError(role)
            return table[role]
        table = {
            # embeddings
            "embed": P(self.tp_full, fs),            # (V, D)
            "head": P(fs, self.tp_full),              # (D, V)
            "frontend": P(fs, self.tp_full),          # (D_front, D)
            # attention
            "wq": P(fs, self.tp_heads, None),         # (D, H, hd)
            "wkv": P(fs, "tp_a", None),                # (D, K, hd)
            "wo": P(self.tp_heads, None, fs),          # (H, hd, D)
            # dense mlp
            "wi": P(fs, self.tp_full),                 # (D, F)
            "wo_mlp": P(self.tp_full, fs),             # (F, D)
            # moe
            "router": P(fs, None),                     # (D, E)
            "expert_wi": P(E_axes, fs, F_axes),        # (E, D, F)
            "expert_wo": P(E_axes, F_axes, fs),        # (E, F, D)
            # mamba
            "ssm_in": P(fs, self.tp_full),             # (D, d_inner)
            "ssm_in_state": P(fs, self.tp_full),       # (D, ssm_state*) small
            "ssm_dt": P(fs, self.tp_full),             # (D, heads)
            "ssm_conv": P(None, self.tp_full),         # (w, channels)
            "ssm_vec": P(self.tp_full),                # (heads,)
            "ssm_out": P(self.tp_full, fs),            # (d_inner, D)
            # norms / scalars
            "norm": P(None),
            "scalar": P(),
        }
        if role not in table:
            raise KeyError(role)
        return table[role]

    def expert_axes(self, cfg: ModelConfig):
        """Public: (expert-dim axes, leftover feature-dim axes)."""
        return self._expert_axes(cfg)

    def _expert_axes(self, cfg: ModelConfig):
        """Split tp axes between the expert dim and the FFN feature dim."""
        if not cfg.num_experts:
            return None, None
        e_axes, rem = [], []
        e = cfg.num_experts
        prod = 1
        for name, size in (("tp_a", self.tp_a), ("tp_b", self.tp_b),
                           ("sp", self.sp)):
            if size == 1:
                continue
            if e % (prod * size) == 0:
                e_axes.append(name)
                prod *= size
            else:
                rem.append(name)
        return (tuple(e_axes) or None), (tuple(rem) or None)

    # ---- activation specs --------------------------------------------------
    def act(self, *dims) -> P:
        return P(*dims)

    def batch_spec(self) -> P:
        """(B, T, ...) activations: batch over dp (or seq over dp)."""
        if self.seq_shard_data:
            return P(None, self.dp)
        return P(self.dp, None)

    def cache_spec(self) -> P:
        """KV cache (B, S, K, hd)."""
        if self.seq_shard_data:
            return P(None, self.dp, "tp_a", None)
        return P(self.dp, None, "tp_a", None)

    def ssm_cache_spec(self) -> P:
        """SSM state (B, heads, hd, state): heads over tp."""
        if self.seq_shard_data:
            return P(None, self.tp_full, None, None)
        return P(self.dp, self.tp_full, None, None)


def refine_mesh(mesh: Mesh, cfg: ModelConfig) -> Mesh:
    """Split the physical "model" axis into ("tp_a","tp_b","sp")."""
    names = list(mesh.axis_names)
    if "model" not in names:
        raise ValueError(f"mesh {names} lacks a 'model' axis")
    model = mesh.shape["model"]
    heads = cfg.num_heads or cfg.ssm_heads
    tp = _largest_div(heads, model)
    tp_a = math.gcd(cfg.kv_heads, tp) if cfg.kv_heads else tp
    # keep tp_a a divisor of tp (it is: gcd with tp's divisor chain)
    while tp % tp_a:
        tp_a //= 2
    tp_b = tp // tp_a
    sp = model // tp
    if cfg.num_heads and cfg.kv_heads:
        g = cfg.num_heads // cfg.kv_heads
        assert g % tp_b == 0, (cfg.name, g, tp_b)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    new_shape, new_names = [], []
    for n in names:
        if n == "model":
            new_shape += [tp_a, tp_b, sp]
            new_names += ["tp_a", "tp_b", "sp"]
        else:
            new_shape.append(axis_sizes[n])
            new_names.append(n)
    devices = mesh.devices.reshape(new_shape)
    return Mesh(devices, tuple(new_names)), tp_a, tp_b, sp


def make_policy(mesh: Mesh, cfg: ModelConfig, *, batch: int,
                train: bool, seq_len: int = 0) -> ShardingPolicy:
    refined, tp_a, tp_b, sp = refine_mesh(mesh, cfg)
    has_pod = "pod" in refined.axis_names
    dp_size = refined.shape["data"] * (refined.shape["pod"] if has_pod else 1)
    model = tp_a * tp_b * sp

    # FSDP decision: params (+opt state +grads) per chip under model-only
    # sharding.  FSDP costs weight all-gathers on every microbatch fwd,
    # remat-recompute AND bwd pass — ~5.9 s of ICI per train step for
    # mamba2-2.7b (EXPERIMENTS.md §Perf iter A1) — so it is engaged only
    # when model-sharded state would actually blow the HBM budget.
    bytes_per_param = 4 if cfg.param_dtype == "float32" else 2
    if train:
        bytes_per_param += (2.1 if cfg.opt_8bit else 8)      # moments
        bytes_per_param += 4 if cfg.param_dtype == "float32" else 2  # grads
    per_chip = cfg.param_count() * bytes_per_param / model
    fsdp = per_chip > 0.5 * HBM_PER_CHIP

    # decode: if weights would need FSDP, keep them stationary instead —
    # re-gathering hundreds of GB of weights per generated token is the
    # worst possible use of ICI (§Perf iter B1)
    weight_stationary = (not train) and fsdp
    if weight_stationary:
        fsdp = False

    seq_shard = batch % dp_size != 0
    if seq_shard and batch != 1:
        raise ValueError(f"batch {batch} not shardable over dp={dp_size}")
    return ShardingPolicy(
        mesh=refined, has_pod=has_pod, tp_a=tp_a, tp_b=tp_b, sp=sp,
        fsdp=fsdp, seq_shard_data=seq_shard,
        weight_stationary=weight_stationary,
    )
