"""Mamba2 SSD (state-space duality) block: chunked parallel scan for
training/prefill, O(1) recurrent update for decode.

Math follows the SSD formulation: within a chunk (length L) the output is an
attention-like quadratic form masked by the cumulative decay; across chunks
a small recurrent state (B, heads, head_dim, state) is carried by a scan.
All decay/softplus math runs in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp



def causal_conv(u, w):
    """Depthwise causal conv.  u: (B, T, C); w: (W, C).  Returns (B, T, C).

    Uses the conv primitive with feature_group_count=C — a pad-and-add
    formulation materializes W shifted copies of u (4x the byte traffic,
    EXPERIMENTS.md §Perf iter A4)."""
    W, C = w.shape
    out = jax.lax.conv_general_dilated(
        u.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],      # (W, 1, C): depthwise
        window_strides=(1,),
        padding=[(W - 1, 0)],                   # causal left pad
        dimension_numbers=("NTC", "TIO", "NTC"),
        feature_group_count=C,
    )
    return out.astype(u.dtype)


def conv_decode(u_t, conv_state, w):
    """One-step conv.  u_t: (B, C); conv_state: (B, W-1, C) past inputs.
    Returns (y_t, new_state)."""
    W = w.shape[0]
    window = jnp.concatenate([conv_state, u_t[:, None]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", window, w)
    return y, window[:, 1:]


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None, unroll: bool = False):
    """SSD forward.

    x:  (B, T, H, P) value heads (f32 or bf16)
    dt: (B, T, H)    discretization steps (post-softplus, f32)
    A:  (H,)         negative decay rates (f32)
    Bm: (B, T, S)    input projections (shared across heads, ngroups=1)
    Cm: (B, T, S)    output projections
    h0: (B, H, P, S) initial state or None
    Returns (y: (B, T, H, P), h_final: (B, H, P, S)).
    """
    Bsz, T, H, P = x.shape
    S = Bm.shape[-1]
    L = min(chunk, T)
    assert T % L == 0, (T, L)
    NC = T // L

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = dtf * A  # (B, T, H), negative

    def ch(a):
        return a.reshape((Bsz, NC, L) + a.shape[2:])

    x_c, dt_c, dA_c = ch(xf), ch(dtf), ch(dA)
    B_c, C_c = ch(Bm.astype(jnp.float32)), ch(Cm.astype(jnp.float32))

    A_cs = jnp.cumsum(dA_c, axis=2)                     # (B,NC,L,H)
    A_end = A_cs[:, :, -1]                              # (B,NC,H)

    # ---- intra-chunk (quadratic, attention-like) ----
    # decay[b,c,h,l,m] = exp(A_cs[l] - A_cs[m]) for l >= m
    # The (B,NC,L,L,H) tensors dominate the memory roofline term for SSM
    # archs (EXPERIMENTS.md §Perf iter A3): the score product is formed in
    # bf16 (decays are in [0,1], the product is numerically tame) and only
    # the einsum accumulates in f32.
    diff = A_cs[:, :, :, None, :] - A_cs[:, :, None, :, :]   # (B,NC,L,L,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcls,bcms->bclm", C_c, B_c)             # (B,NC,L,L)
    scores = cb[..., None] * decay * dt_c[:, :, None, :, :]  # (B,NC,L,L,H)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", scores, x_c)

    # ---- chunk states ----
    w_state = jnp.exp(A_end[:, :, None, :] - A_cs) * dt_c    # (B,NC,L,H)
    states = jnp.einsum("bclh,bcls,bclhp->bchps", w_state, B_c, x_c)

    # ---- inter-chunk recurrence ----
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, S), jnp.float32)

    def step(h, inputs):
        C_k, A_cs_k, A_end_k, S_k = inputs
        y_in = jnp.einsum("bls,bhps->blhp", C_k, h)          # (B,L,H,P)
        y_in = y_in * jnp.exp(A_cs_k)[..., None]             # decay to pos l
        h_next = h * jnp.exp(A_end_k)[:, :, None, None] + S_k
        return h_next, y_in

    xs = (
        C_c.transpose(1, 0, 2, 3),
        A_cs.transpose(1, 0, 2, 3),
        A_end.transpose(1, 0, 2),
        states.transpose(1, 0, 2, 3, 4),
    )
    if unroll:
        h = h0
        ys = []
        for c in range(NC):
            h, y_c = step(h, jax.tree.map(lambda a: a[c], xs))
            ys.append(y_c)
        h_final, y_inter = h, jnp.stack(ys, 0)
    else:
        h_final, y_inter = jax.lax.scan(step, h0, xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4).reshape(Bsz, T, H, P)

    y = (y_intra.reshape(Bsz, T, H, P) + y_inter).astype(x.dtype)
    return y, h_final


def ssd_decode(x_t, dt_t, A, B_t, C_t, h):
    """One-token recurrent update.

    x_t: (B, H, P); dt_t: (B, H); B_t/C_t: (B, S); h: (B, H, P, S).
    Returns (y_t: (B, H, P), h_next)."""
    xf = x_t.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * A)                                  # (B, H)
    inc = jnp.einsum("bh,bs,bhp->bhps", dtf, B_t.astype(jnp.float32), xf)
    h_next = h * decay[:, :, None, None] + inc
    y = jnp.einsum("bs,bhps->bhp", C_t.astype(jnp.float32), h_next)
    return y.astype(x_t.dtype), h_next
