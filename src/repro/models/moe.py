"""Capacity-based top-k Mixture-of-Experts (GShard-style token choice).

Dispatch is **sort-based**: the (token, slot) -> expert assignments are
flattened (slot-major, so first choices win capacity ties), stably sorted by
expert id, and each assignment's position inside its expert's capacity
buffer is its rank within the sorted run.  Nothing of shape (N, E) is ever
materialized — the working set is O(N·k) indices plus the (E, C, D) expert
buffers, which matters at train_4k scale (N=1M, E=128 would make an (N, E)
cumsum a 537 GB tensor).

Compiled FLOPs stay proportional to *active* experts
(capacity_factor × top_k / E of the dense equivalent), keeping the roofline
useful-ratio honest.  Expert weights shard over the tp axes (sharding.py
`_expert_axes`); arctic runs 8 experts/chip.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.compat import shard_map

from repro.configs.base import ModelConfig


def _wsc(x, shardings, name):
    if shardings is not None and shardings.get(name) is not None:
        return jax.lax.with_sharding_constraint(x, shardings[name])
    return x


# ---------------------------------------------------------------------------
# shard_map expert-parallel path (production)
# ---------------------------------------------------------------------------
def _local_positions(e_local, k, n_loc, E, capacity):
    """Sort-based positions for the local token slice (slot-major priority)."""
    e_flat = e_local.T.reshape(n_loc * k)
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    starts = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    rank_sorted = jnp.arange(n_loc * k) - starts[e_sorted]
    pos_flat = jnp.zeros((n_loc * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep_flat = pos_flat < capacity
    return (jnp.where(keep_flat, pos_flat, 0).reshape(k, n_loc),
            keep_flat.reshape(k, n_loc))


def moe_ffn_sharded(cfg: ModelConfig, x, router_w, wi_g, wi_u, wo, policy):
    """Expert-parallel MoE under shard_map (docs/DESIGN.md §5).

    Key observation: activations are dp-sharded and tp-REPLICATED in this
    framework, so every expert owner already holds every local token —
    dispatch needs NO communication.  Each device computes its E_loc experts
    on its data shard's tokens (capacity enforced per (expert, data-shard)),
    and ONE psum over the tp axes both sums expert contributions and
    completes the feature-sharded matmul — exactly the collective a dense
    TP MLP needs.  No GSPMD scatter partitioning involved.

    FSDP: expert weights arrive data-sharded on D and are all-gathered
    in-body (AD turns that into the reduce-scatter of gradients).
    """
    mesh = policy.mesh
    E, k = cfg.num_experts, cfg.top_k
    B, T, D = x.shape
    e_axes, f_axes = policy.expert_axes(cfg)
    e_axes = e_axes or ()
    f_axes = f_axes or ()
    ws = policy.weight_stationary
    dp = policy.dp if not policy.seq_shard_data else ()
    fs = "data" if policy.fsdp else None
    tp_all = tuple(a for a in ("tp_a", "tp_b", "sp") if mesh.shape[a] > 1)
    if ws:
        f_axes = tuple(f_axes) + ("data",)
        psum_axes = tp_all + ("data",)
    else:
        psum_axes = tp_all
    e_loc = E
    for a in e_axes:
        e_loc //= mesh.shape[a]

    from jax.sharding import PartitionSpec as P

    # chunk the expert FFN feature dim when the FSDP-gathered weights would
    # otherwise dominate per-device residency (jamba: 3x0.4 GB per layer)
    f_loc = cfg.d_ff
    for a in f_axes:
        f_loc //= mesh.shape[a]
    n_f_chunks = 1
    while e_loc * D * (f_loc // n_f_chunks) > 2**28 and n_f_chunks < 8:
        n_f_chunks *= 2
    while f_loc % n_f_chunks:
        n_f_chunks //= 2

    def body(xb, rw, wg, wu, wod):
        # xb: (B_loc, T, D); rw: (D/fs, E); w*: (E_loc, D/fs, F_loc)
        n_loc = xb.shape[0] * xb.shape[1]
        xf = xb.reshape(n_loc, D)
        if fs:
            rw = jax.lax.all_gather(rw, fs, axis=0, tiled=True)

        logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                            rw.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eidx = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        capacity = max(1, math.ceil(n_loc * k * cfg.capacity_factor / E))
        pos, keep = _local_positions(eidx, k, n_loc, E, capacity)

        # my expert range from the tp coordinates
        lin = jnp.zeros((), jnp.int32)
        for a in e_axes:
            lin = lin * mesh.shape[a] + jax.lax.axis_index(a)
        e0 = lin * e_loc

        xe = jnp.zeros((e_loc, capacity, D), x.dtype)
        for s in range(k):
            e_rel = eidx[:, s] - e0
            mine = keep[s] & (e_rel >= 0) & (e_rel < e_loc)
            contrib = jnp.where(mine[:, None], xf, 0)
            xe = xe.at[jnp.where(mine, e_rel, 0), pos[s]].add(contrib)

        def ffn_chunk(carry, ws):
            wg_c, wu_c, wo_c = ws
            if fs:
                wg_c = jax.lax.all_gather(wg_c, fs, axis=1, tiled=True)
                wu_c = jax.lax.all_gather(wu_c, fs, axis=1, tiled=True)
                wo_c = jax.lax.all_gather(wo_c, fs, axis=2, tiled=True)
            h = jax.nn.silu(
                jnp.einsum("ecd,edf->ecf", xe, wg_c)
            ) * jnp.einsum("ecd,edf->ecf", xe, wu_c)
            return carry + jnp.einsum(
                "ecf,efd->ecd", h, wo_c).astype(jnp.float32), None

        if n_f_chunks > 1:
            split = lambda w, ax: jnp.stack(
                jnp.split(w, n_f_chunks, axis=ax), axis=0)
            ye0 = jnp.zeros((e_loc, capacity, D), jnp.float32)
            xs = (split(wg, 2), split(wu, 2), split(wod, 1))
            if cfg.probe_unroll:
                ye = ye0
                for i in range(n_f_chunks):
                    ye, _ = ffn_chunk(ye, jax.tree.map(lambda a: a[i], xs))
            else:
                ye, _ = jax.lax.scan(jax.checkpoint(ffn_chunk), ye0, xs)
            ye = ye.astype(x.dtype)
        else:
            if fs:
                wg = jax.lax.all_gather(wg, fs, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, fs, axis=1, tiled=True)
                wod = jax.lax.all_gather(wod, fs, axis=2, tiled=True)
            h = jax.nn.silu(
                jnp.einsum("ecd,edf->ecf", xe, wg)
            ) * jnp.einsum("ecd,edf->ecf", xe, wu)
            ye = jnp.einsum("ecf,efd->ecd", h, wod)  # (E_loc, C, D)

        y = jnp.zeros((n_loc, D), jnp.float32)
        for s in range(k):
            e_rel = eidx[:, s] - e0
            mine = keep[s] & (e_rel >= 0) & (e_rel < e_loc)
            part = ye[jnp.where(mine, e_rel, 0), pos[s]].astype(jnp.float32)
            y = y + part * (gates[:, s] * mine)[:, None]
        if psum_axes:
            y = jax.lax.psum(y, psum_axes)           # experts + F partials

        # load-balance aux (local f/P are unbiased estimates; average over dp)
        f = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (
            n_loc * k)
        aux = E * jnp.sum(f * probs.mean(0))
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return y.reshape(xb.shape).astype(x.dtype), aux

    in_specs = (
        P(dp or None, None, None),               # x
        P(fs, None),                             # router
        P(e_axes or None, fs, f_axes or None),   # wi_g
        P(e_axes or None, fs, f_axes or None),   # wi_u
        P(e_axes or None, f_axes or None, fs),   # wo
    )
    out_specs = (P(dp, None, None), P())
    y, aux = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(x, router_w, wi_g, wi_u, wo)
    return y, aux


def moe_ffn(cfg: ModelConfig, x, router_w, wi_g, wi_u, wo, shardings=None):
    """x: (B, T, D).  router_w: (D, E).  expert weights: (E, D, F)/(E, F, D).

    Returns (y, aux_loss)."""
    B, T, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    N = B * T
    xf = x.reshape(N, D)

    logits = jnp.einsum(
        "nd,de->ne", xf.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)          # (N, E)
    gates, eidx = jax.lax.top_k(probs, k)            # (N, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, math.ceil(N * k * cfg.capacity_factor / E))

    # ---- sort-based positions: slot-major flatten => first choices win ----
    e_flat = eidx.T.reshape(N * k)                   # (k*N,) slot-major
    order = jnp.argsort(e_flat, stable=True)         # tokens grouped by expert
    e_sorted = e_flat[order]
    starts = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    rank_sorted = jnp.arange(N * k) - starts[e_sorted]
    pos_flat = jnp.zeros((N * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32)
    )
    keep_flat = pos_flat < capacity
    pos_flat = jnp.where(keep_flat, pos_flat, 0)
    pos = pos_flat.reshape(k, N)
    keep = keep_flat.reshape(k, N)
    e_slot = eidx.T                                   # (k, N)

    # ---- dispatch into (E, C, D) buffers ----
    xe = jnp.zeros((E, capacity, D), x.dtype)
    for s in range(k):
        contrib = jnp.where(keep[s][:, None], xf, 0)
        xe = xe.at[e_slot[s], pos[s]].add(contrib)
    xe = _wsc(xe, shardings, "moe_xe")

    # ---- expert FFN (SwiGLU), dense per-expert batches ----
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", xe, wi_g)
    ) * jnp.einsum("ecd,edf->ecf", xe, wi_u)
    h = _wsc(h, shardings, "moe_h")
    ye = jnp.einsum("ecf,efd->ecd", h, wo)           # (E, C, D)
    ye = _wsc(ye, shardings, "moe_xe")

    # ---- combine ----
    y = jnp.zeros((N, D), jnp.float32)
    for s in range(k):
        part = ye[e_slot[s], pos[s]].astype(jnp.float32)
        w = (gates[:, s] * keep[s])[:, None]
        y = y + part * w

    # ---- load-balance aux loss (Switch): E * sum_e f_e * P_e ----
    f = jnp.zeros((E,), jnp.float32).at[e_flat].add(1.0) / (N * k)
    p_mean = probs.mean(0)
    aux = E * jnp.sum(f * p_mean)

    return y.reshape(B, T, D).astype(x.dtype), aux
