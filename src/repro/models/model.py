"""Model assembly: parameter templates, init, sharding specs, and the three
entry points every architecture exposes:

    forward_train(cfg, params, batch)            -> (logits, aux_loss)
    prefill(cfg, params, batch, max_len)         -> (last_logits, cache)
    decode_step(cfg, params, cache, tok, cur_len)-> (logits, cache)

Layer heterogeneity is a repeating group of LayerSpecs; parameters for each
slot are stacked over `num_groups` and the stack is consumed by lax.scan
(HLO size O(1) in depth — essential for fast compiles at 512 devices).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as A
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models.layers import dtype_of, gated_mlp, normal_init, pdtype_of, rms_norm
from repro.models.sharding import ShardingPolicy

PyTree = Any


# ===========================================================================
# Parameter templates: single source of truth for shapes / roles / init
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    role: str                  # key into ShardingPolicy.spec
    scale: float = 0.02
    dtype: Optional[str] = None  # override (e.g. f32 for norms/router)
    init: str = "normal"       # "normal" | "zeros" | "ssm_dt" | "ssm_alog"


def _attn_slot_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    G = cfg.num_groups
    D, H, K = cfg.d_model, cfg.num_heads, cfg.kv_heads
    hd = cfg.resolved_head_dim
    out_scale = 0.02 / math.sqrt(2 * cfg.num_layers)
    d = {
        "norm": ParamDef((G, D), "norm", dtype="float32", init="zeros"),
        "wq": ParamDef((G, D, H, hd), "wq"),
        "wk": ParamDef((G, D, K, hd), "wkv"),
        "wv": ParamDef((G, D, K, hd), "wkv"),
        "wo": ParamDef((G, H, hd, D), "wo", scale=out_scale),
    }
    if cfg.sandwich_norm:
        d["post_norm"] = ParamDef((G, D), "norm", dtype="float32", init="zeros")
    return d


def _mamba_slot_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    G = cfg.num_groups
    D, di, st, h, w = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.ssm_heads, cfg.conv_width)
    out_scale = 0.02 / math.sqrt(2 * cfg.num_layers)
    d = {
        "norm": ParamDef((G, D), "norm", dtype="float32", init="zeros"),
        "w_x": ParamDef((G, D, di), "ssm_in"),
        "w_z": ParamDef((G, D, di), "ssm_in"),
        "w_B": ParamDef((G, D, st), "ssm_in_state"),
        "w_C": ParamDef((G, D, st), "ssm_in_state"),
        "w_dt": ParamDef((G, D, h), "ssm_dt"),
        "conv_x": ParamDef((G, w, di), "ssm_conv", scale=0.1),
        "conv_B": ParamDef((G, w, st), "ssm_conv", scale=0.1),
        "conv_C": ParamDef((G, w, st), "ssm_conv", scale=0.1),
        "dt_bias": ParamDef((G, h), "ssm_vec", dtype="float32", init="ssm_dt"),
        "A_log": ParamDef((G, h), "ssm_vec", dtype="float32", init="ssm_alog"),
        "D_skip": ParamDef((G, h), "ssm_vec", dtype="float32", init="zeros"),
        "gate_norm": ParamDef((G, di), "ssm_vec", dtype="float32", init="zeros"),
        "w_out": ParamDef((G, di, D), "ssm_out", scale=out_scale),
    }
    if cfg.sandwich_norm:
        d["post_norm"] = ParamDef((G, D), "norm", dtype="float32", init="zeros")
    return d


def _ffn_slot_defs(cfg: ModelConfig, moe: bool) -> Dict[str, ParamDef]:
    G, D, F = cfg.num_groups, cfg.d_model, cfg.d_ff
    out_scale = 0.02 / math.sqrt(2 * cfg.num_layers)
    d: Dict[str, ParamDef] = {
        "norm2": ParamDef((G, D), "norm", dtype="float32", init="zeros"),
    }
    if cfg.sandwich_norm:
        d["post_norm2"] = ParamDef((G, D), "norm", dtype="float32", init="zeros")
    if moe:
        E = cfg.num_experts
        d.update({
            "router": ParamDef((G, D, E), "router", dtype="float32"),
            "e_wi_g": ParamDef((G, E, D, F), "expert_wi"),
            "e_wi_u": ParamDef((G, E, D, F), "expert_wi"),
            "e_wo": ParamDef((G, E, F, D), "expert_wo", scale=out_scale),
        })
        if cfg.dense_residual:
            d.update({
                "wi_g": ParamDef((G, D, F), "wi"),
                "wi_u": ParamDef((G, D, F), "wi"),
                "wo_m": ParamDef((G, F, D), "wo_mlp", scale=out_scale),
            })
    else:
        if cfg.mlp_gated:
            d.update({
                "wi_g": ParamDef((G, D, F), "wi"),
                "wi_u": ParamDef((G, D, F), "wi"),
                "wo_m": ParamDef((G, F, D), "wo_mlp", scale=out_scale),
            })
        else:
            d.update({
                "wi_u": ParamDef((G, D, F), "wi"),
                "wo_m": ParamDef((G, F, D), "wo_mlp", scale=out_scale),
            })
    return d


def param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    """Nested dict of ParamDef mirroring the params pytree."""
    defs: Dict[str, Any] = {}
    D, Vp = cfg.d_model, cfg.vocab_padded
    if cfg.frontend == "none":
        defs["embed"] = ParamDef((Vp, D), "embed")
    else:
        defs["embed"] = ParamDef((Vp, D), "embed")      # text side still exists
        defs["frontend_proj"] = ParamDef((cfg.frontend_dim, D), "frontend")
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((D, Vp), "head")
    defs["final_norm"] = ParamDef((D,), "norm", dtype="float32", init="zeros")

    blocks = []
    for spec in cfg.group:
        slot: Dict[str, ParamDef] = {}
        if spec.kind == "attn":
            slot.update(_attn_slot_defs(cfg))
        else:
            slot.update(_mamba_slot_defs(cfg))
        if cfg.d_ff > 0:
            slot.update(_ffn_slot_defs(cfg, spec.moe))
        blocks.append(slot)
    defs["blocks"] = blocks
    return defs


def init_params(cfg: ModelConfig, key) -> PyTree:
    defs = param_defs(cfg)
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(leaves))
    pdt = pdtype_of(cfg)

    def mk(d: ParamDef, k):
        dt = jnp.dtype(d.dtype) if d.dtype else pdt
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ssm_dt":
            # dt_bias ~ softplus^-1(uniform(1e-3, 1e-1))
            u = jax.random.uniform(k, d.shape, jnp.float32,
                                   math.log(1e-3), math.log(1e-1))
            dtv = jnp.exp(u)
            return (dtv + jnp.log(-jnp.expm1(-dtv))).astype(dt)
        if d.init == "ssm_alog":
            a = jax.random.uniform(k, d.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(a).astype(dt)
        return normal_init(k, d.shape, dt, d.scale)

    return jax.tree.unflatten(treedef, [mk(d, k) for d, k in zip(leaves, keys)])


def param_specs(cfg: ModelConfig, policy: ShardingPolicy) -> PyTree:
    defs = param_defs(cfg)

    def to_spec(d: ParamDef):
        base = policy.spec(d.role, cfg)
        # block-stacked params have a leading group dim: prepend None
        if d.role not in ("embed", "head", "frontend", "norm", "scalar") and \
                len(d.shape) > len(base):
            from jax.sharding import PartitionSpec as P
            return P(*((None,) + tuple(base)))
        return base

    return jax.tree.map(
        to_spec, defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


# ===========================================================================
# Forward pass
# ===========================================================================
def _embed_inputs(cfg: ModelConfig, params, batch):
    dt = dtype_of(cfg)
    if cfg.frontend != "none" and "embeds" in batch:
        x = jnp.einsum(
            "btf,fd->btd", batch["embeds"].astype(dt),
            params["frontend_proj"].astype(dt),
        )
    else:
        x = params["embed"].astype(dt)[batch["tokens"]]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    return x


def _positions(cfg: ModelConfig, batch, T: int):
    if "positions" in batch:
        return batch["positions"]
    B = (batch.get("tokens") if "tokens" in batch else batch["embeds"]).shape[0]
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    return pos


def _attn_apply(cfg: ModelConfig, spec: LayerSpec, p, x, cos, sin,
                cache_kv=None, cur_len=None, shardings=None):
    """Returns (attn_out, new_kv) — new_kv is (k, v) for cache building."""
    dt = dtype_of(cfg)
    B, T, D = x.shape
    H, K = cfg.num_heads, cfg.kv_heads
    hd = cfg.resolved_head_dim
    G = H // K
    q = jnp.einsum("btd,dnh->btnh", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dkh->btkh", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dkh->btkh", x, p["wv"].astype(dt))
    if cfg.rope_kind != "none":
        q = A.apply_rope(q, cos, sin)
        k = A.apply_rope(k, cos, sin)
    q = q.reshape(B, T, K, G, hd)
    # pin head sharding: without this GSPMD may replicate the score tensors
    q = _wsc(q, shardings, "q")
    k = _wsc(k, shardings, "kv")
    v = _wsc(v, shardings, "kv")

    if cache_kv is None:
        o = A.blockwise_attention(
            q, k, v, causal=cfg.causal, window=spec.window,
            softcap=cfg.attn_softcap, unroll=cfg.probe_unroll,
        )
        new_kv = (k, v)
    else:
        k_cache, v_cache = cache_kv
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cur_len - 1, axis=1
        ) if T == 1 else k_cache
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cur_len - 1, axis=1
        ) if T == 1 else v_cache
        o = A.decode_attention(
            q, k_cache, v_cache, cur_len, window=spec.window,
            softcap=cfg.attn_softcap,
        )
        new_kv = (k_cache, v_cache)
    o = o.reshape(B, T, H, hd)
    out = jnp.einsum("btnh,nhd->btd", o, p["wo"].astype(dt))
    return out, new_kv


def _mamba_apply(cfg: ModelConfig, p, x, cache=None, cur_len=None,
                 shardings=None):
    """Mamba2 block.  Returns (out, new_cache)."""
    dt_ = dtype_of(cfg)
    B, T, D = x.shape
    h, hd, st = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xz = _wsc(jnp.einsum("btd,de->bte", x, p["w_x"].astype(dt_)),
              shardings, "ssm_inner")
    z = _wsc(jnp.einsum("btd,de->bte", x, p["w_z"].astype(dt_)),
             shardings, "ssm_inner")
    Bm = jnp.einsum("btd,ds->bts", x, p["w_B"].astype(dt_))
    Cm = jnp.einsum("btd,ds->bts", x, p["w_C"].astype(dt_))
    dt_raw = jnp.einsum("btd,dh->bth", x, p["w_dt"].astype(dt_))
    dtv = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )
    Aneg = -jnp.exp(p["A_log"].astype(jnp.float32))

    w = cfg.conv_width
    if cache is None:
        # NOTE: conv tail must be taken from the *pre-activation* conv inputs
        xz_tail = xz[:, T - (w - 1):]
        B_tail = Bm[:, T - (w - 1):]
        C_tail = Cm[:, T - (w - 1):]
        xc = jax.nn.silu(M2.causal_conv(xz, p["conv_x"].astype(dt_)))
        Bc = jax.nn.silu(M2.causal_conv(Bm, p["conv_B"].astype(dt_)))
        Cc = jax.nn.silu(M2.causal_conv(Cm, p["conv_C"].astype(dt_)))
        xh = xc.reshape(B, T, h, hd)
        y, h_state = M2.ssd_chunked(xh, dtv, Aneg, Bc, Cc, cfg.ssm_chunk,
                                    unroll=cfg.probe_unroll)
        new_cache = {
            "h": h_state,
            "conv_x": xz_tail, "conv_B": B_tail, "conv_C": C_tail,
        }
    else:
        # single-token decode
        xt, cs_x = M2.conv_decode(xz[:, 0], cache["conv_x"], p["conv_x"].astype(dt_))
        Bt, cs_B = M2.conv_decode(Bm[:, 0], cache["conv_B"], p["conv_B"].astype(dt_))
        Ct, cs_C = M2.conv_decode(Cm[:, 0], cache["conv_C"], p["conv_C"].astype(dt_))
        xt, Bt, Ct = jax.nn.silu(xt), jax.nn.silu(Bt), jax.nn.silu(Ct)
        xh = xt.reshape(B, 1, h, hd)
        y1, h_next = M2.ssd_decode(
            xh[:, 0], dtv[:, 0], Aneg, Bt, Ct,
            cache["h"].astype(jnp.float32),
        )
        y = y1[:, None]
        new_cache = {"h": h_next, "conv_x": cs_x, "conv_B": cs_B, "conv_C": cs_C}

    # D skip-connection (per head, broadcast over head_dim)
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, T, h * hd)
    gated = y * jax.nn.silu(z)
    gated = rms_norm(gated, p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", gated.astype(dt_), p["w_out"].astype(dt_))
    return out, new_cache


def _ffn_apply(cfg: ModelConfig, spec: LayerSpec, p, x, shardings=None):
    """Dense or MoE FFN.  Returns (out, aux_loss)."""
    dt = dtype_of(cfg)
    aux = jnp.zeros((), jnp.float32)
    if spec.moe:
        policy = shardings.get("_policy") if shardings else None
        if policy is not None:
            y, aux = MOE.moe_ffn_sharded(
                cfg, x, p["router"], p["e_wi_g"].astype(dt),
                p["e_wi_u"].astype(dt), p["e_wo"].astype(dt), policy,
            )
        else:
            y, aux = MOE.moe_ffn(
                cfg, x, p["router"], p["e_wi_g"].astype(dt),
                p["e_wi_u"].astype(dt), p["e_wo"].astype(dt),
            )
        if cfg.dense_residual:
            y = y + gated_mlp(x, p["wi_g"].astype(dt), p["wi_u"].astype(dt),
                              p["wo_m"].astype(dt), unroll=cfg.probe_unroll)
    elif cfg.mlp_gated:
        y = gated_mlp(x, p["wi_g"].astype(dt), p["wi_u"].astype(dt),
                      p["wo_m"].astype(dt), unroll=cfg.probe_unroll)
    else:
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["wi_u"].astype(dt)))
        y = jnp.einsum("...f,fd->...d", h, p["wo_m"].astype(dt))
    return y, aux


def _block_apply(cfg: ModelConfig, spec: LayerSpec, p, x, cos, sin,
                 cache=None, cur_len=None, shardings=None):
    """One layer: (attn|mamba) + optional FFN, pre-norm residual.
    Returns (x, new_cache, aux)."""
    h_in = rms_norm(x, p["norm"], cfg.norm_eps)
    if spec.kind == "attn":
        mix, new_cache = _attn_apply(
            cfg, spec, p, h_in, cos, sin,
            cache_kv=None if cache is None else (cache["k"], cache["v"]),
            cur_len=cur_len, shardings=shardings,
        )
        if cache is not None:
            new_cache = {"k": new_cache[0], "v": new_cache[1]}
    else:
        mix, new_cache = _mamba_apply(
            cfg, p, h_in, cache=cache, cur_len=cur_len, shardings=shardings
        )
    if cfg.sandwich_norm:
        mix = rms_norm(mix, p["post_norm"], cfg.norm_eps)
    x = x + mix

    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff > 0:
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        y, aux = _ffn_apply(cfg, spec, p, h2, shardings=shardings)
        if cfg.sandwich_norm:
            y = rms_norm(y, p["post_norm2"], cfg.norm_eps)
        x = x + y
    return x, new_cache, aux


def _wsc(x, shardings, name):
    """with_sharding_constraint if a spec was provided for ``name``."""
    if shardings is not None and shardings.get(name) is not None:
        return jax.lax.with_sharding_constraint(x, shardings[name])
    return x


def _logits(cfg: ModelConfig, params, x, shardings=None):
    dt = dtype_of(cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(dt))
    else:
        logits = jnp.einsum("btd,dv->btv", x, params["head"].astype(dt))
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return _wsc(logits, shardings, "logits")


def forward_hidden(cfg: ModelConfig, params, batch, shardings=None):
    """Run the layer stack.  Returns (hidden (B,T,D), aux_loss)."""
    x = _embed_inputs(cfg, params, batch)
    B, T, _ = x.shape
    pos = _positions(cfg, batch, T)
    cos, sin = (A.rope_angles(cfg, pos) if cfg.rope_kind != "none"
                else (None, None))

    blocks = tuple(params["blocks"])
    x = _wsc(x, shardings, "acts")

    def layer_fn(spec, p, x):
        x, _, a = _block_apply(cfg, spec, p, x, cos, sin,
                               shardings=shardings)
        # layer-boundary activations are the only backward residuals; keep
        # them sharded over both dp and the model axes (docs/DESIGN.md §6)
        return _wsc(x, shardings, "acts"), a

    def group_body(carry, gp):
        x, aux = carry
        for i, (spec, p) in enumerate(zip(cfg.group, gp)):
            fn = functools.partial(layer_fn, spec)
            if cfg.remat:
                # PER-LAYER remat: the group backward recomputes one layer
                # at a time, so peak residency is a single layer's
                # intermediates even for jamba's 8-layer groups
                fn = jax.checkpoint(
                    fn, policy=jax.checkpoint_policies.nothing_saveable
                )
            x, a = fn(p, x)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(
        group_body, (x, jnp.zeros((), jnp.float32)), blocks
    )
    return x, aux / cfg.num_layers


def forward_train(cfg: ModelConfig, params, batch, shardings=None):
    """Full-sequence forward.  Returns (logits (B,T,Vp) f32, aux_loss)."""
    x, aux = forward_hidden(cfg, params, batch, shardings)
    return _logits(cfg, params, x, shardings), aux


def _ce_terms(cfg: ModelConfig, params, x, labels, shardings):
    """(nll_sum, valid_count) for one chunk — full logits never escape."""
    logits = _logits(cfg, params, x, shardings)
    valid = (labels >= 0) & (labels < cfg.vocab)
    labels_c = jnp.clip(labels, 0, cfg.vocab_padded - 1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: keeps the gather local
    # when the vocab dim is sharded (take_along would all-gather the logits)
    viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    ll = jnp.sum(
        jnp.where(viota == labels_c[..., None], logits, 0.0), axis=-1
    )
    nll = (logz - ll) * valid
    return nll.sum(), valid.sum()


def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight: float = 0.01,
            shardings=None, ce_chunks: int = 8):
    """CE loss with T-chunked head+softmax: the (B, T_chunk, V) logits block
    is materialized (and rematerialized in backward) one chunk at a time —
    the full (B, T, V) tensor never exists."""
    x, aux = forward_hidden(cfg, params, batch, shardings)
    labels = batch["labels"]
    B, T, D = x.shape
    while T % ce_chunks:
        ce_chunks //= 2
    if ce_chunks <= 1:
        ns, nv = _ce_terms(cfg, params, x, labels, shardings)
    elif cfg.probe_unroll:
        C = T // ce_chunks
        ns = jnp.zeros((), jnp.float32)
        nv = jnp.zeros((), jnp.int32)
        for i in range(ce_chunks):
            s_, v_ = _ce_terms(cfg, params, x[:, i * C:(i + 1) * C],
                               labels[:, i * C:(i + 1) * C], shardings)
            ns, nv = ns + s_, nv + v_
    else:
        C = T // ce_chunks
        xc = x.reshape(B, ce_chunks, C, D).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, ce_chunks, C).transpose(1, 0, 2)

        def chunk_body(carry, xs):
            xi, li = xs
            s, v = jax.checkpoint(
                lambda a, b: _ce_terms(cfg, params, a, b, shardings)
            )(xi, li)
            return (carry[0] + s, carry[1] + v), None

        (ns, nv), _ = jax.lax.scan(
            chunk_body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
            (xc, lc),
        )
    loss = ns / jnp.maximum(nv, 1)
    return loss + aux_weight * aux, (loss, aux)


# ===========================================================================
# Serving: cache init / prefill / decode
# ===========================================================================
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    """Cache pytree: per slot, stacked over groups (leading G dim)."""
    dt = dtype_of(cfg)
    G = cfg.num_groups
    K, hd = cfg.kv_heads, cfg.resolved_head_dim
    slots = []
    for spec in cfg.group:
        if spec.kind == "attn":
            slots.append({
                "k": jnp.zeros((G, batch, max_len, K, hd), dt),
                "v": jnp.zeros((G, batch, max_len, K, hd), dt),
            })
        else:
            slots.append({
                "h": jnp.zeros(
                    (G, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32,
                ),
                "conv_x": jnp.zeros((G, batch, cfg.conv_width - 1, cfg.d_inner), dt),
                "conv_B": jnp.zeros((G, batch, cfg.conv_width - 1, cfg.ssm_state), dt),
                "conv_C": jnp.zeros((G, batch, cfg.conv_width - 1, cfg.ssm_state), dt),
            })
    return tuple(slots)


def cache_specs(cfg: ModelConfig, policy: ShardingPolicy) -> PyTree:
    from jax.sharding import PartitionSpec as P
    slots = []
    for spec in cfg.group:
        if spec.kind == "attn":
            c = policy.cache_spec()
            s = P(*((None,) + tuple(c)))
            slots.append({"k": s, "v": s})
        else:
            h = policy.ssm_cache_spec()
            hs = P(*((None,) + tuple(h)))
            conv = P(None, policy.dp if not policy.seq_shard_data else None,
                     None, policy.tp_full)
            slots.append({
                "h": hs, "conv_x": conv,
                "conv_B": P(None, conv[1], None, None),
                "conv_C": P(None, conv[1], None, None),
            })
    return tuple(slots)


def prefill(cfg: ModelConfig, params, batch, max_len: int, shardings=None):
    """Forward over a prompt, building the cache.  Returns (last_logits,
    cache, cur_len)."""
    x = _embed_inputs(cfg, params, batch)
    B, T, _ = x.shape
    pos = _positions(cfg, batch, T)
    cos, sin = (A.rope_angles(cfg, pos) if cfg.rope_kind != "none"
                else (None, None))
    x = _wsc(x, shardings, "acts")

    def group_body(x, gp):
        caches = []
        for spec, p in zip(cfg.group, gp):
            x, nc, _ = _block_apply(cfg, spec, p, x, cos, sin,
                                    shardings=shardings)
            if spec.kind == "attn":
                k, v = nc
                pad = max_len - T
                caches.append({
                    "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
                })
            else:
                caches.append(nc)
        x = _wsc(x, shardings, "acts")
        return x, tuple(caches)

    x, cache = jax.lax.scan(group_body, x, tuple(params["blocks"]))
    logits = _logits(cfg, params, x[:, -1:], shardings)
    return logits, cache, jnp.asarray(T, jnp.int32)


def decode_step(cfg: ModelConfig, params, cache, tokens, cur_len,
                shardings=None):
    """One decode step.  tokens: (B, 1) int32 (or embeds for frontends);
    cur_len: int32 — length *including* the new token.  Returns
    (logits (B,1,Vp), new_cache)."""
    batch = {"tokens": tokens}
    x = _embed_inputs(cfg, params, batch)
    B, T, _ = x.shape
    pos = jnp.broadcast_to(cur_len - 1, (B, 1)).astype(jnp.int32)
    if cfg.rope_kind == "mrope":
        pos = pos[..., None] * jnp.ones((3,), jnp.int32)
    cos, sin = (A.rope_angles(cfg, pos) if cfg.rope_kind != "none"
                else (None, None))

    def group_body(x, scanned):
        gp, gcache = scanned
        new_caches = []
        for spec, p, c in zip(cfg.group, gp, gcache):
            x, nc, _ = _block_apply(cfg, spec, p, x, cos, sin,
                                    cache=c, cur_len=cur_len,
                                    shardings=shardings)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(
        group_body, x, (tuple(params["blocks"]), cache)
    )
    return _logits(cfg, params, x, shardings), new_cache
