"""GQA attention: RoPE / M-RoPE, logit softcap, sliding window, blockwise
causal-efficient computation, and single-token decode against a KV cache.

Blockwise attention uses *static* chunk pairs: q chunks are a Python loop,
and for each q chunk only the causally (and window-) reachable KV chunks are
touched — so compiled FLOPs match true causal cost (no masked-out half), and
peak memory is one (q_chunk × k_chunk) score block.  Online softmax combines
blocks in f32.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_angles(cfg: ModelConfig, positions):
    """positions: (B, T) int32 (std) or (B, T, 3) (mrope).
    Returns (cos, sin) of shape (B, T, hd/2) f32."""
    hd = cfg.resolved_head_dim
    half = hd // 2
    inv_freq = 1.0 / (
        cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    if cfg.rope_kind == "mrope":
        if positions.ndim == 2:
            positions = positions[..., None] * jnp.ones(
                (3,), dtype=positions.dtype
            )
        secs = cfg.mrope_sections
        assert sum(secs) == half, (secs, half)
        sec_id = jnp.concatenate(
            [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(secs)]
        )                                            # (half,)
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(sec_id, positions.shape[:-1] + (half,)),
            axis=-1,
        )                                            # (B, T, half)
        ang = pos * inv_freq
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, T, ..., hd); cos/sin: (B, T, hd/2) — rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    shape = cos.shape[:2] + (1,) * (x.ndim - 3) + (half,)
    c = cos.reshape(shape)
    s = sin.reshape(shape)
    xf = x.astype(jnp.float32)
    x1f, x2f = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention (training / prefill)
# ---------------------------------------------------------------------------
def _soft_cap(scores, cap: float):
    if cap and cap > 0:
        return jnp.tanh(scores / cap) * cap
    return scores


def _auto_q_chunk(n: int) -> int:
    c = max(512, n // 8)
    return min(c, 2048, n)


def _auto_k_chunk(n: int) -> int:
    return min(1024, n)


def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        softcap: float = 0.0, q_chunk: int = 0,
                        k_chunk: int = 0, unroll: bool = False):
    """q: (B, T, K, G, hd); k, v: (B, S, K, hd).  Returns (B, T, K, G, hd).

    Flash-style: a static Python loop over q chunks, and per q chunk a
    `lax.scan` over exactly the causally (and window-) reachable KV chunks
    with a `jax.checkpoint`-ed body, so

      * compiled FLOPs match true causal/window cost (future chunks are
        statically absent, the KV scan length is a Python int per q chunk),
      * peak memory is ONE (q_chunk × k_chunk) score block — the backward
        recomputes score blocks instead of saving them (flash backward),
      * HLO size is O(num_q_chunks), compile-friendly at 500k context.
    """
    B, T, K, G, hd = q.shape
    S = k.shape[1]
    q_chunk = min(q_chunk or _auto_q_chunk(T), T)
    k_chunk = min(k_chunk or _auto_k_chunk(S), S)
    nq = math.ceil(T / q_chunk)
    assert T % q_chunk == 0 and S % k_chunk == 0, (T, S, q_chunk, k_chunk)
    scale = 1.0 / math.sqrt(hd)
    nk_total = S // k_chunk

    def block_update(qi, q_lo, kj, vj, k_lo, carry):
        """One online-softmax update; k_lo may be traced (scan) or static."""
        acc, m, l = carry
        s = jnp.einsum(
            "btkgd,bskd->btkgs", qi, kj, preferred_element_type=jnp.float32,
        ) * scale
        s = _soft_cap(s, softcap)
        if causal or window:
            qpos = q_lo + jnp.arange(q_chunk)[:, None]
            kpos = k_lo + jnp.arange(k_chunk)[None, :]
            ok = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                ok &= kpos <= qpos
            if window:
                ok &= kpos >= qpos - window
            s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        acc = acc * alpha[..., None] + jnp.einsum(
            "btkgs,bskd->btkgd", p.astype(v.dtype), vj,
            preferred_element_type=jnp.float32,
        )
        l = l * alpha + p.sum(axis=-1)
        return acc, m_new, l

    out_chunks = []
    for i in range(nq):
        q_lo = i * q_chunk
        qi = q[:, q_lo : q_lo + q_chunk]
        # statically reachable KV chunk range for this q chunk
        last = min((q_lo + q_chunk - 1) // k_chunk, nk_total - 1) \
            if causal else nk_total - 1
        first = max(0, (q_lo - window) // k_chunk) if window else 0
        n_blocks = last - first + 1
        carry = (
            jnp.zeros((B, q_chunk, K, G, hd), jnp.float32),
            jnp.full((B, q_chunk, K, G), NEG_INF, jnp.float32),
            jnp.zeros((B, q_chunk, K, G), jnp.float32),
        )
        if n_blocks <= 2 or unroll:
            for j in range(first, last + 1):
                kj = k[:, j * k_chunk : (j + 1) * k_chunk]
                vj = v[:, j * k_chunk : (j + 1) * k_chunk]
                carry = block_update(qi, q_lo, kj, vj, j * k_chunk, carry)
        else:
            ks = k[:, first * k_chunk : (last + 1) * k_chunk].reshape(
                B, n_blocks, k_chunk, K, hd).transpose(1, 0, 2, 3, 4)
            vs = v[:, first * k_chunk : (last + 1) * k_chunk].reshape(
                B, n_blocks, k_chunk, K, hd).transpose(1, 0, 2, 3, 4)
            offs = (first + jnp.arange(n_blocks)) * k_chunk

            @jax.checkpoint
            def body(carry, xs):
                kj, vj, k_lo = xs
                return block_update(qi, q_lo, kj, vj, k_lo, carry), None

            carry, _ = jax.lax.scan(body, carry, (ks, vs, offs))
        acc, m, l = carry
        out_chunks.append(
            (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype))
    return jnp.concatenate(out_chunks, axis=1)


# ---------------------------------------------------------------------------
# Decode attention (single new token vs. cache)
# ---------------------------------------------------------------------------
def decode_attention(q, k_cache, v_cache, cur_len, *, window: int = 0,
                     softcap: float = 0.0):
    """q: (B, 1, K, G, hd); caches: (B, S, K, hd); cur_len: scalar int32 —
    number of valid cache positions (including the token just written)."""
    B, _, K, G, hd = q.shape
    S = k_cache.shape[1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum(
        "bukgd,bskd->bkgs", q, k_cache, preferred_element_type=jnp.float32
    ) * scale
    s = _soft_cap(s, softcap)
    kpos = jnp.arange(S)
    ok = kpos < cur_len
    if window:
        ok &= kpos >= cur_len - 1 - window
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out[:, None].astype(q.dtype)
