"""Pure-JAX LM substrate: dense / MoE / SSM / hybrid transformer stacks with
scan-over-layers, GQA attention (RoPE / M-RoPE / softcap / sliding window),
capacity-based MoE, Mamba2 SSD — plus the sharding policy that maps every
architecture onto the production mesh."""
