"""Training substrate: hand-rolled AdamW (f32 + 8-bit moment variants),
train-step factory with microbatch accumulation and donation, gradient
compression, and sharded checkpointing."""
