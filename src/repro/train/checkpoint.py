"""Sharded, fault-tolerant checkpointing.

Design (docs/DESIGN.md §6):
  * per-leaf .npy files + a JSON manifest describing the pytree, shapes,
    dtypes, step, and data-iterator state;
  * atomic commit: write to ``<dir>/tmp.<step>`` then rename to
    ``<dir>/step_<step>`` — a crash mid-write never corrupts the latest
    checkpoint;
  * keep-last-K garbage collection;
  * restore *reshards*: arrays are placed with whatever NamedSharding the
    restoring job provides, so a checkpoint taken on a (16,16) mesh restores
    onto (2,16,16), a shrunken elastic mesh, or a single host;
  * async save: a background thread does the file I/O after the arrays are
    fetched, so the train loop blocks only for the device->host copy.

In a multi-process deployment each process would write only
``jax.Array.addressable_shards``; in this single-process container that is
the full array — the manifest format carries shard metadata either way.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

PyTree = Any
_MANIFEST = "manifest.json"

# numpy can't natively (de)serialize bfloat16/fp8 — store as a same-width
# integer view and restore through ml_dtypes using the manifest's dtype
_EXOTIC = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
           "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
           "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2)}


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "_".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out.append((name, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: PyTree, *, extra: Optional[dict] = None,
         keep_last: int = 3, async_write: bool = False):
    """Save a checkpoint.  Returns the final directory path (or a thread)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

    def _write():
        tmp = os.path.join(ckpt_dir, f"tmp.{step}.{os.getpid()}")
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for name, leaf in _leaf_paths(host_tree):
            fn = f"{name}.npy"
            dtype = str(leaf.dtype)
            to_save = leaf
            if dtype in _EXOTIC:
                to_save = leaf.view(_EXOTIC[dtype][0])
            np.save(os.path.join(tmp, fn), to_save)
            manifest["leaves"].append(
                {"name": name, "file": fn,
                 "shape": list(leaf.shape), "dtype": dtype}
            )
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        _gc(ckpt_dir, keep_last)
        return final

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    return _write()


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_")
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    for d in os.listdir(ckpt_dir):
        if d.startswith("tmp.") and os.path.isdir(os.path.join(ckpt_dir, d)):
            # stale partial write from a crashed process
            age = time.time() - os.path.getmtime(os.path.join(ckpt_dir, d))
            if age > 3600:
                shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_")
    )
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like: PyTree, *, step: Optional[int] = None,
            shardings: Optional[PyTree] = None):
    """Restore into the structure of ``like``.  If ``shardings`` (a pytree of
    NamedSharding matching ``like``) is given, arrays are placed sharded —
    this is the elastic-resharding path.  Returns (tree, step, extra)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    by_name = {m["name"]: m for m in manifest["leaves"]}

    names = [n for n, _ in _leaf_paths(like)]
    leaves_like = [l for _, l in _leaf_paths(like)]
    flat_like, treedef = jax.tree.flatten(like)
    assert len(flat_like) == len(names)

    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(names))
    out = []
    for name, ref, sh in zip(names, leaves_like, shard_flat):
        meta = by_name[name]
        arr = np.load(os.path.join(d, meta["file"]))
        if meta["dtype"] in _EXOTIC:
            arr = arr.view(_EXOTIC[meta["dtype"]][1])
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs {ref.shape}"
            )
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), step, manifest.get("extra", {})
