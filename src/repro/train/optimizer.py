"""AdamW with optional 8-bit (int8, per-row absmax) first/second moments.

8-bit moments cut optimizer HBM from 8 bytes/param to 2 + ~0.02 — the
difference between arctic-480b fitting a 256-chip pod or not (docs/DESIGN.md §5).
Quantization is per-row (last axis) absmax, symmetric for m, asymmetric-free
for v (v >= 0 so we store sqrt(v) scaled, which also improves precision).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    eightbit: bool = False
    warmup_steps: int = 100
    total_steps: int = 10000


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum((step + 1.0) / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


# ---------------------------------------------------------------------------
# int8 moment codecs
# ---------------------------------------------------------------------------
def _q8(x):
    """Symmetric per-row int8 quantization.  x: f32 (..., D)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q, scale):
    return q.astype(jnp.float32) * scale


def init_opt_state(params, cfg: OptConfig):
    def per_leaf(p):
        if cfg.eightbit and p.ndim >= 1 and p.size > 4096:
            row = p.shape[:-1] + (1,)
            return {
                "m_q": jnp.zeros(p.shape, jnp.int8),
                "m_s": jnp.ones(row, jnp.float32),
                "v_q": jnp.zeros(p.shape, jnp.int8),
                "v_s": jnp.ones(row, jnp.float32),
            }
        return {
            "m": jnp.zeros(p.shape, jnp.float32),
            "v": jnp.zeros(p.shape, jnp.float32),
        }

    return jax.tree.map(per_leaf, params)


def opt_state_specs(param_specs_tree, params_shape_tree, cfg: OptConfig):
    """Mirror parameter PartitionSpecs onto the optimizer state."""
    from jax.sharding import PartitionSpec as P

    def per_leaf(spec, p):
        if cfg.eightbit and len(p.shape) >= 1 and _size(p.shape) > 4096:
            # scale has a trailing singleton: same spec with last dim None
            s = tuple(spec) + (None,) * (len(p.shape) - len(tuple(spec)))
            scale_spec = P(*(s[:-1] + (None,)))
            return {"m_q": spec, "m_s": scale_spec,
                    "v_q": spec, "v_s": scale_spec}
        return {"m": spec, "v": spec}

    return jax.tree.map(
        per_leaf, param_specs_tree, params_shape_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )


def _size(shape):
    n = 1
    for s in shape:
        n *= s
    return n


def _sqsum(x):
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def global_norm(tree):
    total = jnp.zeros((), jnp.float32)
    for g in jax.tree.leaves(tree):
        if g.size > (1 << 27) and g.ndim >= 2 and g.shape[0] > 1:
            # chunk over the layer-stack axis: avoids materializing a full
            # f32 copy of multi-GB bf16 gradient leaves just to reduce them
            total = total + jnp.sum(jax.lax.map(_sqsum, g))
        else:
            total = total + _sqsum(g)
    return jnp.sqrt(total)


def adamw_update(params, grads, state, step, cfg: OptConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def leaf_core(p, g, s):
        g = g.astype(jnp.float32) * clip
        if "m_q" in s:
            m = _dq8(s["m_q"], s["m_s"])
            v = _dq8(s["v_q"], s["v_s"]) ** 2      # stored as sqrt(v)
        else:
            m, v = s["m"], s["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (upd + cfg.weight_decay * pf)
        new_p = pf.astype(p.dtype)
        if "m_q" in s:
            mq, ms = _q8(m)
            vq, vs = _q8(jnp.sqrt(v))
            return new_p, {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
        return new_p, {"m": m, "v": v}

    def per_leaf(p, g, s):
        # chunk the elementwise update over the leading (layer-stack) axis
        # for huge leaves: bounds the transient f32 (dequantized) moments —
        # a 1.1 TB expert tensor would otherwise spike ~4x its shard in f32
        if p.size > (1 << 27) and p.ndim >= 2 and p.shape[0] > 1:
            return jax.lax.map(lambda a: leaf_core(*a), (p, g, s))
        return leaf_core(p, g, s)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state)
    out = [per_leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
