"""Train-step factory: loss + grad + AdamW under pjit with full sharding,
microbatch gradient accumulation (compute/comm overlap: one gradient
reduction per step regardless of microbatch count), buffer donation, and an
optional HHE-encrypted data plane (batches arrive as Rubato/HERA ciphertext
and are decrypted on-device by keystream subtraction — the paper's cipher
fused into the input pipeline)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.sharding import ShardingPolicy
from repro.train.optimizer import OptConfig, adamw_update, opt_state_specs


def batch_specs(cfg: ModelConfig, policy: ShardingPolicy, *, train: bool = True):
    bs = policy.batch_spec()  # P(dp, None) or P(None, dp)
    d: dict = {}
    if cfg.frontend == "none":
        d["tokens"] = bs
    else:
        d["embeds"] = P(*(tuple(bs) + (None,)))
        if cfg.rope_kind == "mrope":
            d["positions"] = P(*(tuple(bs) + (None,)))
    if train:
        d["labels"] = bs
    return d


def _shard(mesh, tree_specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def act_shardings(cfg: ModelConfig, policy: ShardingPolicy):
    """Internal activation constraints: scan carries sharded over dp AND the
    model axes (keeps per-step backward residuals ~50 MB/dev instead of
    ~1 GB/dev), logits sharded over vocab, attention heads pinned to the tp
    sub-axes (otherwise GSPMD may replicate the score tensors)."""
    mesh = policy.mesh
    bs = tuple(policy.batch_spec())  # (dp, None) or (None, dp)
    b = bs[0] if not policy.seq_shard_data else None
    t = bs[1] if not policy.seq_shard_data else bs[1]
    # Scan carries stay D-sharded over the model axes: replicating them
    # (tried as §Perf iter A2) tripled peak HBM (7.5 -> 21.8 GB for mamba2)
    # without moving the collective term — REFUTED; the layer-boundary
    # cotangent reshards are cheaper than the residual blow-up.
    return {
        "acts": NamedSharding(mesh, P(bs[0], bs[1], policy.tp_full)),
        "logits": NamedSharding(mesh, P(bs[0], bs[1], policy.tp_full)),
        # q: (B, T, K, G, hd); k/v: (B, T(kv), K, hd)
        "q": NamedSharding(mesh, P(b, t, "tp_a", "tp_b", None)),
        "kv": NamedSharding(mesh, P(b, t, "tp_a", None)),
        # mamba inner activations: channels over the full model axes
        "ssm_inner": NamedSharding(mesh, P(b, t, policy.tp_full)),
        # MoE runs under shard_map (models/moe.py moe_ffn_sharded) — the
        # policy rides along so layers can enter shard_map with the mesh
        "_policy": policy,
    }


def make_train_step(cfg: ModelConfig, policy: ShardingPolicy,
                    opt: OptConfig, *, microbatch: int = 1,
                    decryptor=None, donate: bool = True):
    """Returns (jitted_step, shardings dict).

    step(params, opt_state, batch, step_idx) ->
        (params, opt_state, metrics)

    If ``decryptor`` is given (see data/encrypted.py), the batch carries
    ciphertext + block counters and is decrypted on-device first.
    """
    mesh = policy.mesh
    acts = act_shardings(cfg, policy)

    def step_fn(params, opt_state, batch, step_idx):
        if decryptor is not None:
            batch = decryptor(batch)

        def loss_of(p, b):
            return M.loss_fn(cfg, p, b, shardings=acts)

        if microbatch > 1:
            def split(x):
                # interleaved split: (B,) -> (B//m, m) -> (m, B//m) so every
                # device contributes rows to every microbatch (keeps the dp
                # sharding of the batch dim intact through the reshape)
                b = x.shape[0]
                xr = x.reshape((b // microbatch, microbatch) + x.shape[1:])
                return jnp.moveaxis(xr, 1, 0)
            mb = jax.tree.map(split, batch)

            def acc_body(carry, b):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, b)
                gsum = jax.tree.map(
                    lambda a, x: a + x.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), None

            # accumulate in f32 for f32 masters, in bf16 for bf16 masters —
            # a second f32 copy of a 480B-param gradient tree is the
            # difference between fitting 16 GB/chip or not
            acc_dt = (jnp.bfloat16 if cfg.param_dtype == "bfloat16"
                      else jnp.float32)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params
            )
            (gsum, lsum), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32)), mb
            )
            grads = jax.tree.map(lambda g: g / microbatch, gsum)
            loss = lsum / microbatch
        else:
            (loss, (_, aux)), grads = jax.value_and_grad(
                loss_of, has_aux=True
            )(params, batch)

        new_params, new_state, om = adamw_update(
            params, grads, opt_state, step_idx, opt
        )
        metrics = {"loss": loss, **om}
        return new_params, new_state, metrics

    pspecs = M.param_specs(cfg, policy)
    params_shapes = jax.eval_shape(
        functools.partial(M.init_params, cfg), jax.random.key(0)
    )
    ospecs = opt_state_specs(pspecs, params_shapes, opt)
    if decryptor is not None:
        # encrypted batches: ciphertext shards like tokens, counter replicated
        bspecs = {"ct": policy.batch_spec(), "base_ctr": P()}
    else:
        bspecs = batch_specs(cfg, policy, train=True)

    in_sh = (
        _shard(mesh, pspecs),
        _shard(mesh, ospecs),
        _shard(mesh, bspecs),
        NamedSharding(mesh, P()),
    )
    out_sh = (
        _shard(mesh, pspecs),
        _shard(mesh, ospecs),
        NamedSharding(mesh, P()),
    )
    jitted = jax.jit(
        step_fn,
        in_shardings=in_sh,
        out_shardings=out_sh,
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, {"params": pspecs, "opt": ospecs, "batch": bspecs}
