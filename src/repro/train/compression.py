"""Int8 error-feedback gradient compression for cross-pod reduction.

At multi-pod scale the pod-to-pod links are the scarcest bandwidth; the
standard mitigation is quantized all-reduce with error feedback (the
quantization residual is carried to the next step, so the compression is
unbiased over time).  Implemented with shard_map over the "pod" axis:

    g_local   -> q8(g_local + err)            (int8 + per-row scale)
    q8 psum over pods (int32 accumulate)      (8x fewer bytes on the link)
    g_hat     -> dequant / n_pods
    err'      = (g_local + err) - g_hat_own_contribution

Used by wrapping the gradient tree between backward and the optimizer; the
error buffer lives in the train state.  CPU dry-runs exercise the same
collective graph.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def _q8(x):
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def init_error_buffers(grads_like):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
    )


def compressed_pod_reduce(grads, err, mesh, axis: str = "pod"):
    """All-reduce ``grads`` over ``axis`` in int8 with error feedback.

    grads: pytree of f32, already reduced within a pod (i.e. the natural
    GSPMD output); err: matching error-feedback buffers.
    Returns (reduced_grads, new_err).
    """
    npods = mesh.shape[axis]

    def leaf(g, e):
        def body(gl, el):
            x = gl + el
            q, s = _q8(x)
            qsum = jax.lax.psum(q.astype(jnp.int32), axis)
            ssum = jax.lax.psum(s, axis)  # conservative shared scale
            ghat = qsum.astype(jnp.float32) * (ssum / npods) / npods
            new_e = x - q.astype(jnp.float32) * s
            return ghat, new_e

        spec = P()  # grads replicated across pods at this point
        return shard_map(
            body, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec),
            check_vma=False,
        )(g, e)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))
