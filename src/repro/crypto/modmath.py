"""uint32-native modular arithmetic over Z_q for q < 2^28.

TPU has no native 64-bit integer multiply, so we never form a product wider
than 32 bits.  The scheme (docs/DESIGN.md §2):

  * operands are split into L-bit limbs with L = ceil(qbits / 2) <= 14, so
    every partial product is < 2^(2L) <= 2^28 < 2^31;
  * q is required to be in "Solinas-friendly" position: R = 2^(2L) mod q must
    satisfy R * 2^L + 2^(2L) < 2^32 so that the shift-reduce step also stays
    inside uint32.  The shipped primes (2^28 - 2^16 + 1 and 2^25 - 2^14 + 1)
    satisfy this with huge margin.

Reduction never uses integer division: every intermediate has a small static
bound k*q, and we reduce with a branchless conditional-subtract chain of
ceil(log2(k)) + 1 steps.  This is the TPU analogue of the paper's shift-add /
no-DSP datapath: adds, compares and selects only.

All public ops are jax-traceable and operate elementwise on uint32 arrays
whose values are in [0, q).
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class BoundSite:
    """One static proof obligation: a worst-case value ``bound`` at a named
    datapath site that must stay within ``limit`` (2^32 for uint32 fit;
    q for post-reduce residuals).  Enumerated by
    :meth:`Modulus.mul_bound_sites` / :meth:`Modulus.accumulate_sites` and
    consumed by `repro.analysis.bounds`."""

    site: str
    bound: int
    limit: int

    @property
    def ok(self) -> bool:
        return self.bound <= self.limit

    @property
    def margin_bits(self) -> float:
        """Headroom in bits (negative = violated)."""
        if self.bound <= 0:
            return float("inf")
        return math.log2(self.limit) - math.log2(self.bound)


def _is_prime(n: int) -> bool:
    if n < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if n % p == 0:
            return n == p
    # deterministic Miller-Rabin for n < 3.3e24 with these bases
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


@dataclasses.dataclass(frozen=True)
class Modulus:
    """Static description of a prime modulus q < 2^28 plus limb constants."""

    q: int

    def __post_init__(self):
        if not (2 < self.q < 2**28):
            raise ValueError(f"q={self.q} out of supported range (2, 2^28)")
        if not _is_prime(self.q):
            raise ValueError(f"q={self.q} must be prime")
        # Safety envelope for the limb scheme (checked, not assumed).
        if self.R * (1 << self.L) + (1 << (2 * self.L)) >= 2**32:
            raise ValueError(
                f"q={self.q}: R=2^(2L) mod q = {self.R} too large for the "
                "uint32 limb scheme; pick a Solinas-form prime"
            )

    # ---- static (Python int) derived constants -------------------------
    @property
    def bits(self) -> int:
        return self.q.bit_length()

    @property
    def L(self) -> int:
        """Limb width in bits."""
        return (self.bits + 1) // 2

    @property
    def mask(self) -> int:
        return (1 << self.L) - 1

    @property
    def R(self) -> int:
        """2^(2L) mod q — the shift-reduce constant."""
        return (1 << (2 * self.L)) % self.q

    # ---- reduction helpers ---------------------------------------------
    def reduce_steps(self, bound: int) -> tuple:
        """The static multiples m of q the conditional-subtract chain in
        :meth:`reduce` fires for operands < ``bound``, largest first.

        This IS the chain `reduce` executes (it consults this helper), so
        the static-analysis proof over these steps
        (`repro.analysis.bounds`) describes the shipped datapath, not a
        model of it.
        """
        q = self.q
        k = (bound + q - 1) // q  # x < k*q
        m = 1
        while m * 2 < k:
            m *= 2
        steps = []
        # subtract m*q, m/2*q, ..., q
        while m >= 1:
            steps.append(m)
            m //= 2
        return tuple(steps)

    def reduce_residual_bound(self, bound: int) -> int:
        """Exact worst-case value bound after :meth:`reduce` on operands
        < ``bound`` — an interval walk of the conditional-subtract chain.

        Full reduction means the result is <= q, i.e. values land in
        [0, q); `repro.analysis.bounds` asserts that (and that ``bound``
        itself fits uint32) for every static reduce site in the cipher
        datapath.
        """
        b = bound
        for m in self.reduce_steps(bound):
            mq = m * self.q
            if b > mq:
                # values >= mq drop to < b - mq; values < mq are untouched
                b = max(mq, b - mq)
        return b

    def reduce(self, x, bound: int):
        """Reduce x (values < bound) into [0, q) with conditional subtracts.

        ``bound`` is a static Python int.  Uses ceil(log2(bound/q)) steps,
        each subtracting the largest power-of-two multiple of q that can
        still be present (the step schedule is :meth:`reduce_steps`).
        """
        for m in self.reduce_steps(bound):
            mq = jnp.uint32(m * self.q)
            x = jnp.where(x >= mq, x - mq, x)
        return x

    # ---- arithmetic ------------------------------------------------------
    def add(self, x, y):
        return self.reduce(x + y, 2 * self.q)

    def sub(self, x, y):
        return self.reduce(x + jnp.uint32(self.q) - y, 2 * self.q)

    def neg(self, x):
        return self.reduce(jnp.uint32(self.q) - x, 2 * self.q)

    def _shiftL(self, v):
        """v * 2^L mod q for v in [0, q)."""
        a = v >> self.L          # < 2^(bits - L) <= 2^L
        b = v & jnp.uint32(self.mask)
        # a * R < 2^L * R ; b << L < 2^(2L); sum < 2^32 by __post_init__ check
        t = a * jnp.uint32(self.R) + (b << self.L)
        bound = (1 << self.L) * self.R + (1 << (2 * self.L))
        return self.reduce(t, bound)

    def _limb_high_bound(self, bound: int) -> int:
        """Exclusive bound on the high limb of values < ``bound``."""
        return ((bound - 1) >> self.L) + 1

    def _mul_limb_bounds(self, x_bound: int, y_bound: int) -> tuple:
        """Static (p0, p1, p2) partial-product bounds for `mul` operands
        < ``x_bound`` / < ``y_bound``.  Reduced operands (both <= q) get
        the legacy constants, so default call graphs are unchanged."""
        two_l = 1 << (2 * self.L)
        if x_bound <= self.q and y_bound <= self.q:
            return two_l, 2 * two_l, two_l
        xh = self._limb_high_bound(x_bound)
        yh = self._limb_high_bound(y_bound)
        return two_l, (1 << self.L) * (xh + yh), xh * yh

    def mul_fits(self, x_bound: int | None = None,
                 y_bound: int | None = None) -> bool:
        """True iff :meth:`mul` on operands < ``x_bound`` / < ``y_bound``
        keeps every partial product inside uint32 — the feasibility test
        the reduction-scheduling pass (`core/redplan.py`) consults before
        relaxing an input bound."""
        xb = self.q if x_bound is None else x_bound
        yb = self.q if y_bound is None else y_bound
        if max(xb, yb) > 2**32:
            return False
        _, p1, p2 = self._mul_limb_bounds(xb, yb)
        return p1 < 2**32 and p2 < 2**32

    def mul_reduce_steps(self, x_bound: int | None = None,
                         y_bound: int | None = None,
                         reduce_out: bool = True) -> int:
        """Conditional-subtract steps ONE :meth:`mul` call fires under the
        given bounds — replayed from the same step schedules the datapath
        executes (`repro.analysis.cost` uses this for the eager-vs-lazy
        reduction delta)."""
        xb = self.q if x_bound is None else x_bound
        yb = self.q if y_bound is None else y_bound
        p0b, p1b, p2b = self._mul_limb_bounds(xb, yb)
        shift_b = (1 << self.L) * self.R + (1 << (2 * self.L))
        steps = sum(len(self.reduce_steps(b)) for b in (p0b, p1b, p2b))
        steps += 3 * len(self.reduce_steps(shift_b))   # shiftL(p1), 2x shiftL(p2)
        if reduce_out:
            steps += len(self.reduce_steps(3 * self.q))
        return steps

    def mul(self, x, y, *, x_bound: int | None = None,
            y_bound: int | None = None, reduce_out: bool = True):
        """x*y mod q via 2x2 limb decomposition.

        Default: inputs in [0, q), fully reduced output — the legacy
        datapath, graph-identical to before the reduction-scheduling pass
        existed.  ``x_bound``/``y_bound`` relax the input contract (the
        limb recombination recomputes its partial-product bounds; caller
        must have checked :meth:`mul_fits`); ``reduce_out=False`` defers
        the final reduce, returning a raw value < 3q.
        """
        xb = self.q if x_bound is None else x_bound
        yb = self.q if y_bound is None else y_bound
        if not self.mul_fits(xb, yb):
            raise ValueError(
                f"mul operand bounds ({xb}, {yb}) overflow the uint32 limb "
                "scheme; reduce an input first (see Modulus.mul_fits)"
            )
        p0b, p1b, p2b = self._mul_limb_bounds(xb, yb)
        m = jnp.uint32(self.mask)
        xl, xh = x & m, x >> self.L
        yl, yh = y & m, y >> self.L
        p0 = self.reduce(xl * yl, p0b)
        p1 = self.reduce(xl * yh + xh * yl, p1b)
        p2 = self.reduce(xh * yh, p2b)
        t1 = self._shiftL(p1)                    # p1 * 2^L
        t2 = self._shiftL(self._shiftL(p2))      # p2 * 2^(2L)
        s = p0 + t1 + t2                         # < 3q
        return self.reduce(s, 3 * self.q) if reduce_out else s

    def square(self, x):
        return self.mul(x, x)

    def cube(self, x):
        return self.mul(self.mul(x, x), x)

    def mul_small(self, x, c: int, *, in_bound: int | None = None,
                  reduce_out: bool = True):
        """x * c mod q for a small static constant c (shift-add datapath).

        This is the paper's T4: the MixColumns/MixRows matrix has entries in
        {1, 2, 3}, so products are realized as adds, never multiplies.
        Requires c * in_bound < 2^32 (``in_bound`` defaults to q — reduced
        input).  ``reduce_out=False`` returns the raw add chain (< c·in_bound)
        for a lazy accumulator to fold into ONE terminal reduce.
        """
        b = self.q if in_bound is None else in_bound
        if c * b >= 2**32:
            raise ValueError("constant too large for shift-add path")
        if c == 0:
            return jnp.zeros_like(x)
        if c == 1 and (b <= self.q or not reduce_out):
            return x
        acc = x
        for _ in range(c - 1):
            acc = acc + x
        return self.reduce(acc, c * b) if reduce_out else acc

    def matvec_small(self, mat: np.ndarray, x, axis: int = -1, *,
                     in_bound: int | None = None, lazy: bool = False):
        """y = mat @ x mod q along ``axis`` where mat has small int entries.

        mat: (v, v) numpy int array with entries in {0..3}.  x: uint32 array
        whose ``axis`` dim has size v.  Implemented as shift-add accumulation
        with partial-sum bounds checked statically: accumulator stays < 2^32
        because v * 3 * q is verified at trace time (reduce interleaved when
        it would not be).

        ``lazy=True`` is the reduction-scheduling pass's lazy-accumulate
        policy (`core/redplan.py`): terms stay *raw* (no per-term reduce),
        operands may be unreduced up to ``in_bound`` (default q), and each
        row fires ONE terminal reduce — proven safe per row by
        :meth:`accumulate_sites`.  Output is fully reduced either way.
        """
        v = mat.shape[0]
        in_b = self.q if in_bound is None else in_bound
        if not lazy and in_b > self.q:
            raise ValueError(
                "matvec_small eager path needs reduced operands; pass "
                "lazy=True to accept relaxed input bounds")
        x = jnp.moveaxis(x, axis, -1)
        outs = []
        for i in range(v):
            acc = None
            bound = 0
            for j in range(v):
                c = int(mat[i, j])
                if c == 0:
                    continue
                if lazy:
                    term = self.mul_small(x[..., j], c, in_bound=in_b,
                                          reduce_out=False)
                    tb = c * in_b
                else:
                    term = self.mul_small(x[..., j], c)  # < q
                    tb = self.q
                if acc is None:
                    acc, bound = term, tb
                else:
                    if bound + tb >= 2**32:
                        acc = self.reduce(acc, bound)
                        bound = self.q
                    acc = acc + term
                    bound += tb
            outs.append(self.reduce(acc, bound))
        y = jnp.stack(outs, axis=-1)
        return jnp.moveaxis(y, -1, axis)

    def dense_chunk(self, prod_bound: int | None = None) -> int:
        """How many products < ``prod_bound`` (default q) the dense-matvec
        accumulator can sum in uint32 before it must reduce — the ONE
        policy constant shared by :meth:`matvec_dense`, the Pallas kernel's
        dense path (`kernels/mrmc/mrmc.py:mrmc_dense_apply`), and the
        overflow proof (:meth:`dense_accumulate_sites`).  For the shipped
        PASTA modulus (q = 2^26 - 2^12 + 1) this is 64, so a whole t=64
        branch row sums in one pass; under the lazy plan's deferred
        products (< 3q) it shrinks to 21.
        """
        return (2**32 - 1) // (self.q if prod_bound is None else prod_bound)

    def dense_chunk_schedule(self, t: int,
                             prod_bound: int | None = None) -> tuple:
        """(chunk, n_chunks) for a t-term dense row of products <
        ``prod_bound``: chunk is the LARGEST DIVISOR of t that still sums
        raw in uint32 (:meth:`dense_chunk`), so the accumulator splits by
        a reshape — one fused sum per level — instead of ragged
        sequential slices that defeat XLA fusion.  The n_chunks reduced
        partials (< q each) then fold in one raw sum < n_chunks·q.  For
        the shipped PASTA modulus: eager t=64 → (64, 1) (whole row, one
        pass, graph-identical to the pre-pass datapath); lazy deferred
        products < 3q shrink the cap to 21, so t=64 → (16, 4) and
        t=16 → (16, 1).
        """
        cap = max(1, self.dense_chunk(prod_bound))
        ch = max(d for d in range(1, min(cap, t) + 1) if t % d == 0)
        nch = t // ch
        if nch * self.q >= 2**32:
            raise ValueError(
                f"dense chunk schedule ({ch}, {nch}) for t={t}: "
                f"{nch} reduced partials overflow the uint32 fold")
        return ch, nch

    def matvec_dense(self, mat, x, *, x_bound: int | None = None,
                     lazy: bool = False):
        """y = mat @ x mod q for a *dense* uint32 matrix with entries in
        [0, q) — PASTA's stream-sourced affine layer (no shift-add
        structure to exploit, unlike :meth:`matvec_small`).

        mat: (..., t, t) uint32; x: (..., t) uint32; returns (..., t).
        Every product from :meth:`mul` is < q, so chunks of
        :meth:`dense_chunk_schedule` products are summed in raw uint32
        (a reshape, one fused sum), reduced once per chunk, and the
        reduced partials fold in one final raw sum + reduce.

        ``lazy=True`` (the reduction-scheduling pass's lazy-dense policy)
        defers each product's final reduce — t² fewer 3q-reduces per
        matrix — accumulating raw values < 3q in proportionally narrower
        chunks; ``x_bound`` additionally relaxes the operand contract
        through the limb multiply.  Output is fully reduced either way.
        """
        t = x.shape[-1]
        if lazy:
            prods = self.mul(mat, x[..., None, :], y_bound=x_bound,
                             reduce_out=False)   # (..., t, t), each < 3q
            pb = 3 * self.q
        else:
            if x_bound is not None and x_bound > self.q:
                raise ValueError(
                    "matvec_dense eager path needs reduced operands; pass "
                    "lazy=True to accept relaxed input bounds")
            prods = self.mul(mat, x[..., None, :])   # (..., t, t), each < q
            pb = self.q
        ch, nch = self.dense_chunk_schedule(t, pb)
        s = jnp.sum(prods.reshape(prods.shape[:-1] + (nch, ch)),
                    axis=-1, dtype=U32)              # (..., t, nch)
        s = self.reduce(s, ch * pb)                  # each < q
        if nch == 1:
            return s[..., 0]
        return self.reduce(jnp.sum(s, axis=-1, dtype=U32), nch * self.q)

    # ---- static bound enumeration (repro.analysis substrate) -----------
    def dense_accumulate_sites(self, t: int, site: str = "dense-matvec",
                               prod_bound: int | None = None) -> tuple:
        """Proof obligations for one dense t-term matvec row — replays the
        EXACT chunked accumulation of :meth:`matvec_dense` /
        ``mrmc_dense_apply``: ``n_chunks`` identical uint32 sums of
        ``chunk`` products < ``prod_bound`` (q eager; 3q under the lazy
        plan's deferred products), one reduce per chunk, then one raw
        fold of the reduced partials (:meth:`dense_chunk_schedule`).
        """
        pb = self.q if prod_bound is None else prod_bound
        ch, nch = self.dense_chunk_schedule(t, pb)
        b = ch * pb
        sites = [
            BoundSite(site=f"{site}:chunk sum of {ch} products (x{nch})",
                      bound=b, limit=2**32),
            BoundSite(site=f"{site}:chunk residual",
                      bound=self.reduce_residual_bound(b),
                      limit=self.q),
        ]
        if nch > 1:
            fb = nch * self.q
            sites.append(BoundSite(
                site=f"{site}:partial-sum fold of {nch} chunks",
                bound=fb, limit=2**32))
            sites.append(BoundSite(
                site=f"{site}:fold residual",
                bound=self.reduce_residual_bound(fb),
                limit=self.q))
        return tuple(sites)

    def mul_bound_sites(self, x_bound: int | None = None,
                        y_bound: int | None = None,
                        reduce_out: bool = True) -> tuple:
        """Every static intermediate bound `mul` (and thus square/cube)
        reaches, as :class:`BoundSite` records — the uint32-overflow proof
        obligations of the limb scheme, enumerated from the same constants
        the datapath uses.  Relaxed ``x_bound``/``y_bound`` and
        ``reduce_out=False`` replay the partial-product bounds a
        plan-relaxed :meth:`mul` actually runs with.

        For each reduce call two obligations are emitted: the operand
        bound must fit uint32, and the conditional-subtract chain must
        fully reduce it (worst-case residual <= q,
        :meth:`reduce_residual_bound`).  A deferred output emits a
        fit-only obligation (no reduce fires there — downstream owns it).
        """
        xb = self.q if x_bound is None else x_bound
        yb = self.q if y_bound is None else y_bound
        p0b, p1b, p2b = self._mul_limb_bounds(xb, yb)
        two_l = 1 << (2 * self.L)
        shift_t = (1 << self.L) * self.R + two_l
        entries = [
            ("mul:p0 = xl*yl", p0b),
            ("mul:p1 = xl*yh + xh*yl", p1b),
            ("mul:p2 = xh*yh", p2b),
            ("mul:shiftL t = a*R + (b<<L)", shift_t),
        ]
        if reduce_out:
            entries.append(("mul:p0 + p1*2^L + p2*2^2L", 3 * self.q))
        entries += [
            ("add:x + y", 2 * self.q),
            ("sub:x + q - y", 2 * self.q),
        ]
        sites = []
        for name, bound in entries:
            sites.append(BoundSite(site=name, bound=bound, limit=2**32))
            sites.append(BoundSite(site=name + " (residual)",
                                   bound=self.reduce_residual_bound(bound),
                                   limit=self.q))
        if not reduce_out:
            sites.append(BoundSite(
                site="mul:p0 + p1*2^L + p2*2^2L (deferred, unreduced out)",
                bound=3 * self.q, limit=2**32))
        return tuple(sites)

    def accumulate_sites(self, coeffs, site: str = "matvec",
                         in_bound: int | None = None,
                         lazy: bool = False) -> tuple:
        """Worst-case accumulator bound walk for one shift-add row sum.

        ``coeffs`` is one row of a small-constant mix matrix.  Mirrors the
        EXACT interleaved-reduce policy shared by :meth:`matvec_small` and
        the mrmc kernels' ``_combine``: each term is ``mul_small``-scaled
        (an add chain bounded by c*q, then reduced), and the running sum
        reduces to < q whenever the next add could reach 2^32.  With
        ``lazy=True`` (and operands < ``in_bound``, default q) the terms
        stay raw at c·in_bound each, matching the lazy-accumulate policy.
        Returns one :class:`BoundSite` per scaled term, one for the
        accumulator peak, and one for the final residual.
        """
        in_b = self.q if in_bound is None else in_bound
        sites = []
        bound = 0
        peak = 0
        for j, c in enumerate(coeffs):
            c = int(c)
            if c == 0:
                continue
            tb = c * in_b if lazy else self.q
            if lazy:
                if c > 1 or in_b > self.q:
                    sites.append(BoundSite(site=f"{site}:term[{j}] {c}*x "
                                                f"raw chain", bound=tb,
                                           limit=2**32))
            elif c > 1:
                sites.append(BoundSite(site=f"{site}:term[{j}] {c}*x add "
                                            f"chain", bound=c * self.q,
                                       limit=2**32))
            if bound == 0:
                bound = tb
            else:
                if bound + tb >= 2**32:
                    bound = self.q    # interleaved reduce fires
                bound += tb
            peak = max(peak, bound)
        sites.append(BoundSite(site=f"{site}:accumulator peak",
                               bound=peak, limit=2**32))
        sites.append(BoundSite(site=f"{site}:row residual",
                               bound=self.reduce_residual_bound(peak),
                               limit=self.q))
        return tuple(sites)

    def from_signed(self, e):
        """Map signed int32 values (|e| < q) into [0, q)."""
        q = jnp.int32(self.q)
        return jnp.where(e < 0, e + q, e).astype(U32)

    def to_signed(self, x):
        """Centered representative in (-q/2, q/2]."""
        half = jnp.uint32(self.q // 2)
        xi = x.astype(jnp.int32)
        return jnp.where(x > half, xi - jnp.int32(self.q), xi)


# Shipped Solinas primes (verified prime in __post_init__).
Q_HERA = Modulus(2**28 - 2**16 + 1)    # 268369921, 28-bit (HERA Par-128a scale)
Q_RUBATO = Modulus(2**25 - 2**14 + 1)  # 33538049, 25-bit (Rubato Par-128L scale)
Q_PASTA = Modulus(2**26 - 2**12 + 1)   # 67104769, 26-bit (PASTA plaintext scale)
