"""uint32-native modular arithmetic over Z_q for q < 2^28.

TPU has no native 64-bit integer multiply, so we never form a product wider
than 32 bits.  The scheme (docs/DESIGN.md §2):

  * operands are split into L-bit limbs with L = ceil(qbits / 2) <= 14, so
    every partial product is < 2^(2L) <= 2^28 < 2^31;
  * q is required to be in "Solinas-friendly" position: R = 2^(2L) mod q must
    satisfy R * 2^L + 2^(2L) < 2^32 so that the shift-reduce step also stays
    inside uint32.  The shipped primes (2^28 - 2^16 + 1 and 2^25 - 2^14 + 1)
    satisfy this with huge margin.

Reduction never uses integer division: every intermediate has a small static
bound k*q, and we reduce with a branchless conditional-subtract chain of
ceil(log2(k)) + 1 steps.  This is the TPU analogue of the paper's shift-add /
no-DSP datapath: adds, compares and selects only.

All public ops are jax-traceable and operate elementwise on uint32 arrays
whose values are in [0, q).
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class BoundSite:
    """One static proof obligation: a worst-case value ``bound`` at a named
    datapath site that must stay within ``limit`` (2^32 for uint32 fit;
    q for post-reduce residuals).  Enumerated by
    :meth:`Modulus.mul_bound_sites` / :meth:`Modulus.accumulate_sites` and
    consumed by `repro.analysis.bounds`."""

    site: str
    bound: int
    limit: int

    @property
    def ok(self) -> bool:
        return self.bound <= self.limit

    @property
    def margin_bits(self) -> float:
        """Headroom in bits (negative = violated)."""
        if self.bound <= 0:
            return float("inf")
        return math.log2(self.limit) - math.log2(self.bound)


def _is_prime(n: int) -> bool:
    if n < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if n % p == 0:
            return n == p
    # deterministic Miller-Rabin for n < 3.3e24 with these bases
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


@dataclasses.dataclass(frozen=True)
class Modulus:
    """Static description of a prime modulus q < 2^28 plus limb constants."""

    q: int

    def __post_init__(self):
        if not (2 < self.q < 2**28):
            raise ValueError(f"q={self.q} out of supported range (2, 2^28)")
        if not _is_prime(self.q):
            raise ValueError(f"q={self.q} must be prime")
        # Safety envelope for the limb scheme (checked, not assumed).
        if self.R * (1 << self.L) + (1 << (2 * self.L)) >= 2**32:
            raise ValueError(
                f"q={self.q}: R=2^(2L) mod q = {self.R} too large for the "
                "uint32 limb scheme; pick a Solinas-form prime"
            )

    # ---- static (Python int) derived constants -------------------------
    @property
    def bits(self) -> int:
        return self.q.bit_length()

    @property
    def L(self) -> int:
        """Limb width in bits."""
        return (self.bits + 1) // 2

    @property
    def mask(self) -> int:
        return (1 << self.L) - 1

    @property
    def R(self) -> int:
        """2^(2L) mod q — the shift-reduce constant."""
        return (1 << (2 * self.L)) % self.q

    # ---- reduction helpers ---------------------------------------------
    def reduce_steps(self, bound: int) -> tuple:
        """The static multiples m of q the conditional-subtract chain in
        :meth:`reduce` fires for operands < ``bound``, largest first.

        This IS the chain `reduce` executes (it consults this helper), so
        the static-analysis proof over these steps
        (`repro.analysis.bounds`) describes the shipped datapath, not a
        model of it.
        """
        q = self.q
        k = (bound + q - 1) // q  # x < k*q
        m = 1
        while m * 2 < k:
            m *= 2
        steps = []
        # subtract m*q, m/2*q, ..., q
        while m >= 1:
            steps.append(m)
            m //= 2
        return tuple(steps)

    def reduce_residual_bound(self, bound: int) -> int:
        """Exact worst-case value bound after :meth:`reduce` on operands
        < ``bound`` — an interval walk of the conditional-subtract chain.

        Full reduction means the result is <= q, i.e. values land in
        [0, q); `repro.analysis.bounds` asserts that (and that ``bound``
        itself fits uint32) for every static reduce site in the cipher
        datapath.
        """
        b = bound
        for m in self.reduce_steps(bound):
            mq = m * self.q
            if b > mq:
                # values >= mq drop to < b - mq; values < mq are untouched
                b = max(mq, b - mq)
        return b

    def reduce(self, x, bound: int):
        """Reduce x (values < bound) into [0, q) with conditional subtracts.

        ``bound`` is a static Python int.  Uses ceil(log2(bound/q)) steps,
        each subtracting the largest power-of-two multiple of q that can
        still be present (the step schedule is :meth:`reduce_steps`).
        """
        for m in self.reduce_steps(bound):
            mq = jnp.uint32(m * self.q)
            x = jnp.where(x >= mq, x - mq, x)
        return x

    # ---- arithmetic ------------------------------------------------------
    def add(self, x, y):
        return self.reduce(x + y, 2 * self.q)

    def sub(self, x, y):
        return self.reduce(x + jnp.uint32(self.q) - y, 2 * self.q)

    def neg(self, x):
        return self.reduce(jnp.uint32(self.q) - x, 2 * self.q)

    def _shiftL(self, v):
        """v * 2^L mod q for v in [0, q)."""
        a = v >> self.L          # < 2^(bits - L) <= 2^L
        b = v & jnp.uint32(self.mask)
        # a * R < 2^L * R ; b << L < 2^(2L); sum < 2^32 by __post_init__ check
        t = a * jnp.uint32(self.R) + (b << self.L)
        bound = (1 << self.L) * self.R + (1 << (2 * self.L))
        return self.reduce(t, bound)

    def mul(self, x, y):
        """x*y mod q via 2x2 limb decomposition; inputs in [0, q)."""
        m = jnp.uint32(self.mask)
        xl, xh = x & m, x >> self.L
        yl, yh = y & m, y >> self.L
        two_l = 1 << (2 * self.L)
        p0 = self.reduce(xl * yl, two_l)
        p1 = self.reduce(xl * yh + xh * yl, 2 * two_l)
        p2 = self.reduce(xh * yh, two_l)
        t1 = self._shiftL(p1)                    # p1 * 2^L
        t2 = self._shiftL(self._shiftL(p2))      # p2 * 2^(2L)
        return self.reduce(p0 + t1 + t2, 3 * self.q)

    def square(self, x):
        return self.mul(x, x)

    def cube(self, x):
        return self.mul(self.mul(x, x), x)

    def mul_small(self, x, c: int):
        """x * c mod q for a small static constant c (shift-add datapath).

        This is the paper's T4: the MixColumns/MixRows matrix has entries in
        {1, 2, 3}, so products are realized as adds, never multiplies.
        Requires c * q < 2^32.
        """
        if c * self.q >= 2**32:
            raise ValueError("constant too large for shift-add path")
        if c == 0:
            return jnp.zeros_like(x)
        if c == 1:
            return x
        acc = x
        for _ in range(c - 1):
            acc = acc + x
        return self.reduce(acc, c * self.q)

    def matvec_small(self, mat: np.ndarray, x, axis: int = -1):
        """y = mat @ x mod q along ``axis`` where mat has small int entries.

        mat: (v, v) numpy int array with entries in {0..3}.  x: uint32 array
        whose ``axis`` dim has size v.  Implemented as shift-add accumulation
        with partial-sum bounds checked statically: accumulator stays < 2^32
        because v * 3 * q is verified at trace time (reduce interleaved when
        it would not be).
        """
        v = mat.shape[0]
        x = jnp.moveaxis(x, axis, -1)
        outs = []
        for i in range(v):
            acc = None
            bound = 0
            for j in range(v):
                c = int(mat[i, j])
                if c == 0:
                    continue
                term = self.mul_small(x[..., j], c)  # < q
                if acc is None:
                    acc, bound = term, self.q
                else:
                    if bound + self.q >= 2**32:
                        acc = self.reduce(acc, bound)
                        bound = self.q
                    acc = acc + term
                    bound += self.q
            outs.append(self.reduce(acc, bound))
        y = jnp.stack(outs, axis=-1)
        return jnp.moveaxis(y, -1, axis)

    def dense_chunk(self) -> int:
        """How many products < q the dense-matvec accumulator can sum in
        uint32 before it must reduce — the ONE policy constant shared by
        :meth:`matvec_dense`, the Pallas kernel's dense path
        (`kernels/mrmc/mrmc.py:mrmc_dense_apply`), and the overflow proof
        (:meth:`dense_accumulate_sites`).  For the shipped PASTA modulus
        (q = 2^26 - 2^12 + 1) this is 64, so a whole t=64 branch row sums
        in one pass.
        """
        return (2**32 - 1) // self.q

    def matvec_dense(self, mat, x):
        """y = mat @ x mod q for a *dense* uint32 matrix with entries in
        [0, q) — PASTA's stream-sourced affine layer (no shift-add
        structure to exploit, unlike :meth:`matvec_small`).

        mat: (..., t, t) uint32; x: (..., t) uint32; returns (..., t).
        Every product from :meth:`mul` is < q, so chunks of up to
        :meth:`dense_chunk` products are summed in raw uint32 and reduced
        once per chunk; cross-chunk accumulation stays < 2q.
        """
        t = x.shape[-1]
        prods = self.mul(mat, x[..., None, :])       # (..., t, t), each < q
        chunk = self.dense_chunk()
        acc = None
        for a in range(0, t, chunk):
            b = min(t, a + chunk)
            s = jnp.sum(prods[..., a:b], axis=-1, dtype=U32)
            s = self.reduce(s, (b - a) * self.q)
            acc = s if acc is None else self.reduce(acc + s, 2 * self.q)
        return acc

    # ---- static bound enumeration (repro.analysis substrate) -----------
    def dense_accumulate_sites(self, t: int,
                               site: str = "dense-matvec") -> tuple:
        """Proof obligations for one dense t-term matvec row — replays the
        EXACT chunked accumulation of :meth:`matvec_dense` /
        ``mrmc_dense_apply``: per-chunk uint32 sums of < q products, one
        reduce per chunk, cross-chunk adds bounded by 2q.
        """
        chunk = self.dense_chunk()
        sites = []
        done = 0
        while done < t:
            c = min(chunk, t - done)
            b = c * self.q
            sites.append(BoundSite(site=f"{site}:chunk sum of {c} products",
                                   bound=b, limit=2**32))
            sites.append(BoundSite(site=f"{site}:chunk residual",
                                   bound=self.reduce_residual_bound(b),
                                   limit=self.q))
            if done:
                sites.append(BoundSite(site=f"{site}:cross-chunk add",
                                       bound=2 * self.q, limit=2**32))
                sites.append(BoundSite(
                    site=f"{site}:cross-chunk residual",
                    bound=self.reduce_residual_bound(2 * self.q),
                    limit=self.q))
            done += c
        return tuple(sites)

    def mul_bound_sites(self) -> tuple:
        """Every static intermediate bound `mul` (and thus square/cube)
        reaches, as :class:`BoundSite` records — the uint32-overflow proof
        obligations of the limb scheme, enumerated from the same constants
        the datapath uses.

        For each reduce call two obligations are emitted: the operand
        bound must fit uint32, and the conditional-subtract chain must
        fully reduce it (worst-case residual <= q,
        :meth:`reduce_residual_bound`).
        """
        two_l = 1 << (2 * self.L)
        shift_t = (1 << self.L) * self.R + two_l
        sites = []
        for name, bound in (
            ("mul:p0 = xl*yl", two_l),
            ("mul:p1 = xl*yh + xh*yl", 2 * two_l),
            ("mul:p2 = xh*yh", two_l),
            ("mul:shiftL t = a*R + (b<<L)", shift_t),
            ("mul:p0 + p1*2^L + p2*2^2L", 3 * self.q),
            ("add:x + y", 2 * self.q),
            ("sub:x + q - y", 2 * self.q),
        ):
            sites.append(BoundSite(site=name, bound=bound, limit=2**32))
            sites.append(BoundSite(site=name + " (residual)",
                                   bound=self.reduce_residual_bound(bound),
                                   limit=self.q))
        return tuple(sites)

    def accumulate_sites(self, coeffs, site: str = "matvec") -> tuple:
        """Worst-case accumulator bound walk for one shift-add row sum.

        ``coeffs`` is one row of a small-constant mix matrix.  Mirrors the
        EXACT interleaved-reduce policy shared by :meth:`matvec_small` and
        the mrmc kernels' ``_combine``: each term is ``mul_small``-scaled
        (an add chain bounded by c*q, then reduced), and the running sum
        reduces to < q whenever the next add could reach 2^32.  Returns
        one :class:`BoundSite` per scaled term, one for the accumulator
        peak, and one for the final residual.
        """
        sites = []
        bound = 0
        peak = 0
        for j, c in enumerate(coeffs):
            c = int(c)
            if c == 0:
                continue
            if c > 1:
                sites.append(BoundSite(site=f"{site}:term[{j}] {c}*x add "
                                            f"chain", bound=c * self.q,
                                       limit=2**32))
            if bound == 0:
                bound = self.q
            else:
                if bound + self.q >= 2**32:
                    bound = self.q    # interleaved reduce fires
                bound += self.q
            peak = max(peak, bound)
        sites.append(BoundSite(site=f"{site}:accumulator peak",
                               bound=peak, limit=2**32))
        sites.append(BoundSite(site=f"{site}:row residual",
                               bound=self.reduce_residual_bound(peak),
                               limit=self.q))
        return tuple(sites)

    def from_signed(self, e):
        """Map signed int32 values (|e| < q) into [0, q)."""
        q = jnp.int32(self.q)
        return jnp.where(e < 0, e + q, e).astype(U32)

    def to_signed(self, x):
        """Centered representative in (-q/2, q/2]."""
        half = jnp.uint32(self.q // 2)
        xi = x.astype(jnp.int32)
        return jnp.where(x > half, xi - jnp.int32(self.q), xi)


# Shipped Solinas primes (verified prime in __post_init__).
Q_HERA = Modulus(2**28 - 2**16 + 1)    # 268369921, 28-bit (HERA Par-128a scale)
Q_RUBATO = Modulus(2**25 - 2**14 + 1)  # 33538049, 25-bit (Rubato Par-128L scale)
Q_PASTA = Modulus(2**26 - 2**12 + 1)   # 67104769, 26-bit (PASTA plaintext scale)
