"""AES-128 in pure JAX (uint8), plus a CTR-mode keystream.

The paper uses an AES core as the XOF for round-constant sampling (chosen
over SHAKE256 for throughput/area — §IV-D).  We mirror that choice: AES-128
here is the conformance XOF.  The S-box and all GF(2^8) tables are *derived*
(not typed in) and the implementation is validated against FIPS-197 vectors
in tests.

Layout convention: a block is 16 bytes in column-major AES "state" order,
i.e. byte i of the flat block is state[row=i%4, col=i//4] (the FIPS order).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# GF(2^8) tables, derived at import time (numpy, host-side).
# --------------------------------------------------------------------------
def _gf_mul(a: int, b: int) -> int:
    p = 0
    for _ in range(8):
        if b & 1:
            p ^= a
        hi = a & 0x80
        a = (a << 1) & 0xFF
        if hi:
            a ^= 0x1B
        b >>= 1
    return p


def _build_sbox() -> np.ndarray:
    # multiplicative inverse via brute force, then the affine map
    inv = np.zeros(256, dtype=np.uint8)
    for x in range(1, 256):
        for y in range(1, 256):
            if _gf_mul(x, y) == 1:
                inv[x] = y
                break
    sbox = np.zeros(256, dtype=np.uint8)
    for x in range(256):
        b = int(inv[x])
        s = 0x63
        for i in range(8):
            bit = (
                (b >> i)
                ^ (b >> ((i + 4) % 8))
                ^ (b >> ((i + 5) % 8))
                ^ (b >> ((i + 6) % 8))
                ^ (b >> ((i + 7) % 8))
            ) & 1
            s ^= bit << i
        sbox[x] = s  # the 0x63 constant is folded in via the seed value of s
    return sbox


_SBOX_NP = _build_sbox()
assert _SBOX_NP[0x00] == 0x63 and _SBOX_NP[0x01] == 0x7C and _SBOX_NP[0x53] == 0xED, (
    "derived AES S-box failed spot check"
)

_RCON = np.array([0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1B, 0x36],
                 dtype=np.uint8)

# ShiftRows permutation on the flat 16-byte block (FIPS column-major order):
# state[r, c] <- state[r, (c + r) % 4];  flat index = r + 4*c.
_SHIFTROWS_PERM = np.array(
    [(r + 4 * ((c + r) % 4)) for c in range(4) for r in range(4)],
    dtype=np.int32,
)

SBOX = jnp.asarray(_SBOX_NP)
SHIFTROWS_PERM = jnp.asarray(_SHIFTROWS_PERM)


# --------------------------------------------------------------------------
# Key schedule (host-side numpy; round keys are static per cipher instance).
# --------------------------------------------------------------------------
def aes128_key_expand(key_bytes: np.ndarray) -> np.ndarray:
    """Expand a 16-byte key into 11 round keys, shape (11, 16) uint8."""
    key_bytes = np.asarray(key_bytes, dtype=np.uint8).reshape(16)
    words = [key_bytes[4 * i : 4 * i + 4].copy() for i in range(4)]
    for i in range(4, 44):
        t = words[i - 1].copy()
        if i % 4 == 0:
            t = np.roll(t, -1)
            t = _SBOX_NP[t]
            t[0] ^= _RCON[i // 4 - 1]
        words.append(words[i - 4] ^ t)
    rk = np.stack(words).reshape(11, 16)
    return rk


# --------------------------------------------------------------------------
# Block encryption (JAX, batched).
# --------------------------------------------------------------------------
def _xtime(x):
    return ((x << 1) & jnp.uint8(0xFF)) ^ jnp.where(
        (x & jnp.uint8(0x80)) != 0, jnp.uint8(0x1B), jnp.uint8(0)
    )


def _mix_columns(s):
    """MixColumns on (..., 16) flat state (column-major byte order)."""
    s = s.reshape(s.shape[:-1] + (4, 4))  # (..., col, row)
    a0, a1, a2, a3 = s[..., 0], s[..., 1], s[..., 2], s[..., 3]
    x0, x1, x2, x3 = _xtime(a0), _xtime(a1), _xtime(a2), _xtime(a3)
    b0 = x0 ^ (x1 ^ a1) ^ a2 ^ a3
    b1 = a0 ^ x1 ^ (x2 ^ a2) ^ a3
    b2 = a0 ^ a1 ^ x2 ^ (x3 ^ a3)
    b3 = (x0 ^ a0) ^ a1 ^ a2 ^ x3
    out = jnp.stack([b0, b1, b2, b3], axis=-1)
    return out.reshape(out.shape[:-2] + (16,))


@functools.partial(jax.jit, static_argnames=())
def aes128_encrypt_blocks(blocks, round_keys):
    """Encrypt (..., 16) uint8 blocks with (11, 16) uint8 round keys."""
    s = blocks ^ round_keys[0]
    for rnd in range(1, 10):
        s = jnp.take(SBOX, s.astype(jnp.int32), axis=0)
        s = s[..., SHIFTROWS_PERM]
        s = _mix_columns(s)
        s = s ^ round_keys[rnd]
    s = jnp.take(SBOX, s.astype(jnp.int32), axis=0)
    s = s[..., SHIFTROWS_PERM]
    return s ^ round_keys[10]


def aes_ctr_keystream(round_keys, nonce96: np.ndarray, counter0: int, nblocks):
    """AES-CTR keystream: (nblocks, 16) uint8.

    Counter block = nonce (12 bytes) || big-endian 32-bit counter, starting
    at ``counter0``.  ``nblocks`` may be a traced value only if static shape
    is supplied by the caller; here it must be a Python int.
    """
    nonce96 = jnp.asarray(np.asarray(nonce96, dtype=np.uint8).reshape(12))
    ctr = jnp.arange(counter0, counter0 + nblocks, dtype=jnp.uint32)
    b0 = (ctr >> 24).astype(jnp.uint8)
    b1 = (ctr >> 16).astype(jnp.uint8)
    b2 = (ctr >> 8).astype(jnp.uint8)
    b3 = ctr.astype(jnp.uint8)
    ctr_bytes = jnp.stack([b0, b1, b2, b3], axis=-1)          # (n, 4)
    blocks = jnp.concatenate(
        [jnp.broadcast_to(nonce96, (nblocks, 12)), ctr_bytes], axis=-1
    )
    return aes128_encrypt_blocks(blocks, jnp.asarray(round_keys))
