"""Samplers driven by XOF words: uniform-mod-q (rejection) and discrete
Gaussian (inverse-CDF with a lambda/2-bit fixed-point table, per the paper's
§IV-D and [Micciancio-Walter'17]).

JAX needs static shapes, so rejection sampling uses a fixed overdraw of
``OVERDRAW`` candidates per constant and selects the first accepted one.
For the shipped Solinas primes the per-candidate rejection probability is
(2^bits - q) / 2^bits < 2.5e-4, so P(all 4 rejected) < 4e-15 per constant —
negligible, and if it ever happens we fall back to the (infinitesimally
biased) last candidate mod q.  docs/DESIGN.md §8 records this deviation from the
spec's unbounded loop.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.crypto.modmath import Modulus

OVERDRAW = 4


def uniform_mod_q(words, mod: Modulus):
    """Map XOF words to uniform elements of Z_q by masked rejection.

    words: uint32 array (..., n, OVERDRAW) — OVERDRAW candidates per output.
    Returns (..., n) uint32 in [0, q).
    """
    if words.shape[-1] != OVERDRAW:
        raise ValueError(f"expected trailing overdraw dim {OVERDRAW}")
    mask = jnp.uint32((1 << mod.bits) - 1)
    cand = words & mask
    ok = cand < jnp.uint32(mod.q)
    # index of first accepted candidate (argmax of boolean picks first True)
    first = jnp.argmax(ok, axis=-1)
    any_ok = jnp.any(ok, axis=-1)
    picked = jnp.take_along_axis(cand, first[..., None], axis=-1)[..., 0]
    fallback = cand[..., -1] % jnp.uint32(mod.q)
    return jnp.where(any_ok, picked, fallback)


def words_needed_uniform(n: int) -> int:
    return n * OVERDRAW


# Safety pad for the stream sampler: P(more than STREAM_PAD rejections out of
# a few hundred draws at p < 2.5e-4) is < 1e-40.
STREAM_PAD = 16


def uniform_mod_q_stream(words, n_out: int, mod: Modulus):
    """XOF-economical rejection sampling: consume a flat word stream.

    This matches the real cipher's accounting (~1 XOF word per constant, the
    paper's "37 AES invocations" for Rubato Par-128L) instead of the 4x
    overdraw of :func:`uniform_mod_q`.  words: (..., n_out + STREAM_PAD)
    uint32.  Accepted words are compacted (stable order) and the first
    ``n_out`` are returned; with < 1e-40 probability fewer than n_out are
    accepted, in which case rejected slots fall back to word % q.
    """
    if words.shape[-1] < n_out + STREAM_PAD:
        raise ValueError("need n_out + STREAM_PAD words")
    mask = jnp.uint32((1 << mod.bits) - 1)
    cand = words & mask
    ok = cand < jnp.uint32(mod.q)
    order = jnp.argsort(jnp.logical_not(ok), axis=-1, stable=True)
    sorted_cand = jnp.take_along_axis(cand, order, axis=-1)[..., :n_out]
    sorted_ok = jnp.take_along_axis(ok, order, axis=-1)[..., :n_out]
    fallback = sorted_cand % jnp.uint32(mod.q)
    return jnp.where(sorted_ok, sorted_cand, fallback)


def words_needed_uniform_stream(n: int) -> int:
    return n + STREAM_PAD


@dataclasses.dataclass(frozen=True)
class DGaussTable:
    """Inverse-CDF table for a centered discrete Gaussian, sigma given.

    Thresholds are 64-bit fixed point stored as (hi, lo) uint32 pairs so the
    comparison runs in uint32 lanes (lambda/2 = 64-bit precision for
    lambda = 128, matching the paper).  Support is [-tail, +tail] with
    tail = ceil(10 sigma) (mass beyond is < 2^-70 for sigma <= 4).
    """

    sigma: float
    tail: int
    hi: np.ndarray  # (2*tail,) uint32 — cumulative thresholds, ascending
    lo: np.ndarray

    @staticmethod
    def build(sigma: float) -> "DGaussTable":
        tail = int(math.ceil(10 * sigma))
        xs = np.arange(-tail, tail + 1)
        # unnormalized discrete Gaussian mass
        w = np.exp(-(xs.astype(np.float64) ** 2) / (2 * sigma**2))
        p = w / w.sum()
        cdf = np.cumsum(p)[:-1]  # 2*tail interior thresholds
        fixed = np.floor(cdf * float(2**64)).astype(np.float64)
        fixed = np.minimum(fixed, float(2**64 - 1))
        hi = (fixed / 2**32).astype(np.uint64).astype(np.uint32)
        lo = (fixed % 2**32).astype(np.uint64).astype(np.uint32)
        return DGaussTable(sigma=sigma, tail=tail, hi=hi, lo=lo)


def discrete_gaussian(words_hi, words_lo, table: DGaussTable):
    """Sample signed ints from the discrete Gaussian via inverse CDF.

    words_hi/lo: uint32 arrays of identical shape (the 64-bit uniform draw).
    Returns int32 samples in [-tail, tail].
    """
    hi_t = jnp.asarray(table.hi)  # (T,)
    lo_t = jnp.asarray(table.lo)
    u_hi = words_hi[..., None]
    u_lo = words_lo[..., None]
    # u >= threshold  (64-bit lexicographic compare in uint32 lanes)
    ge = (u_hi > hi_t) | ((u_hi == hi_t) & (u_lo >= lo_t))
    idx = jnp.sum(ge.astype(jnp.int32), axis=-1)  # in [0, 2*tail]
    return idx - jnp.int32(table.tail)


def words_needed_gauss(n: int) -> int:
    return 2 * n
