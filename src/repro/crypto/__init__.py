"""Cryptographic substrate for the Presto HHE cipher framework.

Everything here is uint32-native (no 64-bit integers) so that it lowers
cleanly to TPU VPU lanes — see docs/DESIGN.md §2 "Modular arithmetic without
64-bit".
"""

from repro.crypto.modmath import Modulus, Q_HERA, Q_RUBATO
from repro.crypto.aes import (
    aes128_encrypt_blocks,
    aes128_key_expand,
    aes_ctr_keystream,
)
from repro.crypto.xof import make_xof, xof_words
from repro.crypto.sampler import uniform_mod_q, discrete_gaussian, DGaussTable

__all__ = [
    "Modulus",
    "Q_HERA",
    "Q_RUBATO",
    "aes128_encrypt_blocks",
    "aes128_key_expand",
    "aes_ctr_keystream",
    "make_xof",
    "xof_words",
    "uniform_mod_q",
    "discrete_gaussian",
    "DGaussTable",
]
