"""Extendable-output functions (XOF) for round-constant / noise sampling.

Two backends:

  * ``aes`` — the paper's choice (§IV-D): AES-128 in CTR mode keyed by the
    public nonce.  Conformance default.  128 bits / block, exactly the
    producer the paper's "RNG decoupling" feeds through the FIFO.
  * ``threefry`` — beyond-paper TPU-native fast path: JAX's counter-based
    threefry2x32 PRF (add/xor/rotate only; no byte tables, no gathers).
    Same interface, different stream.  See EXPERIMENTS.md §Perf.

Convention (documented in docs/DESIGN.md §8): the XOF for block counter ``ctr``
under public nonce ``nc`` (128-bit) is
    AES-CTR(key = nc, counter_block = nc[0:12] || (ctr << 16 | i))
i.e. each cipher block counter owns a 2^16-block counter subspace, giving
up to 2^20 bytes of XOF output per keystream block — vastly more than the
~4.7 kb the ciphers draw (37 AES blocks for Rubato Par-128L).

Two calling conventions per backend:

  * single-stream (``aes_xof_words`` / ``threefry_xof_words``): one nonce,
    a vector of block counters;
  * multi-stream (``*_xof_words_batched``): per-lane *precompiled* nonce
    material (expanded AES round keys / threefry root keys), so one jit'd
    producer call serves lanes drawn from many concurrent sessions.  Both
    conventions produce bit-identical words for the same (nonce, ctr).

These are the word-stream *primitives*.  Cipher-facing constant
materialization goes through the :mod:`repro.core.producer` registry
(`ConstantsProducer` backends wrapping these functions plus the samplers)
— select producers there, not here; ``make_xof``/``xof_words`` remain only
as primitive accessors for direct XOF tests.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import aes as aes_mod

_CTR_SPACE = 1 << 16  # AES blocks reserved per (nonce, cipher-block) pair


def _words_from_blocks(blocks_u8):
    """(n, 16) uint8 -> (n*4,) uint32, little-endian within each word."""
    b = blocks_u8.reshape(-1, 4, 4).astype(jnp.uint32)
    w = b[..., 0] | (b[..., 1] << 8) | (b[..., 2] << 16) | (b[..., 3] << 24)
    return w.reshape(-1)


def aes_xof_words(nonce: np.ndarray, block_ctrs, n_words: int):
    """uint32 XOF words for a batch of cipher-block counters.

    nonce: 16-byte numpy array (public).  block_ctrs: (lanes,) uint32 array.
    Returns (lanes, n_words) uint32.
    """
    nonce = np.asarray(nonce, dtype=np.uint8).reshape(16)
    rk = jnp.asarray(aes_mod.aes128_key_expand(nonce))
    n_blocks = (n_words + 3) // 4

    nonce12 = jnp.asarray(nonce[:12])

    def per_lane(ctr):
        blocks = _aes_ctr_blocks(nonce12, ctr, n_blocks)
        ks = aes_mod.aes128_encrypt_blocks(blocks, rk)
        return _words_from_blocks(ks)[:n_words]

    return jax.vmap(per_lane)(jnp.asarray(block_ctrs, dtype=jnp.uint32))


def _aes_ctr_blocks(nonce12, ctr, n_blocks):
    """Counter blocks nonce12 || be32(ctr·2^16 + i) for one cipher lane."""
    base = ctr * jnp.uint32(_CTR_SPACE)
    idx = base + jnp.arange(n_blocks, dtype=jnp.uint32)
    b0 = (idx >> 24).astype(jnp.uint8)
    b1 = (idx >> 16).astype(jnp.uint8)
    b2 = (idx >> 8).astype(jnp.uint8)
    b3 = idx.astype(jnp.uint8)
    ctr_bytes = jnp.stack([b0, b1, b2, b3], axis=-1)
    prefix = jnp.broadcast_to(nonce12, (n_blocks, 12))
    return jnp.concatenate([prefix, ctr_bytes], axis=-1)


def aes_xof_words_batched(round_keys, nonce12, block_ctrs, n_words: int):
    """Multi-stream AES XOF: per-lane expanded keys and nonce prefixes.

    round_keys: (lanes, 11, 16) uint8 — ``aes128_key_expand(nonce)`` per lane
    (gathered from a session table; expansion is host-side, once per session).
    nonce12: (lanes, 12) uint8.  block_ctrs: (lanes,) uint32.
    Returns (lanes, n_words) uint32, bit-identical to :func:`aes_xof_words`
    called with each lane's own nonce.
    """
    n_blocks = (n_words + 3) // 4

    def per_lane(rk, n12, ctr):
        blocks = _aes_ctr_blocks(n12, ctr, n_blocks)
        ks = aes_mod.aes128_encrypt_blocks(blocks, rk)
        return _words_from_blocks(ks)[:n_words]

    return jax.vmap(per_lane)(
        jnp.asarray(round_keys, jnp.uint8),
        jnp.asarray(nonce12, jnp.uint8),
        jnp.asarray(block_ctrs, dtype=jnp.uint32),
    )


def threefry_root_key(nonce: np.ndarray):
    """Root PRF key for a nonce (host-side, once per session)."""
    nonce = np.asarray(nonce, dtype=np.uint8).reshape(16)
    seed = int.from_bytes(nonce.tobytes()[:8], "little")
    return jax.random.key(seed & 0x7FFFFFFFFFFFFFFF)


def threefry_xof_words(nonce: np.ndarray, block_ctrs, n_words: int):
    """TPU-native counter-PRF XOF (beyond-paper fast path)."""
    root = threefry_root_key(nonce)

    def per_lane(ctr):
        k = jax.random.fold_in(root, ctr)
        return jax.random.bits(k, (n_words,), dtype=jnp.uint32)

    return jax.vmap(per_lane)(jnp.asarray(block_ctrs, dtype=jnp.uint32))


def threefry_xof_words_batched(root_keys, block_ctrs, n_words: int):
    """Multi-stream threefry XOF: per-lane root keys (see threefry_root_key).

    root_keys: (lanes,) typed PRNG key array (gathered from a session table).
    Bit-identical to :func:`threefry_xof_words` per lane.
    """

    def per_lane(root, ctr):
        k = jax.random.fold_in(root, ctr)
        return jax.random.bits(k, (n_words,), dtype=jnp.uint32)

    return jax.vmap(per_lane)(root_keys, jnp.asarray(block_ctrs, jnp.uint32))


_BACKENDS = {"aes": aes_xof_words, "threefry": threefry_xof_words}


def make_xof(kind: str):
    if kind not in _BACKENDS:
        raise ValueError(f"unknown XOF backend {kind!r}; have {list(_BACKENDS)}")
    return _BACKENDS[kind]


def xof_words(kind: str, nonce, block_ctrs, n_words: int):
    return make_xof(kind)(nonce, block_ctrs, n_words)
