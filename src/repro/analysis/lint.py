"""Schedule-program linter: registered safety rules beyond `validate()`.

``Schedule.validate()`` is the executor gate — it raises on the first
inconsistency so a malformed program can never run.  The linter is the
*reviewer* gate: it walks the whole program (via ``Schedule.op_table()``,
which never raises), reports EVERY violation with an error code, severity,
op index, and provenance, and supports noqa-style suppression — so a new
``build_schedule`` variant gets a complete diagnosis instead of the first
``ValueError``, and CI can gate on "no lint errors" across the full
preset x variant matrix.

Rule catalog (docs/DESIGN.md §13 — keep in sync):

  SA101  rc-coverage       round-constant slices must tile [0, max) exactly
  SA102  rc-shape          rc-slice width vs state width / ARK key_len laws
  SA103  orientation-chain each op's declared orientation == chain state
  SA104  orientation-parity flips must net out: program ends NORMAL
  SA105  truncate-last     at most one TRUNCATE; only ARK/AGN may follow
  SA106  agn-placement     AGN only on rubato programs, once, as last op
  SA107  branch-shape      PASTA laws: branches/mix/init/ARK consistency
  SA108  rc-storage-perm   FIFO reorder is a slice-local, branch-local perm
  SA109  op-fields         enum fields (orientation, nonlinearity) in range
  SA110  mat-plane-shape   stream ops carry a well-formed matrix-plane slice
  SA111  terminal-reduction state fully reduced at TRUNCATE/AGN/program end
                           under the active reduction plan (core/redplan.py)
  SA201  vacuous-variant   (warning) alternating plan that never flips

Suppression: a rule code listed in ``Schedule.suppress`` (the program's
own ``# noqa`` escape hatch) or passed via ``lint(sched, suppress=...)``
is skipped.  Errors gate CI; warnings are reported but never fail.

Plan-aware rules: checkers declaring a third parameter receive the
``ReductionPlan`` passed to ``lint(sched, plan=...)`` (None when the
caller lints the schedule alone) — the linter threads reduction-schedule
context without changing the two-argument rule contract.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import schedule as S
from repro.core.schedule import Schedule

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to the op that caused it."""

    code: str
    severity: str            # "error" | "warning"
    rule: str                # short rule name ("rc-coverage")
    message: str
    schedule: str            # schedule name ("pasta-128l/alternating")
    op_index: Optional[int]  # None = whole-program finding
    provenance: str          # op_table provenance, or the schedule name

    def render(self) -> str:
        sev = self.severity.upper()
        return f"{self.code} [{sev}] {self.provenance}: {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    name: str
    severity: str
    doc: str
    check: Callable[[Schedule, Tuple[S.OpInfo, ...]],
                    Iterator[Tuple[Optional[int], str]]]


_RULES: Dict[str, Rule] = {}


def rule(code: str, name: str, severity: str = ERROR):
    """Register a checker.  Checkers take (schedule, op_table) and yield
    (op_index | None, message) pairs; the framework wraps them into
    :class:`Finding`s with provenance."""

    def deco(fn):
        if code in _RULES:
            raise ValueError(f"lint rule {code} already registered")
        _RULES[code] = Rule(code=code, name=name, severity=severity,
                            doc=(fn.__doc__ or "").strip(), check=fn)
        return fn

    return deco


def registered_rules() -> Tuple[Rule, ...]:
    """All rules, sorted by code — the catalog docs/DESIGN.md §13 mirrors."""
    return tuple(_RULES[c] for c in sorted(_RULES))


def lint(sched: Schedule, suppress: Iterable[str] = (),
         plan=None) -> List[Finding]:
    """Run every registered rule over ``sched``; return all findings.

    Rules named in ``suppress`` or in ``sched.suppress`` are skipped
    entirely (the noqa mechanism).  Unknown codes in either set raise —
    a suppression that matches nothing is a stale escape hatch.

    ``plan`` (a `core.redplan.ReductionPlan`, optional) is handed to
    plan-aware rules — checkers whose signature declares a third
    parameter (SA111) — so reduction-schedule laws lint alongside the
    structural ones; with ``plan=None`` those rules have nothing to check.
    """
    muted = set(suppress) | set(sched.suppress)
    unknown = muted - set(_RULES)
    if unknown:
        raise ValueError(
            f"unknown lint rule code(s) suppressed: {sorted(unknown)}; "
            f"registered: {sorted(_RULES)}"
        )
    table = sched.op_table()
    findings: List[Finding] = []
    for r in registered_rules():
        if r.code in muted:
            continue
        takes_plan = len(inspect.signature(r.check).parameters) >= 3
        results = r.check(sched, table, plan) if takes_plan \
            else r.check(sched, table)
        for op_index, message in results:
            prov = (table[op_index].provenance
                    if op_index is not None and op_index < len(table)
                    else sched.name)
            findings.append(Finding(
                code=r.code, severity=r.severity, rule=r.name,
                message=message, schedule=sched.name, op_index=op_index,
                provenance=prov,
            ))
    return findings


def errors(findings: Iterable[Finding]) -> List[Finding]:
    return [f for f in findings if f.severity == ERROR]


# ==========================================================================
# Helpers
# ==========================================================================
def _rc_ops(table):
    """(OpInfo, rc_slice) for every constant-consuming op, program order."""
    out = []
    for info in table:
        op = info.op
        if isinstance(op, S.ARK):
            out.append((info, op.rc_slice))
        elif isinstance(op, S.MRMC) and op.has_rc:
            out.append((info, op.rc_slice))
    return out


# ==========================================================================
# Rules
# ==========================================================================
@rule("SA101", "rc-coverage")
def _check_rc_coverage(sched, table):
    """Round-constant slices must tile [0, max_end) exactly — no gap, no
    overlap, no reuse: the producer's FIFO delivers each constant once and
    the accounting (n_round_constants) is the max slice end."""
    rc = _rc_ops(table)
    if not rc:
        yield None, ("program consumes no round constants at all (every "
                     "cipher draws per-block randomness)")
        return
    covered = np.zeros(max(0, max(b for _, (_, b) in rc)), dtype=np.int32)
    for info, (a, b) in rc:
        if a < 0 or b <= a:
            yield info.index, f"degenerate rc_slice [{a}, {b})"
            continue
        covered[a:b] += 1
    gaps = np.flatnonzero(covered == 0)
    if gaps.size:
        yield None, (f"rc stream has {gaps.size} unconsumed constant(s), "
                     f"first at index {int(gaps[0])} (gap in slice tiling)")
    over = np.flatnonzero(covered > 1)
    if over.size:
        yield None, (f"{over.size} constant(s) consumed more than once, "
                     f"first at index {int(over[0])} (overlapping slices)")
    prev_end = 0
    for info, (a, b) in rc:
        if a != prev_end:
            yield info.index, (
                f"rc_slice starts at {a} but the FIFO cursor is at "
                f"{prev_end} — constants must be consumed in stream order")
        prev_end = max(prev_end, b)


@rule("SA102", "rc-shape")
def _check_rc_shape(sched, table):
    """Constant-slice widths must match the state: an ARK consumes exactly
    key_len == state-width constants (Rubato's final truncated ARK included),
    and an affine MRMC adds exactly state-width constants."""
    for info in table:
        op = info.op
        if isinstance(op, S.ARK):
            a, b = op.rc_slice
            if b - a != op.key_len:
                yield info.index, (f"rc_slice width {b - a} != key_len "
                                   f"{op.key_len}")
            if op.key_len != info.in_width:
                yield info.index, (f"key_len {op.key_len} != state width "
                                   f"{info.in_width} at this op")
        elif isinstance(op, S.MRMC) and op.has_rc:
            a, b = op.rc_slice
            if b - a != info.in_width:
                yield info.index, (f"affine rc_slice width {b - a} != "
                                   f"state width {info.in_width}")


@rule("SA103", "orientation-chain")
def _check_orientation_chain(sched, table):
    """Every op must declare the orientation the chain actually delivers:
    only MRMC may change orientation (out_orientation), so a mismatch means
    the op would read a differently-laid-out state than it was compiled
    for — silent wrong answers in the storage-order kernels."""
    for info in table:
        if info.op.orientation != info.chain_orientation:
            yield info.index, (
                f"declares {info.op.orientation} input but the chain is "
                f"{info.chain_orientation} here (flips happen only at MRMC "
                f"out_orientation)")


@rule("SA104", "orientation-parity")
def _check_orientation_parity(sched, table):
    """Orientation flips must net out: the program must END in normal
    orientation (keystream bytes are defined row-major).  An alternating
    variant with an odd uncompensated flip count emits transposed output."""
    if table and table[-1].out_orientation != S.NORMAL:
        flips = sum(1 for i in table
                    if isinstance(i.op, S.MRMC)
                    and i.op.orientation != i.op.out_orientation)
        yield None, (f"program ends in transposed orientation "
                     f"({flips} net-odd MRMC flip(s)); output relabeling "
                     f"does not net to normal")


@rule("SA105", "truncate-last")
def _check_truncate_last(sched, table):
    """TRUNCATE is a terminal narrowing: at most one, in normal
    orientation, keep == schedule.l, and only width-l ops (ARK, AGN) may
    follow — a matrix or Feistel layer after truncation would read past
    the narrowed state."""
    seen = None
    for info in table:
        op = info.op
        if isinstance(op, S.TRUNCATE):
            if seen is not None:
                yield info.index, "second TRUNCATE (only one allowed)"
            seen = info.index
            if info.chain_orientation != S.NORMAL:
                yield info.index, "TRUNCATE needs normal orientation"
            if not (0 < op.keep <= info.in_width):
                yield info.index, (f"keep {op.keep} out of range for state "
                                   f"width {info.in_width}")
            if op.keep != sched.l:
                yield info.index, (f"keep {op.keep} != schedule.l "
                                   f"{sched.l}")
        elif seen is not None and not isinstance(op, (S.ARK, S.AGN)):
            yield info.index, (f"{type(op).__name__} after TRUNCATE "
                               f"(ops[{seen}]); only ARK/AGN may follow")
    if seen is None and sched.l < sched.n:
        yield None, (f"l={sched.l} < n={sched.n} but the program never "
                     f"truncates")


@rule("SA106", "agn-placement")
def _check_agn_placement(sched, table):
    """AGN is Rubato's client-side noise stage: legal only on rubato
    programs, at most once, as the final op, in normal orientation — noise
    added mid-program would be amplified by later rounds and break the
    cipher's (and the HE noise budget's) accounting."""
    agns = [i for i in table if isinstance(i.op, S.AGN)]
    for info in agns[1:]:
        yield info.index, "second AGN (only one allowed)"
    if agns:
        info = agns[0]
        if sched.kind != "rubato":
            yield info.index, (f"AGN on a {sched.kind!r} program (only "
                               f"rubato carries cipher-side noise)")
        if info.index != len(table) - 1:
            yield info.index, "AGN must be the final op"
        if info.chain_orientation != S.NORMAL:
            yield info.index, "AGN needs normal orientation"


@rule("SA107", "branch-shape")
def _check_branch_shape(sched, table):
    """PASTA branch laws: branch count matches the state factorization
    (n == branches * v^2), branch mixing only exists on 2-branch states
    and then on EVERY affine layer, and keyed-init programs carry no ARK
    (the key already is the state; an ARK would re-key mid-permutation)."""
    if sched.n != sched.branches * sched.v * sched.v:
        yield None, (f"n={sched.n} != branches*v^2 = "
                     f"{sched.branches * sched.v * sched.v}")
    for info in table:
        op = info.op
        if isinstance(op, S.MRMC) and op.mix_branches and sched.branches != 2:
            yield info.index, (f"mix_branches on a {sched.branches}-branch "
                               f"state (needs exactly 2)")
        if sched.branches == 2 and isinstance(op, S.MRMC) and op.has_rc \
                and not op.mix_branches:
            yield info.index, ("affine layer without branch mixing on a "
                               "2-branch state (PASTA couples branches at "
                               "every affine layer)")
        if sched.init == "key" and isinstance(op, S.ARK):
            yield info.index, ("ARK inside a keyed-init (init='key') "
                               "program")
    if sched.init not in ("ic", "key"):
        yield None, f"unknown init {sched.init!r}"


@rule("SA108", "rc-storage-perm")
def _check_rc_storage_perm(sched, table):
    """The kernel FIFO reorder must be a true permutation that stays inside
    each constant slice AND inside each branch's half of a slice — a
    constant crossing either boundary would be delivered to the wrong
    datapath element (or the wrong branch matrix) in storage order."""
    try:
        perm = sched.rc_storage_perm()
    except Exception as e:  # malformed accounting upstream
        yield None, f"rc_storage_perm() raised: {e}"
        return
    if perm is None:
        return
    n_rc = len(perm)
    if sorted(perm) != list(range(n_rc)):
        yield None, "rc storage reorder is not a permutation"
        return
    t = sched.n // sched.branches
    for info, (a, b) in _rc_ops(table):
        if b > n_rc or a < 0:
            continue  # SA101's finding
        seg = perm[a:b] - a
        if (seg < 0).any() or (seg >= b - a).any():
            yield info.index, "storage reorder leaks outside the rc slice"
            continue
        if sched.branches > 1 and b - a == sched.n:
            for br in range(sched.branches):
                part = seg[br * t:(br + 1) * t]
                if ((part < br * t) | (part >= (br + 1) * t)).any():
                    yield info.index, (
                        f"storage reorder crosses the branch boundary in "
                        f"branch {br}'s half of the slice")
                    break


@rule("SA109", "op-fields")
def _check_op_fields(sched, table):
    """Enum-valued op fields must be in range: orientations from
    ORIENTATIONS, nonlinearity kind from {cube, feistel} — the executors
    silently fall through on unknown values."""
    for info in table:
        op = info.op
        if op.orientation not in S.ORIENTATIONS:
            yield info.index, f"unknown orientation {op.orientation!r}"
        if isinstance(op, S.MRMC) and op.out_orientation not in S.ORIENTATIONS:
            yield info.index, \
                f"unknown out_orientation {op.out_orientation!r}"
        if isinstance(op, S.NONLINEAR) and op.kind not in ("cube", "feistel"):
            yield info.index, f"unknown nonlinearity {op.kind!r}"


@rule("SA110", "mat-plane-shape")
def _check_mat_plane_shape(sched, table):
    """Stream-sourced matrix ops must carry a well-formed plane slice:
    matrix_source in range, slice width == branches*t^2 (one dense t x t
    block per branch), slices consumed contiguously in matrix-FIFO order,
    and static-matrix ops carrying no slice at all — a malformed slice
    would feed an op the wrong (or another op's) streamed matrix."""
    cursor = 0
    for info in table:
        op = info.op
        if not isinstance(op, S.MRMC):
            continue
        if op.matrix_source not in ("static", "stream"):
            yield info.index, \
                f"unknown matrix_source {op.matrix_source!r}"
            continue
        if not op.streams_matrix:
            if op.mat_slice != (0, 0):
                yield info.index, (f"static-matrix op carries mat_slice "
                                   f"{op.mat_slice} (must be (0, 0))")
            continue
        a, b = op.mat_slice
        want = info.in_width * (info.in_width // sched.branches)
        if a < 0 or b - a != want:
            yield info.index, (
                f"mat_slice [{a}, {b}) is {b - a} words, need "
                f"branches*t^2 = {want} (one dense t x t per branch)")
        if a != cursor:
            yield info.index, (
                f"mat_slice starts at {a} but the matrix FIFO cursor is "
                f"at {cursor} — planes must be consumed in stream order")
        cursor = max(cursor, b)


@rule("SA111", "terminal-reduction")
def _check_terminal_reduction(sched, table, plan=None):
    """The terminal-reduction law (docs/DESIGN.md §14): under ANY
    reduction plan the state must be fully reduced (< q) entering every
    TRUNCATE and AGN and at program end — keystream bytes are defined as
    canonical residues, so a reduce deferred past an output boundary
    emits wrong answers, not just different scheduling.  Plan-aware: only
    checks when `lint(sched, plan=...)` supplies the active plan."""
    if plan is None:
        return
    if len(plan.ops) != len(sched.ops):
        yield None, (f"reduction plan has {len(plan.ops)} op entries for a "
                     f"{len(sched.ops)}-op program (stale plan)")
        return
    for idx, what, bound in plan.terminal_sites(sched):
        if bound > plan.q:
            yield idx, (f"{what} bound {bound} > q={plan.q} under the "
                        f"{plan.mode!r} plan — a reduce is deferred past "
                        f"the output boundary (terminal-reduction law)")


@rule("SA201", "vacuous-variant", severity=WARNING)
def _check_vacuous_variant(sched, table):
    """An 'alternating' variant that never actually flips is vacuously
    equal to 'normal' — the orientation property tests pass without
    exercising any transposed code path (a coverage trap, not a bug)."""
    if sched.variant == "alternating" and not sched.has_transposed_ops:
        yield None, ("alternating variant contains no transposed op; the "
                     "flip plan is vacuous")
