"""Static analysis of `Schedule` programs (docs/DESIGN.md §13).

PRs 3-5 made the cipher *data*: HERA, Rubato, and PASTA are declarative
`core/schedule.py` programs that five engines interpret.  Correctness and
performance properties are therefore statically derivable by walking the
program — no runtime, no goldens, no kernel launch:

  * :mod:`repro.analysis.lint` — well-formedness and safety rules beyond
    ``Schedule.validate()``: rc-slice coverage/disjointness, orientation
    parity, PASTA branch-shape laws, TRUNCATE/AGN placement.  Each rule is
    a registered checker with an error code, severity, and noqa-style
    suppression; findings carry op index + provenance.
  * :mod:`repro.analysis.bounds` — abstract interpretation: worst-case
    value intervals through the limb-scheme datapath, enumerated from the
    same `crypto.modmath` constants the kernels use, PROVING uint32
    accumulator safety for every preset x variant; plus static
    multiplicative-depth derivation cross-checked against the
    depth-tracked FV circuit's measured depths.
  * :mod:`repro.analysis.cost` — analytic cost model: op counts, bytes
    moved, and modmul intensity per program -> per-engine roofline
    ceilings, validated against the tuner's measured `StreamPlan` timings
    (predicted ordering must match measured ordering, tolerance-gated).

One CLI drives all three::

    PYTHONPATH=src python -m repro.analysis <preset> [--variant ...]
    PYTHONPATH=src python -m repro.analysis --all --format json
    PYTHONPATH=src python -m repro.analysis --check     # snapshot drift

`scripts/ci.sh`'s ``analyze`` stage runs the full preset x variant matrix
and fails on any lint error, unproven overflow bound, or depth mismatch.
"""

from repro.analysis.bounds import (          # noqa: F401  (public API)
    DepthReport,
    OverflowProof,
    depth_report,
    prove_overflow_safety,
    static_depth,
)
from repro.analysis.cost import (            # noqa: F401
    CostReport,
    analyze_cost,
    predict_engine_times,
    validate_measured_ordering,
)
from repro.analysis.lint import (            # noqa: F401
    Finding,
    lint,
    registered_rules,
)
