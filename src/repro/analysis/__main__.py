"""CLI driver for the schedule-IR static analyzers.

Examples::

    PYTHONPATH=src python -m repro.analysis pasta-128l
    PYTHONPATH=src python -m repro.analysis hera-128a --variant alternating
    PYTHONPATH=src python -m repro.analysis --all --format json
    PYTHONPATH=src python -m repro.analysis --all --check         # drift gate
    PYTHONPATH=src python -m repro.analysis --all --write-snapshot
    PYTHONPATH=src python -m repro.analysis rubato-128s --validate-ordering

Exit status is 0 only when every requested claim holds: no lint errors,
every overflow obligation proved, static == paper == measured depth, and
(when requested) predicted engine ordering matching the measured plans /
snapshot analytic fields matching exactly.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.analysis.bounds import depth_report, prove_overflow_safety
from repro.analysis.cost import (
    MachineModel,
    analyze_cost,
    predict_engine_times,
    reduction_report,
    validate_measured_ordering,
)
from repro.analysis.lint import ERROR, lint
from repro.core.params import REGISTRY, get_params
from repro.core.redplan import plan_reductions
from repro.core.schedule import VARIANTS

#: 1 = initial analytic matrix; 2 = reduction-scheduling pass (per-variant
#: "reduction" eager-vs-lazy cond-subtract deltas + lazy-plan overflow
#: proofs; lint now runs against the shipped lazy plan so SA111 is live)
SNAPSHOT_SCHEMA = 2
DEFAULT_SNAPSHOT = (pathlib.Path(__file__).resolve().parents[3]
                    / "benchmarks" / "BENCH_schedule_analysis.json")
#: relative drift in measured per-lane p50 that --check flags
MEASURED_DRIFT_TOL = 0.20


def analyze_one(name: str, variant: str, measure: bool = True) -> dict:
    """Run all three analyzers on one (preset, variant); JSON-able dict."""
    params = get_params(name)
    sched = params.schedule(variant)
    lazy_plan = plan_reductions(params, sched, "lazy")
    findings = lint(sched, plan=lazy_plan)
    proof = prove_overflow_safety(params, sched, reduction="eager")
    lazy_proof = prove_overflow_safety(params, sched, plan=lazy_plan)
    depth = depth_report(params, variant, measure=measure)
    cost = analyze_cost(params, sched)
    red = reduction_report(params, sched)

    def proof_json(p):
        return {
            "proved": p.proved,
            "n_checks": len(p.checks),
            "min_margin_bits": round(p.min_margin_bits, 4),
            "tightest": (f"{p.tightest.provenance} :: "
                         f"{p.tightest.site}"),
            "failures": [c.render() for c in p.failures()],
        }

    return {
        "preset": name,
        "variant": variant,
        "lint": {
            "errors": [f.render() for f in findings
                       if f.severity == ERROR],
            "warnings": [f.render() for f in findings
                         if f.severity != ERROR],
        },
        "overflow": proof_json(proof),
        "overflow_lazy": proof_json(lazy_proof),
        "depth": {
            "static": depth.static,
            "paper": depth.paper,
            "measured": depth.measured,
            "ok": depth.ok,
        },
        "cost": cost.to_json(),
        "reduction": red.to_json(),
        "ok": (not findings or all(f.severity != ERROR
                                   for f in findings))
        and proof.proved and lazy_proof.proved and depth.ok,
    }


def render_table(res: dict) -> str:
    lines = [f"== {res['preset']}/{res['variant']} "
             f"[{'ok' if res['ok'] else 'FAIL'}] =="]
    le, lw = res["lint"]["errors"], res["lint"]["warnings"]
    lines.append(f"  lint: {len(le)} error(s), {len(lw)} warning(s)")
    lines += [f"    {m}" for m in le + lw]
    for mode in ("overflow", "overflow_lazy"):
        ov = res[mode]
        tag = "overflow[lazy]" if mode == "overflow_lazy" else "overflow"
        lines.append(
            f"  {tag}: {'PROVED' if ov['proved'] else 'UNPROVEN'} "
            f"({ov['n_checks']} obligations, min margin "
            f"{ov['min_margin_bits']:+.2f} bits at {ov['tightest']})")
        lines += [f"    {m}" for m in ov["failures"]]
    d = res["depth"]
    m = "-" if d["measured"] is None else d["measured"]
    lines.append(f"  depth: static={d['static']} paper={d['paper']} "
                 f"measured={m} [{'ok' if d['ok'] else 'MISMATCH'}]")
    c = res["cost"]
    lines.append(
        f"  cost/lane: {c['modmul']} modmul, {c['modadd']} modadd, "
        f"{c['reduce_steps']} reduce steps, {c['shift_add']} shift-adds, "
        f"{c['bytes_per_lane']} B moved "
        f"(intensity {c['modmul_intensity']:.4f} modmul/B), "
        f"{c['call_sites']} call sites")
    r = res["reduction"]
    lines.append(
        f"  reduction: eager {r['eager_steps']} -> lazy {r['lazy_steps']} "
        f"cond-subtract steps/lane (-{r['saved_steps']}, "
        f"{r['saved_pct']:.1f}% saved)")
    return "\n".join(lines)


# ==========================================================================
# Snapshot (benchmarks/BENCH_schedule_analysis.json)
# ==========================================================================
def build_snapshot(measure: bool, lanes: int) -> dict:
    """Full preset x variant analytic matrix + predicted ceilings +
    whatever measured tuner tables exist in the plan cache."""
    from repro.core.tuner import load_measurements

    machine = MachineModel.for_backend()
    presets: dict = {}
    for name in sorted(REGISTRY):
        params = get_params(name)
        variants = {v: analyze_one(name, v, measure=measure)
                    for v in VARIANTS}
        preds = predict_engine_times(params, lanes=1, machine=machine)
        measured = {}
        for row in load_measurements(params, lanes=lanes):
            eng = row.get("engine")
            win = max(1, int(row.get("window", 1)))
            if eng is None or "p50_ms" not in row:
                continue
            per_lane = float(row["p50_ms"]) / win
            if eng not in measured or per_lane < measured[eng]:
                measured[eng] = per_lane
        presets[name] = {
            "variants": variants,
            "predicted": {e: p.to_json() for e, p in sorted(preds.items())},
            "measured_p50_ms_per_lane": {e: round(t, 6)
                                         for e, t in sorted(measured.items())},
        }
    return {
        "schema": SNAPSHOT_SCHEMA,
        "backend": machine.name,
        "lanes": lanes,
        "presets": presets,
    }


def check_snapshot(snapshot: dict, current: dict, strict: bool) -> list:
    """Compare a stored snapshot against the current analysis.

    Analytic fields (lint counts, proof status/obligation count/margins,
    depths, cost counters) are deterministic and must match EXACTLY.
    Predicted ceilings compare only when the snapshot's backend matches
    this host's.  Measured p50 drift beyond MEASURED_DRIFT_TOL is a
    warning — an error only under --strict (a clean checkout has no plan
    cache and must still pass CI).
    Returns a list of (level, message); level in {"error", "warning"}.
    """
    problems: list = []
    same_backend = snapshot.get("backend") == current.get("backend")
    if snapshot.get("schema") != SNAPSHOT_SCHEMA:
        return [("error", f"snapshot schema {snapshot.get('schema')} != "
                 f"{SNAPSHOT_SCHEMA}; regenerate with --write-snapshot")]
    for name, snap in sorted(snapshot.get("presets", {}).items()):
        cur = current["presets"].get(name)
        if cur is None:
            problems.append(("error", f"{name}: preset vanished from "
                             "REGISTRY but is in the snapshot"))
            continue
        for variant, sv in sorted(snap.get("variants", {}).items()):
            cv = cur["variants"].get(variant)
            if cv is None:
                problems.append(("error", f"{name}/{variant}: variant "
                                 "missing from current analysis"))
                continue
            for path, get in (
                ("lint errors", lambda r: len(r["lint"]["errors"])),
                ("lint warnings", lambda r: len(r["lint"]["warnings"])),
                ("overflow proved", lambda r: r["overflow"]["proved"]),
                ("overflow n_checks", lambda r: r["overflow"]["n_checks"]),
                ("overflow min_margin_bits",
                 lambda r: r["overflow"]["min_margin_bits"]),
                ("overflow_lazy proved",
                 lambda r: r["overflow_lazy"]["proved"]),
                ("overflow_lazy n_checks",
                 lambda r: r["overflow_lazy"]["n_checks"]),
                ("overflow_lazy min_margin_bits",
                 lambda r: r["overflow_lazy"]["min_margin_bits"]),
                ("reduction", lambda r: r["reduction"]),
                ("depth static", lambda r: r["depth"]["static"]),
                ("depth paper", lambda r: r["depth"]["paper"]),
                ("cost", lambda r: {k: v for k, v in r["cost"].items()
                                    if k != "modmul_intensity"}),
            ):
                want, got = get(sv), get(cv)
                if want != got:
                    problems.append(
                        ("error", f"{name}/{variant}: {path} drifted: "
                         f"snapshot {want!r} != current {got!r}"))
        if same_backend:
            for eng, sp in sorted(snap.get("predicted", {}).items()):
                cp = cur["predicted"].get(eng)
                if cp is None:
                    problems.append(("warning", f"{name}: engine {eng} no "
                                     "longer predicted on this backend"))
                    continue
                for field in ("ceiling_lanes_per_s", "bound_by"):
                    if sp.get(field) != cp.get(field):
                        problems.append(
                            ("error", f"{name}: predicted {eng}.{field} "
                             f"drifted: {sp.get(field)!r} != "
                             f"{cp.get(field)!r}"))
        for eng, ms in sorted(
                snap.get("measured_p50_ms_per_lane", {}).items()):
            cm = cur["measured_p50_ms_per_lane"]
            if eng not in cm:
                problems.append(("warning", f"{name}: no current measured "
                                 f"timing for {eng} (plan cache empty?)"))
                continue
            drift = abs(cm[eng] - ms) / max(ms, 1e-12)
            if drift > MEASURED_DRIFT_TOL:
                level = "error" if strict else "warning"
                problems.append(
                    (level, f"{name}: measured {eng} p50/lane drifted "
                     f"{drift * 100:.0f}% (snapshot {ms:.4f} ms, "
                     f"now {cm[eng]:.4f} ms)"))
    return problems


# ==========================================================================
# Entry point
# ==========================================================================
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis of schedule-IR cipher programs: "
                    "lint, overflow/depth proofs, analytic roofline.")
    ap.add_argument("preset", nargs="?", choices=sorted(REGISTRY),
                    help="one preset; or use --all")
    ap.add_argument("--all", action="store_true",
                    help="analyze every preset in the registry")
    ap.add_argument("--variant", choices=list(VARIANTS) + ["all"],
                    default="all", help="schedule variant (default: all)")
    ap.add_argument("--format", choices=("table", "json"), default="table")
    ap.add_argument("--no-measure", action="store_true",
                    help="skip the measured FV-depth cross-check (fast)")
    ap.add_argument("--check", action="store_true",
                    help="compare against the checked-in snapshot; exit 1 "
                         "on analytic drift")
    ap.add_argument("--strict", action="store_true",
                    help="with --check: measured-timing drift is an error")
    ap.add_argument("--write-snapshot", action="store_true",
                    help="regenerate the snapshot file")
    ap.add_argument("--snapshot", type=pathlib.Path,
                    default=DEFAULT_SNAPSHOT, metavar="PATH")
    ap.add_argument("--validate-ordering", action="store_true",
                    help="check predicted vs measured engine ordering "
                         "from the tuner's cached measurement tables")
    ap.add_argument("--lanes", type=int, default=8,
                    help="lane count for measurement lookup (default 8)")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="measured-gap tolerance for ordering (default 0.2)")
    args = ap.parse_args(argv)

    if not args.preset and not args.all:
        ap.error("give a preset name or --all")
    names = sorted(REGISTRY) if args.all else [args.preset]
    variants = list(VARIANTS) if args.variant == "all" else [args.variant]
    measure = not args.no_measure

    if args.check or args.write_snapshot:
        current = build_snapshot(measure=measure, lanes=args.lanes)
        if args.write_snapshot:
            args.snapshot.write_text(
                json.dumps(current, indent=1, sort_keys=True) + "\n")
            print(f"wrote {args.snapshot}")
            bad = [n for n, p in current["presets"].items()
                   for v in p["variants"].values() if not v["ok"]]
            return 1 if bad else 0
        if not args.snapshot.exists():
            print(f"snapshot {args.snapshot} missing; run --write-snapshot",
                  file=sys.stderr)
            return 1
        snapshot = json.loads(args.snapshot.read_text())
        problems = check_snapshot(snapshot, current, strict=args.strict)
        for level, msg in problems:
            print(f"[{level}] {msg}")
        errors = [m for level, m in problems if level == "error"]
        analytic_ok = all(v["ok"] for p in current["presets"].values()
                          for v in p["variants"].values())
        print(f"snapshot check: {len(errors)} error(s), "
              f"{len(problems) - len(errors)} warning(s); analytic "
              f"matrix {'ok' if analytic_ok else 'FAIL'}")
        return 0 if not errors and analytic_ok else 1

    results = [analyze_one(n, v, measure=measure)
               for n in names for v in variants]
    ok = all(r["ok"] for r in results)

    ordering_reports = []
    if args.validate_ordering:
        from repro.core.tuner import load_measurements

        for n in names:
            params = get_params(n)
            rows = load_measurements(params, lanes=args.lanes)
            ordering_reports.append(
                validate_measured_ordering(params, rows, tol=args.tol))
        ok = ok and all(r.ok or r.skipped for r in ordering_reports)

    if args.format == "json":
        out = {"results": results, "ok": ok}
        if ordering_reports:
            out["ordering"] = [
                {"preset": r.preset, "ok": r.ok, "skipped": r.skipped,
                 "measured_per_lane_ms": r.measured_per_lane_ms,
                 "predicted_per_lane_ms": r.predicted_per_lane_ms}
                for r in ordering_reports]
        print(json.dumps(out, indent=1, sort_keys=True))
    else:
        for r in results:
            print(render_table(r))
        for r in ordering_reports:
            print(r.render())
        print(f"analysis: {len(results)} program(s) "
              f"[{'ok' if ok else 'FAIL'}]")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
