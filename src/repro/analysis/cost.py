"""Analytic cost model: walk the program, predict per-engine ceilings.

The DaCe ``RooflineModel`` shape (SNIPPETS §2-3): analyze the IR, not the
runtime.  One walk of a `Schedule` yields exact static counts — modular
multiplies (the limb-scheme hot op), reduced adds, conditional-subtract
steps, shift-add chain adds (T4's multiplier-free linear layers), traced
call sites, and bytes moved per lane — from which per-engine roofline
ceilings follow: an engine's throughput is capped by
``min(compute ceiling, memory ceiling)`` under its execution profile
(eager per-site dispatch for ``ref``, fused XLA for ``jax``, the
interpreter penalty for ``pallas-interpret``, lane sharding for
``sharded``).

The model is validated against MEASURED `StreamPlan` timings: the tuner
persists its full per-candidate table (`core/tuner.py
load_measurements`), and :func:`validate_measured_ordering` requires the
predicted per-engine ordering to match the measured one wherever the
measured gap exceeds the tolerance — predicted *ratios* are a model,
predicted *ordering* is a checkable claim.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import schedule as S
from repro.core.params import CipherParams
from repro.core.schedule import Schedule

#: one full limb-scheme modmul costs about this many reduced-add
#: equivalents (3 limb products + 2 shiftLs + 4 reduce chains); used only
#: to weight the compute term — ordering, not absolute time, is the claim
MUL_WEIGHT = 12.0


# ==========================================================================
# Static counts: one walk of the program
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class CostReport:
    """Exact static per-program counts (per keystream lane unless noted)."""

    schedule: str
    n_ops: int              # program length (schedule ops)
    modmul: int             # full limb-scheme muls per lane
    modadd: int             # reduced adds per lane
    reduce_steps: int       # conditional-subtract select steps per lane
    shift_add: int          # small-constant add-chain adds per lane (T4)
    call_sites: int         # traced primitive call sites per program
    rc_per_lane: int        # round constants streamed in per lane
    bytes_in_per_lane: int
    bytes_out_per_lane: int

    @property
    def bytes_per_lane(self) -> int:
        return self.bytes_in_per_lane + self.bytes_out_per_lane

    @property
    def weighted_elem_ops(self) -> float:
        """Compute work per lane in reduced-add equivalents."""
        return (self.modmul * MUL_WEIGHT + self.modadd + self.reduce_steps
                + self.shift_add)

    @property
    def modmul_intensity(self) -> float:
        """Modular multiplies per byte moved — the cipher's signature:
        HERA's cube tower is mul-heavy, PASTA's affine layers are
        bandwidth-heavy (constants dominate), Rubato sits between."""
        return self.modmul / max(1, self.bytes_per_lane)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["bytes_per_lane"] = self.bytes_per_lane
        d["modmul_intensity"] = round(self.modmul_intensity, 6)
        d["weighted_elem_ops"] = self.weighted_elem_ops
        return d


def _row_cost(mod, row) -> Tuple[int, int, int, int]:
    """(shift_adds, acc_adds, reduce_steps, call_sites) for one shift-add
    matvec row — a replay of the `matvec_small`/`_combine` interleaved-
    reduce policy with the SAME step schedule `Modulus.reduce` executes."""
    shift_adds = acc_adds = steps = sites = 0
    bound = 0
    for c in row:
        c = int(c)
        if c == 0:
            continue
        if c > 1:
            shift_adds += c - 1
            steps += len(mod.reduce_steps(c * mod.q))
            sites += c  # add chain + reduce
        if bound == 0:
            bound = mod.q
        else:
            if bound + mod.q >= 2**32:
                steps += len(mod.reduce_steps(bound))
                sites += 1
                bound = mod.q
            acc_adds += 1
            sites += 1
            bound += mod.q
    steps += len(mod.reduce_steps(bound))
    sites += 1
    return shift_adds, acc_adds, steps, sites


def analyze_cost(params: CipherParams,
                 schedule: Optional[Schedule] = None,
                 variant: str = "normal") -> CostReport:
    """Walk ``schedule`` once and count everything the engines will do.

    Orientation is cost-free by construction (Eq. 2 flips are output
    relabelings; storage-order constants make transposed ARKs plain
    contiguous reads), so normal and alternating variants of one preset
    report identical counts — which is itself a checkable claim
    (tests/test_analysis.py asserts it).
    """
    if schedule is None:
        schedule = params.schedule(variant)
    mod = params.mod
    add_steps = len(mod.reduce_steps(2 * mod.q))   # every mod.add/sub
    mat = params.mix_matrix()
    v, nb = params.v, schedule.branches

    muls = adds = steps = shift = sites = 0
    for info in schedule.op_table():
        op, w = info.op, info.in_width
        if isinstance(op, S.ARK):
            m = op.key_len
            muls += m
            adds += m
            steps += m * add_steps
            sites += 2
        elif isinstance(op, S.MRMC) and op.streams_matrix:
            # stream-sourced dense affine layer: one t x t matvec per
            # branch under the chunked-accumulate policy of
            # Modulus.matvec_dense (products < q sum raw in uint32 per
            # divisor chunk, one reduce per chunk, then one raw fold of
            # the reduced partials — Modulus.dense_chunk_schedule)
            t = w // nb
            ch, nch = mod.dense_chunk_schedule(t)
            muls += nb * t * t
            adds += nb * t * (t - nch)            # raw in-chunk sums
            adds += nb * t * (nch - 1)            # partial-sum fold
            steps += nb * t * nch * len(mod.reduce_steps(ch * mod.q))
            if nch > 1:
                steps += nb * t * len(mod.reduce_steps(nch * mod.q))
            sites += 3 + (2 if nch > 1 else 0)
            if op.has_rc:
                adds += w
                steps += w * add_steps
                sites += 1
            if op.mix_branches:
                t2 = w // 2
                adds += 3 * t2
                steps += 3 * t2 * add_steps
                sites += 3
        elif isinstance(op, S.MRMC):
            # two matvec passes (MixColumns, MixRows) per branch; each
            # pass applies every matrix row across v row-vectors of width v
            for row in mat:
                sa, aa, st, si = _row_cost(mod, row)
                muls += 0
                shift += 2 * nb * v * sa
                adds += 2 * nb * v * aa
                steps += 2 * nb * v * st
                sites += 2 * nb * si
            if op.has_rc:
                adds += w
                steps += w * add_steps
                sites += 1
            if op.mix_branches:
                t = w // 2
                adds += 3 * t
                steps += 3 * t * add_steps
                sites += 3
        elif isinstance(op, S.NONLINEAR):
            if op.kind == "cube":
                muls += 2 * w
                sites += 2
            else:  # feistel, per branch: t-1 squares + t adds
                t = w // nb
                muls += nb * (t - 1)
                adds += nb * t
                steps += nb * t * add_steps
                sites += 2 * nb
        elif isinstance(op, S.AGN):
            # signed fold is a where-select (lands in [0, q), no reduce);
            # the one reduced add is the only reduce this path needs
            adds += w
            steps += w * add_steps
            sites += 2
    noise_bytes = 4 * params.l if params.n_noise else 0
    mat_bytes = 4 * schedule.n_matrix_constants   # streamed matrix planes
    return CostReport(
        schedule=schedule.name,
        n_ops=len(schedule.ops),
        modmul=muls, modadd=adds, reduce_steps=steps, shift_add=shift,
        call_sites=sites,
        rc_per_lane=schedule.n_round_constants,
        bytes_in_per_lane=4 * schedule.n_round_constants + noise_bytes
        + mat_bytes,
        bytes_out_per_lane=4 * params.l,
    )


# ==========================================================================
# Reduction-schedule accounting: eager vs lazy conditional-subtract steps
# ==========================================================================
def _row_reduce_steps(mod, row, in_bound: int, lazy: bool) -> int:
    """Conditional-subtract steps ONE shift-add matvec row fires under the
    eager or lazy accumulate policy — a steps-only replay of the walk
    `Modulus.matvec_small` / `accumulate_sites` share."""
    steps = 0
    bound = 0
    for c in row:
        c = int(c)
        if c == 0:
            continue
        if lazy:
            tb = c * in_bound          # raw add chain, no per-term reduce
        else:
            tb = mod.q
            if c > 1:
                steps += len(mod.reduce_steps(c * mod.q))
        if bound == 0:
            bound = tb
        else:
            if bound + tb >= 2**32:
                steps += len(mod.reduce_steps(bound))
                bound = mod.q
            bound += tb
    steps += len(mod.reduce_steps(bound))   # terminal row reduce
    return steps


def count_reduce_steps(params: CipherParams, schedule: Schedule,
                       plan) -> int:
    """Total conditional-subtract select steps per keystream lane when the
    program executes under ``plan`` (a `core.redplan.ReductionPlan`) —
    including the limb-internal reduces of every modular multiply
    (`Modulus.mul_reduce_steps`), replayed from the same static step
    schedules the datapath fires."""
    from repro.core import redplan as RP

    mod = params.mod
    q = mod.q
    add_steps = len(mod.reduce_steps(2 * q))
    mat = params.mix_matrix()
    v, nb = params.v, schedule.branches
    total = 0
    for i, info in enumerate(schedule.op_table()):
        op, w = info.op, info.in_width
        p = plan.ops[i]
        in_b = p.in_bound
        if isinstance(op, S.ARK):
            m = op.key_len
            total += m * mod.mul_reduce_steps()       # k (.) rc limb mul
            if not p.has(RP.DEFER_OUT):
                total += m * len(mod.reduce_steps(in_b + q))
        elif isinstance(op, S.MRMC) and op.streams_matrix:
            t = w // nb
            lazy_d = p.has(RP.LAZY_DENSE)
            per_mul = mod.mul_reduce_steps(
                None, in_b if lazy_d else None, reduce_out=not lazy_d)
            total += nb * t * t * per_mul
            pb = 3 * q if lazy_d else q
            ch, nch = mod.dense_chunk_schedule(t, pb)
            total += nb * t * nch * len(mod.reduce_steps(ch * pb))
            if nch > 1:
                total += nb * t * len(mod.reduce_steps(nch * q))
            fold = p.has(RP.FOLD_MIX)
            if op.has_rc and not fold:
                total += w * add_steps
            if op.mix_branches:
                t2 = w // 2
                if fold:
                    mix_in = 2 * q if op.has_rc else q
                    total += 2 * t2 * len(mod.reduce_steps(3 * mix_in))
                else:
                    total += 3 * t2 * add_steps
        elif isinstance(op, S.MRMC):
            lazy_a = p.has(RP.LAZY_ACCUMULATE)
            for row in mat:
                # first pass sees operands < in_b; its rows reduce
                # terminally, so the second pass runs from q
                total += nb * v * (
                    _row_reduce_steps(mod, row, in_b, lazy_a)
                    + _row_reduce_steps(mod, row, q, lazy_a))
            if op.has_rc:
                total += w * add_steps
            if op.mix_branches:
                total += 3 * (w // 2) * add_steps
        elif isinstance(op, S.NONLINEAR):
            if op.kind == "cube":
                total += 2 * w * mod.mul_reduce_steps()
            else:
                t = w // nb
                total += nb * (t - 1) * mod.mul_reduce_steps()
                total += nb * t * len(mod.reduce_steps(in_b + q))
        elif isinstance(op, S.AGN):
            total += w * add_steps
    return total


@dataclasses.dataclass(frozen=True)
class ReductionReport:
    """Eager vs lazy conditional-subtract totals for one program — the
    reduction-scheduling pass's measurable static win, surfaced in the
    analysis snapshot (`repro.analysis.__main__`)."""

    schedule: str
    eager_steps: int        # per lane, everything-reduced plan
    lazy_steps: int         # per lane, shipped lazy plan

    @property
    def saved_steps(self) -> int:
        return self.eager_steps - self.lazy_steps

    @property
    def saved_pct(self) -> float:
        return 100.0 * self.saved_steps / max(1, self.eager_steps)

    def to_json(self) -> dict:
        return {
            "schedule": self.schedule,
            "eager_steps": self.eager_steps,
            "lazy_steps": self.lazy_steps,
            "saved_steps": self.saved_steps,
            "saved_pct": round(self.saved_pct, 3),
        }

    def render(self) -> str:
        return (f"reduction {self.schedule}: eager {self.eager_steps} -> "
                f"lazy {self.lazy_steps} cond-subtract steps/lane "
                f"(-{self.saved_steps}, {self.saved_pct:.1f}% saved)")


def reduction_report(params: CipherParams,
                     schedule: Optional[Schedule] = None,
                     variant: str = "normal") -> ReductionReport:
    """Count the program's conditional-subtract steps under the eager and
    lazy reduction plans (`core/redplan.py`) and report the delta.  The
    lazy plan is the shipped default datapath, so ``saved_steps`` is the
    static reduce-work the pass actually removed."""
    from repro.core.redplan import plan_reductions

    if schedule is None:
        schedule = params.schedule(variant)
    eager = count_reduce_steps(
        params, schedule, plan_reductions(params, schedule, "eager"))
    lazy = count_reduce_steps(
        params, schedule, plan_reductions(params, schedule, "lazy"))
    return ReductionReport(schedule=schedule.name, eager_steps=eager,
                           lazy_steps=lazy)


# ==========================================================================
# Machine + engine profiles -> roofline ceilings
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Sustained rates the ceilings are computed against.  Deterministic
    per backend kind (cpu/gpu/tpu) so snapshots compare stably across
    hosts of the same kind; absolute accuracy is NOT the claim — measured
    validation is ordering-only."""

    name: str
    elem_ops_per_s: float    # sustained u32 elementwise ops (add-equiv)
    mem_bw: float            # bytes/s
    dispatch_s: float        # per traced-primitive eager dispatch cost

    @classmethod
    def for_backend(cls, backend: Optional[str] = None) -> "MachineModel":
        if backend is None:
            import jax

            backend = jax.default_backend()
        if backend == "tpu":
            # one TPU v5e-class chip (benchmarks/cipher_roofline.py scales
            # by mesh size separately)
            return cls(name="tpu", elem_ops_per_s=2e12, mem_bw=819e9,
                       dispatch_s=3e-6)
        if backend == "gpu":
            return cls(name="gpu", elem_ops_per_s=5e11, mem_bw=1.5e12,
                       dispatch_s=5e-6)
        return cls(name="cpu", elem_ops_per_s=5e9, mem_bw=2e10,
                   dispatch_s=20e-6)


@dataclasses.dataclass(frozen=True)
class EngineProfile:
    """How one registered engine maps static counts to time."""

    name: str
    compute_scale: float = 1.0      # multiplier on machine elem throughput
    interpret_factor: float = 1.0   # slowdown for interpreter execution
    eager_dispatch: bool = False    # pays dispatch_s per call site per op
    fused_io: bool = True           # False: intermediate HBM round trips
    tpu_only: bool = False


ENGINE_PROFILES: Dict[str, EngineProfile] = {
    # eager per-primitive dispatch dominates small windows
    "ref": EngineProfile(name="ref", eager_dispatch=True, fused_io=False),
    "jax": EngineProfile(name="jax"),
    # fused kernel: modules overlap, constants stream (T1/T3)
    "pallas": EngineProfile(name="pallas", compute_scale=1.6, tpu_only=True),
    "pallas-interpret": EngineProfile(name="pallas-interpret",
                                      interpret_factor=400.0,
                                      eager_dispatch=True),
    "sharded": EngineProfile(name="sharded", compute_scale=1.6),
}


@dataclasses.dataclass(frozen=True)
class EnginePrediction:
    """Predicted cost of one engine on one (program, lanes) workload."""

    engine: str
    seconds: float           # predicted wall time for the window
    compute_s: float
    memory_s: float
    dispatch_s: float
    ceiling_lanes_per_s: float   # roofline: min(compute, memory) ceiling
    bound_by: str            # "compute" | "memory" | "dispatch"

    @property
    def per_lane_s(self) -> float:
        return self.seconds

    def to_json(self) -> dict:
        return {"engine": self.engine, "seconds": self.seconds,
                "ceiling_lanes_per_s": self.ceiling_lanes_per_s,
                "bound_by": self.bound_by}


def predict_engine_times(params: CipherParams, lanes: int,
                         engines: Optional[Sequence[str]] = None,
                         variant: str = "normal",
                         machine: Optional[MachineModel] = None,
                         ) -> Dict[str, EnginePrediction]:
    """Per-engine predicted window time + roofline ceiling for ``lanes``
    keystream lanes of this preset.  Engines default to every profiled
    backend legal on this machine kind (``pallas`` only on tpu)."""
    if machine is None:
        machine = MachineModel.for_backend()
    cost = analyze_cost(params, variant=variant)
    if engines is None:
        engines = [n for n, p in ENGINE_PROFILES.items()
                   if not (p.tpu_only and machine.name != "tpu")]
    out: Dict[str, EnginePrediction] = {}
    for name in engines:
        prof = ENGINE_PROFILES[name]
        rate = machine.elem_ops_per_s * prof.compute_scale \
            / prof.interpret_factor
        t_compute = cost.weighted_elem_ops * lanes / rate
        io_factor = 1.0 if prof.fused_io else 2.0  # per-op HBM round trips
        t_memory = cost.bytes_per_lane * lanes * io_factor / machine.mem_bw
        t_dispatch = (cost.call_sites * machine.dispatch_s
                      if prof.eager_dispatch else 0.0)
        seconds = max(t_compute, t_memory) + t_dispatch
        ceiling = min(rate / cost.weighted_elem_ops,
                      machine.mem_bw / (cost.bytes_per_lane * io_factor))
        bound = max((("compute", t_compute), ("memory", t_memory),
                     ("dispatch", t_dispatch)), key=lambda kv: kv[1])[0]
        out[name] = EnginePrediction(
            engine=name, seconds=seconds, compute_s=t_compute,
            memory_s=t_memory, dispatch_s=t_dispatch,
            ceiling_lanes_per_s=ceiling, bound_by=bound,
        )
    return out


# ==========================================================================
# Validation against measured StreamPlan timings
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class OrderingPair:
    fast: str                # engine predicted faster
    slow: str
    predicted_ratio: float   # slow/fast, > 1
    measured_ratio: float    # measured slow/fast (per-lane p50)
    within_tolerance: bool   # measured gap too small to rank
    agrees: bool

    def render(self) -> str:
        if self.within_tolerance:
            return (f"  {self.fast} ~ {self.slow}: measured gap "
                    f"{self.measured_ratio:.2f}x within tolerance (unranked)")
        mark = "ok" if self.agrees else "MISMATCH"
        return (f"  {self.fast} < {self.slow}: predicted "
                f"{self.predicted_ratio:.1f}x, measured "
                f"{self.measured_ratio:.2f}x [{mark}]")


@dataclasses.dataclass(frozen=True)
class OrderingReport:
    """Did the analytic model rank the engines the way the farm measured
    them?  Pairs whose measured gap is within tolerance are unranked (a
    model should not be failed on noise)."""

    preset: str
    measured_per_lane_ms: Dict[str, float]   # best plan per engine
    predicted_per_lane_ms: Dict[str, float]
    pairs: Tuple[OrderingPair, ...]
    skipped: str = ""        # non-empty = validation had nothing to rank

    @property
    def ok(self) -> bool:
        return all(p.agrees or p.within_tolerance for p in self.pairs)

    def render(self) -> str:
        if self.skipped:
            return f"ordering {self.preset}: SKIPPED ({self.skipped})"
        lines = [f"ordering {self.preset}: "
                 f"{'ok' if self.ok else 'MISMATCH'}"]
        for eng in sorted(self.measured_per_lane_ms):
            lines.append(
                f"  {eng:18s} measured {self.measured_per_lane_ms[eng]:9.4f} "
                f"ms/lane   predicted {self.predicted_per_lane_ms[eng]:9.4f}")
        lines += [p.render() for p in self.pairs]
        return "\n".join(lines)


def validate_measured_ordering(params: CipherParams,
                               measurements: Sequence[dict],
                               tol: float = 0.2,
                               machine: Optional[MachineModel] = None,
                               ) -> OrderingReport:
    """Check the model's per-engine ordering against a measured timing
    table (rows from `core.tuner.load_measurements`: plan fields +
    ``p50_ms`` per candidate).

    Per engine the BEST measured plan is used (the tuner's own selection
    semantics), normalized to per-lane latency by its window so plans at
    different window sizes compare.  For every engine pair whose measured
    gap exceeds ``tol`` the predicted ordering must agree.
    """
    best: Dict[str, float] = {}
    for row in measurements:
        eng = row.get("engine")
        win = max(1, int(row.get("window", 1)))
        if eng is None or "p50_ms" not in row:
            continue
        per_lane = float(row["p50_ms"]) / win
        if eng not in best or per_lane < best[eng]:
            best[eng] = per_lane
    if len(best) < 2:
        return OrderingReport(
            preset=params.name, measured_per_lane_ms=best,
            predicted_per_lane_ms={}, pairs=(),
            skipped=f"need >= 2 measured engines, have {sorted(best)}")
    preds = predict_engine_times(params, lanes=1, engines=sorted(best),
                                 machine=machine)
    pred_ms = {e: p.seconds * 1e3 for e, p in preds.items()}
    pairs: List[OrderingPair] = []
    names = sorted(best)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            fast, slow = (a, b) if pred_ms[a] <= pred_ms[b] else (b, a)
            predicted_ratio = pred_ms[slow] / max(pred_ms[fast], 1e-12)
            measured_ratio = best[slow] / max(best[fast], 1e-12)
            within = max(measured_ratio, 1 / max(measured_ratio, 1e-12)) \
                <= 1 + tol
            pairs.append(OrderingPair(
                fast=fast, slow=slow, predicted_ratio=predicted_ratio,
                measured_ratio=measured_ratio, within_tolerance=within,
                agrees=measured_ratio >= 1.0,
            ))
    return OrderingReport(preset=params.name, measured_per_lane_ms=best,
                          predicted_per_lane_ms=pred_ms, pairs=tuple(pairs))
