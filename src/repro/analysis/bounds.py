"""Abstract interpretation of schedule programs: overflow proofs + depth.

The limb-scheme datapath (crypto/modmath.py) never forms a value that a
uint32 cannot hold — that is the invariant the Pallas kernel trusts
implicitly on every preset.  This module PROVES it statically, per
(preset, variant), by walking the schedule program and enumerating every
worst-case intermediate bound the datapath can reach:

  * the Modulus-level obligations (limb products, the shift-reduce
    constant, add/sub operands) come from
    :meth:`Modulus.mul_bound_sites` — enumerated from the same static
    constants ``mul``/``add`` trace with;
  * the per-op obligations (MRMC shift-add row accumulation with the
    preset's actual mix-matrix rows, Feistel/cube chains, affine constant
    adds, branch mixing, AGN signed folds) come from walking
    ``Schedule.op_table()`` and :meth:`Modulus.accumulate_sites`, which
    mirrors the EXACT interleaved-reduce policy `matvec_small` and the
    mrmc kernels' ``_combine`` execute;
  * every reduce site additionally proves the conditional-subtract chain
    fully reduces (worst-case residual <= q,
    :meth:`Modulus.reduce_residual_bound`) — a bound that fits uint32 but
    doesn't reduce is still a wrong answer.

Multiplicative depth is derived from the same walk (2 per Cube, 1 per
Feistel layer; linear ops free) and cross-checked against the
depth-tracked FV circuit's MEASURED depth (`core/transcipher.py`), so the
paper's HERA 10 / Rubato 2 / PASTA r+1 claims are pinned from two
independent directions.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core import schedule as S
from repro.core.params import CipherParams
from repro.core.schedule import Schedule


@dataclasses.dataclass(frozen=True)
class SiteCheck:
    """One discharged (or violated) proof obligation at a datapath site."""

    provenance: str   # op_table provenance or "modulus q=..."
    site: str         # BoundSite.site
    bound: int        # worst-case value reached
    limit: int        # envelope (2^32 for u32 fit; q for residuals)
    ok: bool
    margin_bits: float

    def render(self) -> str:
        mark = "ok " if self.ok else "FAIL"
        return (f"  [{mark}] {self.provenance} :: {self.site}: "
                f"bound {self.bound} <= {self.limit} "
                f"(margin {self.margin_bits:+.2f} bits)")


@dataclasses.dataclass(frozen=True)
class OverflowProof:
    """The full obligation list for one (preset, variant) program."""

    schedule: str
    q: int
    checks: Tuple[SiteCheck, ...]

    @property
    def proved(self) -> bool:
        return all(c.ok for c in self.checks)

    @property
    def min_margin_bits(self) -> float:
        return min(c.margin_bits for c in self.checks)

    @property
    def tightest(self) -> SiteCheck:
        return min(self.checks, key=lambda c: c.margin_bits)

    def failures(self) -> Tuple[SiteCheck, ...]:
        return tuple(c for c in self.checks if not c.ok)


def _wrap(provenance: str, sites) -> list:
    return [SiteCheck(provenance=provenance, site=s.site, bound=s.bound,
                      limit=s.limit, ok=s.ok, margin_bits=s.margin_bits)
            for s in sites]


def _site(mod, provenance: str, name: str, bound: int) -> list:
    """A u32-fit obligation plus its reduce-completeness obligation."""
    from repro.crypto.modmath import BoundSite

    return _wrap(provenance, (
        BoundSite(site=name, bound=bound, limit=2**32),
        BoundSite(site=name + " (residual)",
                  bound=mod.reduce_residual_bound(bound), limit=mod.q),
    ))


def _fit(provenance: str, name: str, bound: int) -> list:
    """A u32-fit-ONLY obligation: no reduce fires at this site (the value
    flows raw into a downstream accumulator that owns the reduce)."""
    from repro.crypto.modmath import BoundSite

    return _wrap(provenance, (BoundSite(site=name, bound=bound,
                                        limit=2**32),))


def prove_overflow_safety(params: CipherParams,
                          schedule: Optional[Schedule] = None,
                          variant: str = "normal",
                          reduction: str = "eager",
                          plan=None) -> OverflowProof:
    """Prove every intermediate of ``schedule`` fits uint32 and reduces.

    The walk visits each op once; MRMC obligations use the preset's actual
    mix matrix rows (deduplicated — the circulant family repeats rows), so
    the proof covers exactly the accumulation schedule
    ``mrmc_matrix_apply`` unrolls.  Orientation never changes bounds (a
    flip is a relabeling), so one proof covers what both orientations of
    an op compute — but the variant is still walked op-for-op so
    provenance matches the program that ships.

    ``reduction`` selects which reduction schedule (`core/redplan.py`) the
    proof discharges: "eager" replays the legacy everything-reduced
    datapath; "lazy" replays every deferral the shipped plan makes — the
    relaxed ARK sum, the lazy shift-add accumulators at their raw term
    bounds, the deferred dense products at 3q in narrowed chunks, and the
    folded branch-mix terminal reduce — one obligation per deferred site.
    An explicit ``plan`` overrides the mode (the can-fail path: an
    over-deferred plan yields *undischarged* obligations here, including
    the terminal-reduction-law sites, rather than an exception).
    """
    if schedule is None:
        schedule = params.schedule(variant)
    mod = params.mod
    q = mod.q
    if plan is None:
        from repro.core.redplan import plan_reductions

        plan = plan_reductions(params, schedule, reduction)
    from repro.core import redplan as RP

    checks: list = []

    # Modulus-level obligations: limb products, shift-reduce, add/sub.
    checks += _wrap(f"modulus q={q} (L={mod.L}, R={mod.R})",
                    mod.mul_bound_sites())

    mat = params.mix_matrix()
    rows = {tuple(int(c) for c in row) for row in mat}

    for i, info in enumerate(schedule.op_table()):
        op = info.op
        prov = info.provenance
        p = plan.ops[i] if i < len(plan.ops) else RP.OpPlan(i, q, q)
        in_b = p.in_bound
        if isinstance(op, S.ARK):
            if p.has(RP.DEFER_OUT):
                # x (< in_b) + (k (.) rc) (< q) stays RAW: fit-only — the
                # next op's lazy accumulator owns the reduce
                checks += _fit(prov, "ark: x + k*rc (deferred, raw out)",
                               in_b + q)
            else:
                # x + (k (.) rc): mul output < q, x < in_b
                checks += _site(mod, prov, "ark: x + k*rc operands",
                                in_b + q)
        elif isinstance(op, S.MRMC):
            if op.streams_matrix:
                # stream-sourced dense affine layer: one t-term dense
                # matvec row per output element, accumulated under the
                # chunked policy matvec_dense / mrmc_dense_apply execute
                t = info.in_width // schedule.branches
                if p.has(RP.LAZY_DENSE):
                    # relaxed limb multiply (state operand < in_b) with the
                    # per-product final reduce deferred: raw products < 3q
                    checks += _wrap(
                        prov + " [lazy-dense mul]",
                        mod.mul_bound_sites(x_bound=q, y_bound=in_b,
                                            reduce_out=False))
                    checks += _wrap(prov, mod.dense_accumulate_sites(
                        t, site=f"dense matvec t={t} (lazy)",
                        prod_bound=3 * q))
                else:
                    checks += _wrap(prov, mod.dense_accumulate_sites(
                        t, site=f"dense matvec t={t}"))
            else:
                # two shift-add matvec passes (MixColumns then MixRows)
                # per branch run the same row set; bounds are per-row
                lazy_a = p.has(RP.LAZY_ACCUMULATE)
                for row in sorted(rows):
                    if lazy_a:
                        # first pass accepts operands < in_b; its rows are
                        # terminally reduced, so the second pass relaxes
                        # from q — both replayed at their true bounds
                        checks += _wrap(prov, mod.accumulate_sites(
                            row, site=f"mrmc row {list(row)} (lazy cols)",
                            in_bound=in_b, lazy=True))
                        checks += _wrap(prov, mod.accumulate_sites(
                            row, site=f"mrmc row {list(row)} (lazy rows)",
                            lazy=True))
                    else:
                        checks += _wrap(prov, mod.accumulate_sites(
                            row, site=f"mrmc row {list(row)}"))
            fold = p.has(RP.FOLD_MIX)
            mix_in = 2 * q if op.has_rc else q
            if op.has_rc:
                if fold:
                    checks += _fit(prov,
                                   "affine: matrix_out + rc (deferred, raw)",
                                   2 * q)
                else:
                    checks += _site(mod, prov, "affine: matrix_out + rc",
                                    2 * q)
            if op.mix_branches:
                if fold:
                    checks += _fit(prov, "branch mix: s = L + R (raw)",
                                   2 * mix_in)
                    checks += _site(mod, prov,
                                    "branch mix: s + L (and s + R), "
                                    "one terminal reduce", 3 * mix_in)
                else:
                    checks += _site(mod, prov, "branch mix: s = L + R",
                                    2 * q)
                    checks += _site(mod, prov,
                                    "branch mix: s + L (and s + R)", 2 * q)
        elif isinstance(op, S.NONLINEAR):
            if op.kind == "cube":
                # x^3 = mul(mul(x, x), x): both muls take [0, q) operands,
                # so the modulus-level mul obligations cover them; record
                # the chaining fact explicitly.
                checks += _site(mod, prov,
                                "cube: mul(mul(x,x),x) final sum", 3 * q)
            else:
                checks += _site(mod, prov, "feistel: x + shift(x^2)",
                                in_b + q)
        elif isinstance(op, S.AGN):
            # signed noise e with |e| < q folded to [0, 2q) then reduced,
            # then added to the state
            checks += _site(mod, prov, "agn: signed fold e + q", 2 * q)
            checks += _site(mod, prov, "agn: x + e_folded", in_b + q)
    # Terminal-reduction law (lint rule SA111): state must be fully
    # reduced before every TRUNCATE/AGN input and at program end.  Under
    # the shipped plans these discharge trivially; an over-deferred custom
    # plan surfaces here as an UNDISCHARGED obligation.
    from repro.crypto.modmath import BoundSite

    for idx, what, bound in plan.terminal_sites(schedule):
        where = f"ops[{idx}]" if idx is not None else "program end"
        checks += _wrap(
            f"terminal-reduction law (SA111) [{plan.mode}]",
            (BoundSite(site=f"{where}: {what} fully reduced", bound=bound,
                       limit=q),))
    return OverflowProof(schedule=schedule.name, q=q, checks=tuple(checks))


# ==========================================================================
# Multiplicative depth
# ==========================================================================
#: paper depth laws per cipher kind, as a function of rounds r
PAPER_DEPTH = {
    "hera": lambda r: 2 * r,        # Cube = depth 2 per round (10 @ r=5)
    "rubato": lambda r: r,          # Feistel = depth 1 per round (2 @ r=2)
    "pasta": lambda r: r + 1,       # (r-1) Feistel + final Cube
}


def static_depth(schedule: Schedule) -> int:
    """Multiplicative depth derived by walking the program: ct x ct
    multiplies happen only in the nonlinear layers (ARK's k*rc is
    plaintext-by-ciphertext in the FV accounting; the linear layers are
    depth-free), and the state flows through every layer sequentially —
    so depth is simply the sum of per-layer depths."""
    depth = 0
    for op in schedule.ops:
        if isinstance(op, S.NONLINEAR):
            depth += 2 if op.kind == "cube" else 1
    return depth


@dataclasses.dataclass(frozen=True)
class DepthReport:
    """Static vs paper-law vs measured multiplicative depth."""

    schedule: str
    static: int
    paper: int
    measured: Optional[int]    # None = measurement skipped

    @property
    def ok(self) -> bool:
        if self.static != self.paper:
            return False
        return self.measured is None or self.measured == self.static

    def render(self) -> str:
        m = "-" if self.measured is None else str(self.measured)
        mark = "ok" if self.ok else "MISMATCH"
        return (f"depth {self.schedule}: static={self.static} "
                f"paper={self.paper} measured={m} [{mark}]")


def depth_report(params: CipherParams, variant: str = "normal",
                 measure: bool = True) -> DepthReport:
    """Derive the static depth and cross-check it both against the paper
    law for the cipher kind and (unless ``measure=False``) against the
    depth the FV circuit actually accumulates on one block."""
    sched = params.schedule(variant)
    static = static_depth(sched)
    paper = PAPER_DEPTH[params.kind](params.rounds)
    measured = None
    if measure:
        from repro.core.transcipher import measured_depth

        measured = measured_depth(params)
    return DepthReport(schedule=sched.name, static=static, paper=paper,
                       measured=measured)
