#!/usr/bin/env python
"""Fallback import-hygiene linter for hosts without ruff.

The `lint` CI stage (scripts/ci.sh) prefers ruff with the checked-in
ruff.toml; this script is the degraded-but-hermetic path for the
accelerator image, which ships no linter and must not pip-install one.
It enforces the highest-value subset with matching semantics:

  * files must parse (syntax errors fail the stage);
  * every imported name must be used (ruff F401), where "used" means it
    appears as a load name anywhere in the module, in ``__all__``, or the
    import line carries ``# noqa`` (bare or listing F401);
  * ``__init__.py`` files are exempt (re-exports are the API surface);
  * duplicate imports of the same binding in the same scope (ruff F811's
    import case).

Usage: python scripts/astlint.py DIR [DIR ...]
Exits 1 if any finding, printing ruff-style ``path:line: code message``.
"""

from __future__ import annotations

import ast
import pathlib
import re
import sys

NOQA = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z0-9, ]+))?", re.I)


def _noqa_lines(source: str, code: str) -> set:
    """Physical lines (1-based) suppressed for ``code`` (or blanket noqa)."""
    out = set()
    for i, line in enumerate(source.splitlines(), 1):
        m = NOQA.search(line)
        if not m:
            continue
        codes = m.group("codes")
        if codes is None or code in codes.upper().replace(" ", "").split(","):
            out.add(i)
    return out


def _names_in_string_annotation(value: str) -> set:
    try:
        expr = ast.parse(value, mode="eval")
    except SyntaxError:
        return set()
    return {n.id for n in ast.walk(expr) if isinstance(n, ast.Name)}


def _used_names(tree: ast.AST) -> set:
    used = set()
    annotations = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.AnnAssign):
            annotations.append(node.annotation)
        elif isinstance(node, ast.arg) and node.annotation is not None:
            annotations.append(node.annotation)
        elif (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.returns is not None):
            annotations.append(node.returns)
        elif (isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "__all__"
                for t in node.targets)):
            for elt in ast.walk(node.value):
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    used.add(elt.value)
    # quoted annotations ("CipherParams", Optional["Schedule"]) are uses
    for ann in annotations:
        for sub in ast.walk(ann):
            if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                used |= _names_in_string_annotation(sub.value)
    return used


def _imports_with_scope(tree: ast.AST):
    """Yield (scope_path, import_node) — scope-aware so a function-local
    import never collides with another function's (ruff F811 semantics)."""
    def walk(node, scope):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.Import, ast.ImportFrom)):
                yield scope, child
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.ClassDef)):
                yield from walk(child, scope + (child.name,))
            else:
                yield from walk(child, scope)
    yield from walk(tree, ())


def lint_file(path: pathlib.Path) -> list:
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [(e.lineno or 0, "E999", f"syntax error: {e.msg}")]
    findings = []
    if path.name == "__init__.py":
        return findings
    suppressed = _noqa_lines(source, "F401")
    dup_suppressed = _noqa_lines(source, "F811")
    used = _used_names(tree)
    seen: dict = {}
    for scope, node in sorted(_imports_with_scope(tree),
                              key=lambda sn: sn[1].lineno):
        if isinstance(node, ast.ImportFrom) and node.module == "__future__":
            continue
        for alias in node.names:
            if alias.name == "*":
                continue
            binding = alias.asname or alias.name.split(".")[0]
            prev = seen.get((scope, binding))
            if (prev is not None and prev != node.lineno
                    and node.lineno not in dup_suppressed):
                findings.append(
                    (node.lineno, "F811",
                     f"redefinition of unused import {binding!r} "
                     f"(first at line {prev})"))
            seen.setdefault((scope, binding), node.lineno)
            if binding not in used and node.lineno not in suppressed:
                shown = alias.name + (f" as {alias.asname}"
                                      if alias.asname else "")
                findings.append(
                    (node.lineno, "F401", f"{shown!r} imported but unused"))
    return findings


def main(argv) -> int:
    roots = [pathlib.Path(a) for a in (argv or ["src"])]
    files = sorted(f for root in roots for f in root.rglob("*.py"))
    n = 0
    for f in files:
        for line, code, msg in lint_file(f):
            print(f"{f}:{line}: {code} {msg}")
            n += 1
    print(f"astlint: {len(files)} files, {n} finding(s)")
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
