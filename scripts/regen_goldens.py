#!/usr/bin/env python
"""Regenerate the checked-in golden keystream digests in tests/test_schedule.py.

The golden vectors pin the cipher definitions themselves (every preset in
`repro.core.params.REGISTRY` × noise on/off, SHA-256 of the little-endian
uint32 keystream bytes for make_cipher(name, seed=123) over block counters
0..3).  This script is the ONE legitimate way to touch them:

    PYTHONPATH=src python scripts/regen_goldens.py            # print table
    PYTHONPATH=src python scripts/regen_goldens.py --check    # CI gate
    PYTHONPATH=src python scripts/regen_goldens.py --write    # rewrite block

``--check`` exits non-zero if regeneration would change ANY digest (or a
preset is missing an entry) — the ci.sh ``golden-regen`` stage, so a
schedule/executor/params drift that would silently re-pin the ciphers
fails CI instead.  ``--write`` rewrites the marked GOLDEN block in
tests/test_schedule.py in place; only do that when a cipher definition
deliberately changes (e.g. a new preset lands), never to "fix" a refactor.

Digest recipe is deliberately identical to tests/test_schedule.py's
`test_golden_keystream_digest`: the reference executor (`keystream_ref`,
normal variant) is the oracle, and the alternating-variant / kernel /
engine matrices all chain to it.
"""

from __future__ import annotations

import argparse
import hashlib
import pathlib
import re
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

TEST_FILE = pathlib.Path(__file__).parent.parent / "tests" / "test_schedule.py"
BEGIN = "# --- GOLDEN-BEGIN (scripts/regen_goldens.py) ---"
END = "# --- GOLDEN-END ---"
SEED, LANES = 123, 4   # must match tests/test_schedule.py


def compute_goldens() -> dict:
    """(preset, "plain"|"noise") -> sha256 hex digest, for every preset."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import make_cipher
    from repro.core.params import REGISTRY
    from repro.kernels.keystream.ref import keystream_ref

    out = {}
    for name, p in REGISTRY.items():
        ci = make_cipher(name, seed=SEED)
        consts = ci.round_constant_stream(jnp.arange(LANES, dtype=jnp.uint32))
        modes = [("plain", None)]
        if p.n_noise:
            modes.append(("noise", consts["noise"]))
        for mode, noise in modes:
            z = keystream_ref(p, ci.key, consts["rc"], noise,
                              mats=consts.get("mats"))
            out[(name, mode)] = hashlib.sha256(
                np.array(z).astype("<u4").tobytes()).hexdigest()
    return out


def render_block(goldens: dict) -> str:
    """The GOLDEN block body, byte-exact with what the test file carries."""
    lines = [BEGIN, "GOLDEN = {"]
    for (name, mode), digest in goldens.items():   # REGISTRY order
        lines.append(f'    ("{name}", "{mode}"): "{digest}",')
    lines += ["}", END]
    return "\n".join(lines)


def current_block(text: str) -> str:
    m = re.search(re.escape(BEGIN) + r".*?" + re.escape(END), text, re.S)
    if not m:
        raise SystemExit(
            f"no GOLDEN markers in {TEST_FILE} — expected a block between "
            f"{BEGIN!r} and {END!r}")
    return m.group(0)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--check", action="store_true",
                      help="fail (exit 1) if regeneration would change any "
                           "checked-in digest")
    mode.add_argument("--write", action="store_true",
                      help="rewrite the GOLDEN block in place")
    args = ap.parse_args(argv)

    goldens = compute_goldens()
    fresh = render_block(goldens)
    text = TEST_FILE.read_text()
    checked_in = current_block(text)

    if checked_in == fresh:
        print(f"golden digests reproduce byte-for-byte "
              f"({len(goldens)} entries, {TEST_FILE.name} unchanged)")
        return 0

    if args.write:
        TEST_FILE.write_text(text.replace(checked_in, fresh))
        print(f"rewrote GOLDEN block in {TEST_FILE} ({len(goldens)} entries)")
        return 0

    print("golden digest drift — regeneration would CHANGE the checked-in "
          "block:\n")
    print("--- checked in ---")
    print(checked_in)
    print("--- regenerated ---")
    print(fresh)
    if args.check:
        print("\nFAIL: the cipher definitions no longer reproduce the "
              "checked-in goldens.  If the change is deliberate, run "
              "scripts/regen_goldens.py --write and say so in the commit.")
        return 1
    print("\n(run with --write to accept, --check to gate)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
