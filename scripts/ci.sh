#!/usr/bin/env bash
# Tier-1 verify entry point (ROADMAP.md): drift smokes first (engine
# matrix, schedule golden vectors, engine+producer availability, tuner
# persist/reload, farm-bench canaries), then the fast lap, then the slow
# interpret-mode Pallas sweeps.  One command:
#
#   scripts/ci.sh          # smoke + fast lap + slow lap (full tier-1)
#   scripts/ci.sh --fast   # smoke + fast lap (developer inner loop)
#
# The smoke stage fails fast on backend drift: the engine bit-exactness
# matrix (every registered KeystreamEngine vs the reference, both ciphers,
# all presets) plus a tiny end-to-end keystream_farm_bench lap that keeps
# every default engine dispatching through the double-buffered farm.  The
# fast lap excludes tests marked `slow` (full-lane interpret-mode kernel
# sweeps, see tests/conftest.py); everything else — including the farm
# bit-exactness cross-checks — runs there.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== smoke: engine matrix (both schedule variants) ==="
python -m pytest -x -q tests/test_engine.py

echo "=== schedule drift: golden vectors + orientation property ==="
python -m pytest -x -q tests/test_schedule.py

echo "=== schedule drift: engine availability must not regress ==="
python - <<'PYEOF'
from repro.core.engine import engine_caps
caps = engine_caps()
must = {"ref", "jax", "pallas-interpret"}          # portable on every host
missing = sorted(n for n in must if not caps[n].available)
assert not missing, f"engine availability regressed: {missing}"
for name, c in caps.items():
    assert c.available or c.reason, f"{name} unavailable without a reason"
    assert set(c.schedule_variants) >= {"normal", "alternating"}, name
print("engine x variant availability ok:",
      {n: c.available for n, c in caps.items()})
PYEOF

echo "=== producer drift: producer availability must not regress ==="
python - <<'PYEOF'
from repro.core.params import get_params
from repro.core.producer import (compatible_producers, producer_caps,
                                 registered_producers)
caps = producer_caps()
must = {"aes", "threefry", "cached"}               # portable on every host
missing = sorted(n for n in must if n not in caps or not caps[n].available)
assert not missing, f"producer availability regressed: {missing}"
for name, c in caps.items():
    assert c.available or c.reason, f"{name} unavailable without a reason"
# every preset keeps >= 2 stream-preserving (interchangeable) producers
for preset in ("hera-128a", "rubato-128l"):
    comp = compatible_producers(get_params(preset))
    assert len(comp) >= 2, f"{preset}: stream-preserving set shrank: {comp}"
print("producer availability ok:", sorted(registered_producers()))
PYEOF

echo "=== tuner smoke: measured StreamPlan persists + reloads deterministically ==="
TUNER_CACHE="$(mktemp -d)/streamplans.json"
REPRO_TUNER_CACHE="$TUNER_CACHE" python - <<'PYEOF'
from repro.core.tuner import StreamPlan, autotune, default_cache_path, load_plan

# tiny measured lap: producers x depths on the jax engine, 8-lane windows
plan = autotune("rubato-128s", 8, sessions=2, n_windows=2, reps=1,
                engines=["jax"], variants=["normal"], windows=[8],
                depths=[2, 3], verbose=True)
assert isinstance(plan, StreamPlan), plan
assert default_cache_path().exists(), "plan was not persisted"
# JSON round trip is bit-identical
assert StreamPlan.from_json(plan.to_json()) == plan
# a second autotune must be a deterministic cache hit (no re-timing)
again = autotune("rubato-128s", 8, sessions=2, n_windows=2, reps=1)
assert again == plan, (again, plan)
# and the cache-only lookup "auto" resolution consults agrees
loaded = load_plan("rubato-128s", 8)
assert loaded == plan, (loaded, plan)
# "auto" resolution consults the persisted plan
from repro.core.engine import resolve_engine
from repro.core.params import get_params
assert resolve_engine("auto", params=get_params("rubato-128s")) == plan.engine
print("tuner smoke ok:", plan.describe())
PYEOF
rm -rf "$(dirname "$TUNER_CACHE")"

echo "=== smoke: keystream farm bench (tiny, no gating; both variants) ==="
python benchmarks/keystream_farm_bench.py --smoke --schedule normal
python benchmarks/keystream_farm_bench.py --smoke --schedule alternating
echo "=== smoke: farm bench producer/depth sweep (cached producer, depth 3) ==="
python benchmarks/keystream_farm_bench.py --smoke --producer aes cached --depth 2 3

echo "=== fast lap (-m 'not slow'; engine/schedule suites already ran) ==="
python -m pytest -x -q -m "not slow" --ignore=tests/test_engine.py \
  --ignore=tests/test_schedule.py

if [[ "${1:-}" == "--fast" ]]; then
  echo "=== fast mode (--fast); skipping slow lap ==="
  exit 0
fi

echo "=== slow lap (-m slow) ==="
python -m pytest -x -q -m slow
