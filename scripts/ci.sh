#!/usr/bin/env bash
# Tier-1 verify entry point (ROADMAP.md): engine-drift smoke first, then
# the fast lap, then the slow interpret-mode Pallas sweeps.  One command,
# three stages:
#
#   scripts/ci.sh          # smoke + fast lap + slow lap (full tier-1)
#   scripts/ci.sh --fast   # smoke + fast lap (developer inner loop)
#
# The smoke stage fails fast on backend drift: the engine bit-exactness
# matrix (every registered KeystreamEngine vs the reference, both ciphers,
# all presets) plus a tiny end-to-end keystream_farm_bench lap that keeps
# every default engine dispatching through the double-buffered farm.  The
# fast lap excludes tests marked `slow` (full-lane interpret-mode kernel
# sweeps, see tests/conftest.py); everything else — including the farm
# bit-exactness cross-checks — runs there.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== smoke: engine matrix ==="
python -m pytest -x -q tests/test_engine.py

echo "=== smoke: keystream farm bench (tiny, no gating) ==="
python benchmarks/keystream_farm_bench.py --smoke

echo "=== fast lap (-m 'not slow'; engine matrix already ran in smoke) ==="
python -m pytest -x -q -m "not slow" --ignore=tests/test_engine.py

if [[ "${1:-}" == "--fast" ]]; then
  echo "=== fast mode (--fast); skipping slow lap ==="
  exit 0
fi

echo "=== slow lap (-m slow) ==="
python -m pytest -x -q -m slow
