#!/usr/bin/env bash
# Tier-1 verify entry point (ROADMAP.md): engine-drift smoke first, then
# the fast lap, then the slow interpret-mode Pallas sweeps.  One command,
# three stages:
#
#   scripts/ci.sh          # smoke + fast lap + slow lap (full tier-1)
#   scripts/ci.sh --fast   # smoke + fast lap (developer inner loop)
#
# The smoke stage fails fast on backend drift: the engine bit-exactness
# matrix (every registered KeystreamEngine vs the reference, both ciphers,
# all presets) plus a tiny end-to-end keystream_farm_bench lap that keeps
# every default engine dispatching through the double-buffered farm.  The
# fast lap excludes tests marked `slow` (full-lane interpret-mode kernel
# sweeps, see tests/conftest.py); everything else — including the farm
# bit-exactness cross-checks — runs there.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== smoke: engine matrix (both schedule variants) ==="
python -m pytest -x -q tests/test_engine.py

echo "=== schedule drift: golden vectors + orientation property ==="
python -m pytest -x -q tests/test_schedule.py

echo "=== schedule drift: engine availability must not regress ==="
python - <<'PYEOF'
from repro.core.engine import engine_caps
caps = engine_caps()
must = {"ref", "jax", "pallas-interpret"}          # portable on every host
missing = sorted(n for n in must if not caps[n].available)
assert not missing, f"engine availability regressed: {missing}"
for name, c in caps.items():
    assert c.available or c.reason, f"{name} unavailable without a reason"
    assert set(c.schedule_variants) >= {"normal", "alternating"}, name
print("engine x variant availability ok:",
      {n: c.available for n, c in caps.items()})
PYEOF

echo "=== smoke: keystream farm bench (tiny, no gating; both variants) ==="
python benchmarks/keystream_farm_bench.py --smoke --schedule normal
python benchmarks/keystream_farm_bench.py --smoke --schedule alternating

echo "=== fast lap (-m 'not slow'; engine/schedule suites already ran) ==="
python -m pytest -x -q -m "not slow" --ignore=tests/test_engine.py \
  --ignore=tests/test_schedule.py

if [[ "${1:-}" == "--fast" ]]; then
  echo "=== fast mode (--fast); skipping slow lap ==="
  exit 0
fi

echo "=== slow lap (-m slow) ==="
python -m pytest -x -q -m slow
