#!/usr/bin/env bash
# Tier-1 verify entry point (ROADMAP.md), as a STAGED pipeline: every drift
# guard is a named, individually-runnable stage with its own timing, and a
# summary table prints at the end (docs/DESIGN.md §12 describes what each
# stage guards).
#
#   scripts/ci.sh                         # every stage (full tier-1)
#   scripts/ci.sh --fast                  # all but the nightly-only stages
#   scripts/ci.sh --strict                # bench/analyze timing drift errors
#   scripts/ci.sh --list                  # enumerate stages
#   scripts/ci.sh --list-names [--fast]   # machine-readable stage list (the
#                                         # GitHub workflow derives its fast
#                                         # matrix from this — never hand-list)
#   scripts/ci.sh --stage schedule-drift  # one stage in isolation
#   scripts/ci.sh --stage tuner-smoke --stage bench-smoke   # several
#
# With CI_SUMMARY_FILE set, the per-stage timing summary is also written
# there (the nightly workflow uploads it as an artifact).
#
# Preset lists inside the availability guards are DERIVED from
# core/params.py's REGISTRY — a new cipher preset (e.g. PASTA) is covered
# automatically, never hand-listed here.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# --------------------------------------------------------------------------
# Stage registry: name|what it guards
# --------------------------------------------------------------------------
STAGES=(
  "engine-matrix|engine x preset x noise x variant bit-exactness (tests/test_engine.py)"
  "schedule-drift|golden keystream vectors + orientation property (tests/test_schedule.py)"
  "golden-regen|regen_goldens.py --check: regeneration reproduces checked-in digests"
  "reduction-plan|lazy==eager parity + terminal-reduction law + plan shape (tests/test_redplan.py)"
  "engine-availability|registered engines stay available, with reasons, on every preset"
  "producer-availability|registered producers + stream-preserving sets per preset"
  "tuner-smoke|StreamPlan measure -> persist -> deterministic reload -> auto consult"
  "workflow-lint|.github/workflows/ci.yml parses (the workflow that runs this script)"
  "lint|ruff (or scripts/astlint.py fallback) over src scripts benchmarks tests"
  "analyze|schedule-IR static analysis matrix + snapshot drift (repro.analysis)"
  "bench-smoke|keystream farm bench canary: both variants + producer/depth sweep"
  "bench-gate|farm trajectory snapshot: p50/p99 regression + matrix-prefetch overlap"
  "serve-smoke|async serving plane on loopback: 8 concurrent clients x 2 tenants, live rotation, exact recovery"
  "serve-gate|serve trajectory snapshot: req/s + p50/p99 drift vs BENCH_serve_trajectory.json"
  "fast-lap|pytest -m 'not slow' (everything else; engine/schedule suites above)"
  "slow-lap|pytest -m slow: full-lane interpret-mode Pallas sweeps"
)

# stages the --fast lap skips (nightly/full laps run them): the interpret
# sweep and the serve load-replay gate are the two multi-minute stages
FAST_EXCLUDE=("slow-lap" "serve-gate")

fast_excluded() {
  local e
  for e in "${FAST_EXCLUDE[@]}"; do [[ "$e" == "$1" ]] && return 0; done
  return 1
}

stage_names() { local s; for s in "${STAGES[@]}"; do echo "${s%%|*}"; done; }

list_stages() {
  echo "stages (run one with --stage <name>; * = skipped by --fast):"
  local s name mark
  for s in "${STAGES[@]}"; do
    name="${s%%|*}"
    mark=" "
    fast_excluded "$name" && mark="*"
    printf " %s %-22s %s\n" "$mark" "$name" "${s#*|}"
  done
}

list_stage_names() {
  # machine-readable: one stage name per line, honoring --fast — the
  # GitHub workflow's matrix derives from this (workflow-lint checks it)
  local name
  while IFS= read -r name; do
    [[ $FAST -eq 1 ]] && fast_excluded "$name" && continue
    echo "$name"
  done < <(stage_names)
}

# --------------------------------------------------------------------------
# Stage bodies
# --------------------------------------------------------------------------
stage_engine_matrix() {
  python -m pytest -x -q tests/test_engine.py
}

stage_schedule_drift() {
  python -m pytest -x -q tests/test_schedule.py
}

stage_golden_regen() {
  python scripts/regen_goldens.py --check
  # stream-identity pin: the matrix-plane payload rides AFTER the rc+noise
  # words in the per-(nonce, ctr) XOF stream, so re-pinning PASTA (real
  # streamed matrices) must never have moved a HERA/Rubato digest — these
  # are the pre-matrix-plane values, byte-identical by construction
  python - <<'PYEOF'
import sys
sys.path.insert(0, "scripts")
from regen_goldens import compute_goldens
PINNED = {
    ("hera-128a", "plain"):
        "894abb58f75f5306e40200bc670d9e4672dd5e345d1f0ad97545c22f1b1132b2",
    ("rubato-128s", "plain"):
        "9c46b0244571ba344f043498875dea5576c0a6775e39676294191a7e0adf315f",
    ("rubato-128s", "noise"):
        "e5d632a451be7b27918ac669ef8bf177fd814b779658d28550e396eedc97ee75",
    ("rubato-128m", "plain"):
        "28a0da4bdad86ca4d35079d7997441efc183508227ff3be81cd271c950b86d8b",
    ("rubato-128m", "noise"):
        "37acf76c4ab8438e866e6ee38f69c32170fb09462d6012991e3787953921b9ee",
    ("rubato-128l", "plain"):
        "286453548ffff0abc2231c2603cd895410bab849f334f58b6eff6276d74a5471",
    ("rubato-128l", "noise"):
        "f89adf017a718905d2e7c40eaac8aebb014111ecba24975b52b75ac7cfca2099",
}
got = compute_goldens()
drifted = {k: got[k] for k in PINNED if got.get(k) != PINNED[k]}
assert not drifted, (
    f"HERA/Rubato digests moved — the matrix-plane stream is no longer "
    f"drawn after the vector constants: {sorted(drifted)}")
print(f"HERA/Rubato goldens byte-identical across the matrix-plane "
      f"change ({len(PINNED)} digests)")
PYEOF
}

stage_reduction_plan() {
  # the reduction-scheduling pass's own gate (docs/DESIGN.md §14): plan
  # derivation shape, lazy == eager bit-exactness across presets x
  # variants x noise x engines, the two-sided terminal-reduction-law
  # can-fail fixtures (SA111), and the relaxed modmath primitives; the
  # lazy-plan overflow proof itself is discharged by the analyze stage
  python -m pytest -x -q -m "not slow" tests/test_redplan.py
}

stage_engine_availability() {
  python - <<'PYEOF'
from repro.core.engine import engine_caps
from repro.core.params import REGISTRY
caps = engine_caps()
must = {"ref", "jax", "pallas-interpret"}          # portable on every host
missing = sorted(n for n in must if not caps[n].available)
assert not missing, f"engine availability regressed: {missing}"
for name, c in caps.items():
    assert c.available or c.reason, f"{name} unavailable without a reason"
    assert set(c.schedule_variants) >= {"normal", "alternating"}, name
# every registered preset (derived, never hand-listed) binds every portable
# engine — a new cipher that breaks an engine fails here, not in serving
from repro.core import make_cipher, make_engine
for preset in sorted(REGISTRY):
    ci = make_cipher(preset, seed=0)
    for eng in sorted(must):
        make_engine(eng, ci.params, ci.key, variant="auto")
print("engine x variant availability ok:",
      {n: c.available for n, c in caps.items()},
      "on presets", sorted(REGISTRY))
PYEOF
}

stage_producer_availability() {
  python - <<'PYEOF'
from repro.core.params import REGISTRY, get_params
from repro.core.producer import (compatible_producers, producer_caps,
                                 registered_producers)
caps = producer_caps()
must = {"aes", "threefry", "cached"}               # portable on every host
missing = sorted(n for n in must if n not in caps or not caps[n].available)
assert not missing, f"producer availability regressed: {missing}"
for name, c in caps.items():
    assert c.available or c.reason, f"{name} unavailable without a reason"
# every preset keeps >= 2 stream-preserving (interchangeable) producers —
# the preset list is DERIVED from core/params.py (new ciphers auto-covered)
for preset in sorted(REGISTRY):
    comp = compatible_producers(get_params(preset))
    assert len(comp) >= 2, f"{preset}: stream-preserving set shrank: {comp}"
print("producer availability ok:", sorted(registered_producers()),
      "on presets", sorted(REGISTRY))
PYEOF
}

stage_tuner_smoke() {
  local tuner_cache
  tuner_cache="$(mktemp -d)/streamplans.json"
  REPRO_TUNER_CACHE="$tuner_cache" python - <<'PYEOF'
from repro.core.tuner import (PLAN_SCHEMA, StreamPlan, autotune,
                              default_cache_path, load_plan)

# tiny measured lap: producers x depths on the jax engine, 8-lane windows
plan = autotune("rubato-128s", 8, sessions=2, n_windows=2, reps=1,
                engines=["jax"], variants=["normal"], windows=[8],
                depths=[2, 3], verbose=True)
assert isinstance(plan, StreamPlan), plan
assert default_cache_path().exists(), "plan was not persisted"
# JSON round trip is bit-identical
assert StreamPlan.from_json(plan.to_json()) == plan
# a second autotune must be a deterministic cache hit (no re-timing)
again = autotune("rubato-128s", 8, sessions=2, n_windows=2, reps=1)
assert again == plan, (again, plan)
# and the cache-only lookup "auto" resolution consults agrees
loaded = load_plan("rubato-128s", 8)
assert loaded == plan, (loaded, plan)
# "auto" resolution consults the persisted plan
from repro.core.engine import resolve_engine
from repro.core.params import get_params
assert resolve_engine("auto", params=get_params("rubato-128s")) == plan.engine
# stale-schema entries are invalidated, not trusted
import json
path = default_cache_path()
data = json.loads(path.read_text())
for entry in data["plans"].values():
    entry["schema"] = PLAN_SCHEMA - 1
path.write_text(json.dumps(data))
assert load_plan("rubato-128s", 8) is None, "stale schema plan was trusted"
print("tuner smoke ok:", plan.describe())
PYEOF
  rm -rf "$(dirname "$tuner_cache")"
}

stage_workflow_lint() {
  python - <<'PYEOF'
import pathlib, re, subprocess, sys
path = pathlib.Path(".github/workflows/ci.yml")
assert path.exists(), f"{path} missing"
text = path.read_text()
try:
    import yaml
except ImportError:   # offline image without pyyaml: structural fallback
    for needle in ("jobs:", "runs-on:", "scripts/ci.sh",
                   "--list-names --fast", "cancel-in-progress: true",
                   "benchmarks/BENCH_*.json"):
        assert needle in text, f"workflow missing {needle!r}"
    print("workflow ok (structural check; pyyaml unavailable)")
    sys.exit(0)
doc = yaml.safe_load(text)
assert isinstance(doc, dict) and "jobs" in doc, "workflow has no jobs"
# 'on:' parses to the boolean True key in YAML 1.1
trig = doc.get("on", doc.get(True))
assert trig, "workflow has no triggers"
jobs = doc["jobs"]
assert any("ci.sh" in str(j) for j in jobs.values()), \
    "no job invokes scripts/ci.sh"
# concurrency hygiene: one live run per ref, stale runs ALWAYS cancelled
conc = doc.get("concurrency") or {}
assert "github.ref" in str(conc.get("group", "")), \
    "concurrency group must be per-ref"
assert conc.get("cancel-in-progress") is True, \
    "concurrency.cancel-in-progress must be unconditionally true"
# the fast lap's stage list must be DERIVED from ci.sh, never hand-listed:
# a job lists stages via --list-names --fast, and the matrix job consumes
# that output through fromJSON — hardcoded stage arrays are the drift bug
# this lint exists to catch
derive_jobs = [n for n, j in jobs.items()
               if "--list-names --fast" in str(j)]
assert derive_jobs, "no job derives the stage list via " \
    "'scripts/ci.sh --list-names --fast'"
matrix_jobs = [n for n, j in jobs.items()
               if (j.get("strategy") or {}).get("matrix")]
assert matrix_jobs, "no matrix job runs the fast-lap stages"
for n in matrix_jobs:
    m = jobs[n]["strategy"]["matrix"]
    assert isinstance(m.get("stage"), str) and "fromJSON" in m["stage"], \
        f"job {n!r}: matrix.stage must be fromJSON(<derive job output>), " \
        f"not a hardcoded list: {m.get('stage')!r}"
# the derived list agrees with what ci.sh actually declares right now
listed = subprocess.run(
    ["bash", "scripts/ci.sh", "--list-names", "--fast"],
    capture_output=True, text=True, check=True).stdout.split()
assert listed, "--list-names --fast returned no stages"
declared = subprocess.run(
    ["bash", "scripts/ci.sh", "--list-names"],
    capture_output=True, text=True, check=True).stdout.split()
assert set(listed) < set(declared), \
    "fast list must be a strict subset of all stages (nightly-only " \
    "stages exist)"
assert "serve-smoke" in listed, "serve-smoke must ride the fast lap"
assert "serve-gate" in set(declared) - set(listed), \
    "serve-gate must be nightly-only"
# nightly artifacts: bench snapshots + the per-stage timing summary
sched_jobs = [j for j in jobs.values()
              if "schedule" in str(j.get("if", ""))
              and "!=" not in str(j.get("if", ""))]
assert sched_jobs, "no nightly (schedule-gated) job"
arts = [s for j in sched_jobs for s in j.get("steps", [])
        if "upload-artifact" in str(s.get("uses", ""))]
assert arts, "nightly job uploads no artifacts"
paths = " ".join(str(s.get("with", {}).get("path", "")) for s in arts)
assert "benchmarks/BENCH_" in paths, \
    "nightly artifacts must include benchmarks/BENCH_*.json"
assert re.search(r"summary", paths), \
    "nightly artifacts must include the stage timing summary"
assert any("CI_SUMMARY_FILE" in str(j) for j in sched_jobs), \
    "nightly job must set CI_SUMMARY_FILE for the timing summary"
print(f"workflow ok: jobs={sorted(jobs)} triggers={sorted(trig)}; "
      f"fast matrix derived from --list-names ({len(listed)} stages), "
      f"nightly uploads bench snapshots + summary")
PYEOF
}

stage_lint() {
  # ruff when the host has it (GitHub CI installs it; ruff.toml is the
  # config); the hermetic accelerator image has no linter and must not
  # pip-install one, so fall back to the AST-based F401/F811/E999 subset
  if command -v ruff >/dev/null 2>&1; then
    ruff check src scripts benchmarks tests
  else
    echo "ruff not on PATH; running scripts/astlint.py fallback"
    python scripts/astlint.py src scripts benchmarks tests
  fi
}

stage_analyze() {
  # full preset x variant matrix: lint errors, unproven overflow bounds
  # (eager AND lazy-plan obligations), and static/paper/measured depth
  # mismatches all fail; the checked-in snapshot gates analytic drift
  # (measured-timing drift only warns — unless --strict, the nightly
  # mode — so a clean checkout with an empty plan cache still passes)
  python -m repro.analysis --all --check "${STRICT_ARGS[@]}"
}

stage_bench_smoke() {
  echo "--- farm bench smoke: schedule variants (all cipher kinds) ---"
  python benchmarks/keystream_farm_bench.py --smoke --schedule normal
  python benchmarks/keystream_farm_bench.py --smoke --schedule alternating
  echo "--- farm bench smoke: producer/depth sweep (cached producer, depth 3) ---"
  python benchmarks/keystream_farm_bench.py --smoke --producer aes cached --depth 2 3
}

stage_bench_gate() {
  # fresh trajectory lap vs benchmarks/BENCH_farm_trajectory.json: entry
  # set (preset x engine x producer x matrix_depth) must match exactly;
  # >20% p50/p99 regressions are flagged (warnings by default — timings
  # are host-dependent; the nightly lap runs ci.sh --strict to make them
  # errors on the quiet scheduled runner)
  python benchmarks/keystream_farm_bench.py --check "${STRICT_ARGS[@]}"
}

stage_serve_smoke() {
  # the serving plane end to end over real loopback TCP: 8 concurrent
  # clients split across 2 tenants, both HHE directions, one mid-stream
  # live key rotation — every recovered plaintext must be bit-exact
  python - <<'PYEOF'
import asyncio

import numpy as np

from repro.serve.server import ServeClient, ServePlane
from repro.serve.tenants import TenantRegistry

N_CLIENTS, TENANTS = 8, ("tenant-a", "tenant-b")


async def drive(client, rng, rotate_at):
    session = await client.open_session()
    q, l = client.params.mod.q, client.params.l
    for step in range(4):
        if step == rotate_at:
            await client.rotate(session)     # live rotation mid-stream
        toks = rng.integers(0, q, size=(int(rng.integers(1, 5)), l),
                            dtype=np.uint32)
        r = await client.encrypt_to_server(session, toks)
        assert r.get("ok"), f"inbound submit failed: {r}"
        got = np.asarray(r["result"], np.uint32)
        assert np.array_equal(got, toks), "inbound recovery not exact"
        toks = rng.integers(0, q, size=(int(rng.integers(1, 5)), l),
                            dtype=np.uint32)
        r, back = await client.decrypt_from_server(session, toks)
        assert r.get("ok"), f"outbound submit failed: {r}"
        assert np.array_equal(back, toks), "outbound recovery not exact"
    return rotate_at >= 0


async def main():
    registry = TenantRegistry("hera-80", capacity=4, window=8,
                              deadline_s=0.01, max_pending_lanes=128)
    plane = ServePlane(registry, port=0, tick_s=0.002)
    host, port = await plane.start()
    clients = [ServeClient(host, port, TENANTS[i % len(TENANTS)])
               for i in range(N_CLIENTS)]
    try:
        for c in clients:
            await c.connect()
        keys = {c.tenant: c.key.tobytes() for c in clients}
        assert len(set(keys.values())) == len(TENANTS), \
            "tenant keys must be distinct"
        # all clients concurrently; client 0 rotates mid-stream
        rotated = await asyncio.gather(*[
            drive(c, np.random.default_rng(100 + i), 2 if i == 0 else -1)
            for i, c in enumerate(clients)
        ])
        assert any(rotated), "no client exercised live rotation"
        stats = await clients[0].stats(tenant_scoped=False)
    finally:
        for c in clients:
            await c.close()
        await plane.stop()
    served = sum(t["windows_served"] for t in stats["per_tenant"].values())
    print(f"serve smoke ok: {N_CLIENTS} clients x {len(TENANTS)} tenants, "
          f"{served} windows served, exact recovery both directions "
          f"(1 live rotation)")


asyncio.run(main())
PYEOF
}

stage_serve_gate() {
  # fresh load-replay lap vs benchmarks/BENCH_serve_trajectory.json: the
  # preset entry set must match exactly; >20% req/s drops or p50/p99
  # growth are flagged (warnings by default, errors on the nightly
  # --strict lap — same contract as bench-gate)
  python benchmarks/serve_load_bench.py --smoke --check "${STRICT_ARGS[@]}"
}

stage_fast_lap() {
  # engine/schedule/redplan suites have their own stages; everything else
  # not slow
  python -m pytest -x -q -m "not slow" --ignore=tests/test_engine.py \
    --ignore=tests/test_schedule.py --ignore=tests/test_redplan.py
}

stage_slow_lap() {
  python -m pytest -x -q -m slow
}

run_stage() {
  # dispatch derived from the name: stage foo-bar runs stage_foo_bar(), so
  # the STAGES registry is the single place a stage is declared
  local fn="stage_${1//-/_}"
  if ! declare -F "$fn" >/dev/null; then
    echo "stage $1 declared in STAGES but $fn() is missing" >&2
    return 2
  fi
  "$fn"
}

# --------------------------------------------------------------------------
# Driver: stage selection, per-stage timing, exit summary table
# --------------------------------------------------------------------------
SELECTED=()
FAST=0
LIST=0
LIST_NAMES=0
STRICT_ARGS=()
while [[ $# -gt 0 ]]; do
  case "$1" in
    --list) LIST=1; shift ;;
    --list-names) LIST_NAMES=1; shift ;;
    --fast) FAST=1; shift ;;
    --strict) STRICT_ARGS=(--strict); shift ;;
    --stage)
      [[ $# -ge 2 ]] || { echo "--stage needs a name (--list)" >&2; exit 2; }
      SELECTED+=("$2"); shift 2 ;;
    *) echo "unknown argument: $1" \
       "(--list | --list-names | --fast | --strict | --stage <name>)" >&2
       exit 2 ;;
  esac
done
[[ $LIST -eq 1 ]] && { list_stages; exit 0; }
[[ $LIST_NAMES -eq 1 ]] && { list_stage_names; exit 0; }

if [[ ${#SELECTED[@]} -eq 0 ]]; then
  while IFS= read -r name; do
    [[ $FAST -eq 1 ]] && fast_excluded "$name" && continue
    SELECTED+=("$name")
  done < <(stage_names)
fi
# validate names before running anything (pure bash: `stage_names | grep -q`
# under pipefail is a SIGPIPE race — grep exits on match while the writer is
# still echoing, and a loaded host turns that into a spurious failure)
for name in "${SELECTED[@]}"; do
  known=0
  for s in "${STAGES[@]}"; do
    [[ "${s%%|*}" == "$name" ]] && { known=1; break; }
  done
  [[ $known -eq 1 ]] || {
    echo "unknown stage: $name" >&2; list_stages >&2; exit 2; }
done

declare -a RESULT_NAMES RESULT_STATUS RESULT_SECS
FAILED=0
for name in "${SELECTED[@]}"; do
  echo
  echo "=== stage: $name ==="
  t0=$SECONDS
  set +e
  ( set -e; run_stage "$name" )
  rc=$?
  set -e
  dt=$(( SECONDS - t0 ))
  RESULT_NAMES+=("$name"); RESULT_SECS+=("$dt")
  if [[ $rc -eq 0 ]]; then
    RESULT_STATUS+=("PASS")
  else
    RESULT_STATUS+=("FAIL")
    FAILED=1
    echo "!!! stage $name FAILED (rc=$rc) — continuing to summarize" >&2
  fi
done

print_summary() {
  echo "=== ci.sh summary ==="
  printf "%-22s %-6s %8s\n" "stage" "status" "seconds"
  printf "%-22s %-6s %8s\n" "----------------------" "------" "-------"
  local i
  for i in "${!RESULT_NAMES[@]}"; do
    printf "%-22s %-6s %8s\n" \
      "${RESULT_NAMES[$i]}" "${RESULT_STATUS[$i]}" "${RESULT_SECS[$i]}"
  done
  if [[ $FAILED -ne 0 ]]; then
    echo "overall: FAIL"
  else
    echo "overall: PASS"
  fi
}

echo
print_summary
if [[ -n "${CI_SUMMARY_FILE:-}" ]]; then
  print_summary > "$CI_SUMMARY_FILE"
  echo "(summary written to $CI_SUMMARY_FILE)"
fi
[[ $FAILED -eq 0 ]] || exit 1
