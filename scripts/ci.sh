#!/usr/bin/env bash
# Tier-1 verify entry point (ROADMAP.md): fast lap first, then the slow
# interpret-mode Pallas sweeps.  One command, two laps:
#
#   scripts/ci.sh          # fast lap + slow lap (the full tier-1 suite)
#   scripts/ci.sh --fast   # fast lap only (developer inner loop)
#
# The fast lap excludes tests marked `slow` (full-lane interpret-mode
# kernel sweeps, see tests/conftest.py); everything else — including the
# farm bit-exactness cross-checks — runs there.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== fast lap (-m 'not slow') ==="
python -m pytest -x -q -m "not slow"

if [[ "${1:-}" == "--fast" ]]; then
  echo "=== fast lap only (--fast); skipping slow lap ==="
  exit 0
fi

echo "=== slow lap (-m slow) ==="
python -m pytest -x -q -m slow
