"""Round-schedule IR tests (core/schedule.py).

Three layers of protection:

  * **golden vectors** — checked-in SHA-256 digests of the keystream for
    every preset × noise on/off, generated from the pre-IR (PR 2) executors.
    Any schedule/executor drift — op order, rc-slice accounting, orientation
    handling — breaks these.  scripts/ci.sh runs this file in its
    schedule-drift stage.
  * **orientation property** — the alternating-orientation variant is
    bit-exact with the normal one on every preset (the executable form of
    Eq. 2: MRMC commutes with transposition, so the orientation plan is
    pure scheduling), for both the pure-JAX interpreter and the Pallas
    kernel.
  * **program structure** — accounting (n_arks, n_round_constants) matches
    the paper's FIFO-depth numbers and params derives it from the program;
    validate() rejects malformed orientation chains.
"""

import dataclasses
import hashlib

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import build_schedule, execute_schedule, make_cipher
from repro.core import schedule as S
from repro.core.params import get_params
from repro.kernels.keystream.ops import keystream_kernel_apply
from repro.kernels.keystream.ref import keystream_ref

PRESETS = ["hera-128a", "rubato-128s", "rubato-128m", "rubato-128l",
           "pasta-128s", "pasta-128l"]
SEED, LANES = 123, 4

# SHA-256 of the little-endian uint32 keystream bytes for
# make_cipher(name, seed=123) over block counters 0..3 — HERA/Rubato
# entries generated from the pre-schedule-IR executors (PR 2 tree), PASTA
# from the cross-checked IR executors at introduction (PR 5).  These
# digests pin the cipher itself: regenerating them
# (scripts/regen_goldens.py --write) is only legitimate when the cipher
# definition deliberately changes, never to "fix" a refactor; the ci.sh
# golden-regen stage fails if regeneration would change any digest.
# --- GOLDEN-BEGIN (scripts/regen_goldens.py) ---
GOLDEN = {
    ("hera-80", "plain"): "c5a66b2b098fede998837c2f7596f0279d9b44968561a3d90058713c5410e052",
    ("hera-128a", "plain"): "894abb58f75f5306e40200bc670d9e4672dd5e345d1f0ad97545c22f1b1132b2",
    ("rubato-128s", "plain"): "9c46b0244571ba344f043498875dea5576c0a6775e39676294191a7e0adf315f",
    ("rubato-128s", "noise"): "e5d632a451be7b27918ac669ef8bf177fd814b779658d28550e396eedc97ee75",
    ("rubato-128m", "plain"): "28a0da4bdad86ca4d35079d7997441efc183508227ff3be81cd271c950b86d8b",
    ("rubato-128m", "noise"): "37acf76c4ab8438e866e6ee38f69c32170fb09462d6012991e3787953921b9ee",
    ("rubato-128l", "plain"): "286453548ffff0abc2231c2603cd895410bab849f334f58b6eff6276d74a5471",
    ("rubato-128l", "noise"): "f89adf017a718905d2e7c40eaac8aebb014111ecba24975b52b75ac7cfca2099",
    ("pasta-128s", "plain"): "021dbc05a9e7b35b06bf077da4d1b657558fdb1156173d6c1ccb69e5e58ff586",
    ("pasta-128l", "plain"): "5d8b9aec6b5d50f63d64477d3ff1e45078047c98ed92c4473fc4d0dabcf92331",
}
# --- GOLDEN-END ---


def _constants(name):
    ci = make_cipher(name, seed=SEED)
    consts = ci.round_constant_stream(jnp.arange(LANES, dtype=jnp.uint32))
    return ci, consts


def _digest(z) -> str:
    return hashlib.sha256(np.array(z).astype("<u4").tobytes()).hexdigest()


# ---------------------------------------------------------------------------
# Golden vectors: schedule executors vs the checked-in pre-IR keystream
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("with_noise", [False, True])
@pytest.mark.parametrize("name", PRESETS)
def test_golden_keystream_digest(name, with_noise):
    p = get_params(name)
    if with_noise and not p.n_noise:
        pytest.skip("preset has no AGN noise (HERA)")
    ci, consts = _constants(name)
    noise = consts["noise"] if with_noise else None
    z = keystream_ref(p, ci.key, consts["rc"], noise,
                      mats=consts.get("mats"))
    assert _digest(z) == GOLDEN[(name, "noise" if with_noise else "plain")]


@pytest.mark.parametrize("name", PRESETS)
def test_golden_digest_alternating_variant(name):
    """The alternating orientation plan must hit the same golden digest."""
    p = get_params(name)
    ci, consts = _constants(name)
    z = keystream_ref(p, ci.key, consts["rc"], consts["noise"],
                      variant="alternating", mats=consts.get("mats"))
    assert _digest(z) == GOLDEN[(name, "noise" if p.n_noise else "plain")]


# ---------------------------------------------------------------------------
# Orientation property: alternating == normal, bit for bit (Eq. 2)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", PRESETS)
def test_alternating_bit_exact_pure_jax(name):
    p = get_params(name)
    ci, consts = _constants(name)
    a = execute_schedule(p, build_schedule(p, "normal"), ci.key,
                         consts["rc"], consts["noise"],
                         mats=consts.get("mats"))
    b = execute_schedule(p, build_schedule(p, "alternating"), ci.key,
                         consts["rc"], consts["noise"],
                         mats=consts.get("mats"))
    np.testing.assert_array_equal(np.array(a), np.array(b))


@pytest.mark.parametrize("name", ["hera-128a", "rubato-128s", "pasta-128s"])
def test_alternating_bit_exact_kernel(name):
    """Kernel-side orientation handling (storage-order constants, permuted
    key column, transposed Feistel shifts) vs the normal plan.  The full
    engine × preset × variant matrix lives in tests/test_engine.py; this is
    the fast direct-kernel check."""
    p = get_params(name)
    ci, consts = _constants(name)
    a = keystream_kernel_apply(p, ci.key, consts["rc"], consts["noise"],
                               interpret=True, variant="normal",
                               mats=consts.get("mats"))
    b = keystream_kernel_apply(p, ci.key, consts["rc"], consts["noise"],
                               interpret=True, variant="alternating",
                               mats=consts.get("mats"))
    np.testing.assert_array_equal(np.array(a), np.array(b))


@pytest.mark.parametrize("name", PRESETS)
def test_eq2_licenses_transposed_rounds(name, rng):
    """Eq. 2: MRMC(Xᵀ) = MRMC(X)ᵀ ⇒ mrmc_transposed ≡ mrmc on the stored
    array — exactly why the alternating variant's transposed-state MRMC
    runs the unmodified datapath, and why a flip is a pure output relabel
    (_mrmc_flat's swapaxes).  Per branch for PASTA's two-word state."""
    from repro.core import rounds as R
    from repro.core.schedule import _mrmc_flat

    p = get_params(name)
    x = jnp.asarray(rng.integers(0, p.mod.q, (6, p.n), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.array(R.mrmc_transposed(p, x)), np.array(R.mrmc(p, x)))
    v, b = p.v, p.branches
    flipped = np.array(_mrmc_flat(p, x, flip_out=True)).reshape(6, b, v, v)
    plain = np.array(_mrmc_flat(p, x, flip_out=False)).reshape(6, b, v, v)
    np.testing.assert_array_equal(flipped, np.swapaxes(plain, 2, 3))


def test_alternating_uses_both_orientations():
    """The alternating plan must actually flip (else the property test is
    vacuous): transposed constant-consuming ops (ARKs for HERA/Rubato,
    affine MRMCs for PASTA) and transposed nonlinear layers appear for
    every preset, and Eq. 2 (mrmc_transposed) is what licenses them."""
    for name in PRESETS:
        sched = build_schedule(get_params(name), "alternating")
        if sched.n_arks:
            assert any(op.orientation == S.TRANSPOSED for op in sched.ops
                       if isinstance(op, S.ARK)), name
        else:
            assert any(op.out_orientation == S.TRANSPOSED for op in sched.ops
                       if isinstance(op, S.MRMC) and op.has_rc), name
        assert any(op.orientation == S.TRANSPOSED for op in sched.ops
                   if isinstance(op, S.NONLINEAR)), name
        assert not build_schedule(get_params(name)).has_transposed_ops


# ---------------------------------------------------------------------------
# Program structure and derived accounting
# ---------------------------------------------------------------------------
def test_accounting_derives_from_program():
    # Presto §IV-C FIFO depths: HERA 96, Rubato Par-128L 188 = 64+64+60;
    # PASTA draws (r+1)·n affine constants (no ARKs at all)
    hera = build_schedule(get_params("hera-128a"))
    rub = build_schedule(get_params("rubato-128l"))
    pasta = build_schedule(get_params("pasta-128l"))
    assert hera.n_arks == 6 and hera.n_round_constants == 96
    assert rub.n_arks == 3 and rub.n_round_constants == 188
    assert pasta.n_arks == 0 and pasta.n_round_constants == 512
    # params delegates to the program (no duplicated formulas)
    assert get_params("hera-128a").n_round_constants == 96
    assert get_params("rubato-128l").n_arks == 3
    assert get_params("pasta-128s").n_round_constants == 160


def test_program_shapes():
    hera = build_schedule(get_params("hera-128a"))
    rub = build_schedule(get_params("rubato-128l"))
    # HERA: no truncation, no AGN; Rubato: both
    assert not any(isinstance(op, (S.TRUNCATE, S.AGN)) for op in hera.ops)
    assert any(isinstance(op, S.TRUNCATE) for op in rub.ops)
    assert isinstance(rub.ops[-1], S.AGN)
    # all three ciphers share the count structure: r+1 MRMCs, r nonlinear
    for name in PRESETS:
        p = get_params(name)
        sched = build_schedule(p)
        assert sched.n_mrmc == p.rounds + 1
        assert sum(isinstance(op, S.NONLINEAR)
                   for op in sched.ops) == p.rounds


def test_pasta_program_shape():
    """PASTA's structural signature: keyed two-branch permutation, affine
    MRMCs carrying additive constants + branch mix, Feistel intermediate
    rounds with a cube final round, truncation to one branch."""
    p = get_params("pasta-128l")
    sched = build_schedule(p)
    assert sched.init == "key" and sched.branches == 2
    assert sched.n_arks == 0 and not any(
        isinstance(op, S.AGN) for op in sched.ops)
    affine = [op for op in sched.ops if isinstance(op, S.MRMC)]
    assert all(op.has_rc and op.mix_branches for op in affine)
    nl = [op.kind for op in sched.ops if isinstance(op, S.NONLINEAR)]
    assert nl == ["feistel"] * (p.rounds - 1) + ["cube"]
    assert isinstance(sched.ops[-1], S.TRUNCATE)
    assert sched.ops[-1].keep == p.l == p.n // 2


def test_validate_rejects_broken_orientation_chain():
    sched = build_schedule(get_params("hera-128a"), "alternating")
    ops = list(sched.ops)
    # claim the final ARK runs transposed without an MRMC flip before it
    ops[-1] = dataclasses.replace(ops[-1], orientation=S.TRANSPOSED)
    with pytest.raises(ValueError, match="expects transposed"):
        dataclasses.replace(sched, ops=tuple(ops)).validate()


def test_validate_error_paths():
    """Every malformed-program fixture is REFUSED with an actionable
    message (the same fixtures must be diagnosed, with rule codes, by the
    static linter — tests/test_analysis.py runs the other side)."""
    from broken_schedules import ALL

    for build, name in ALL:
        broken, _, match = build()
        with pytest.raises(ValueError, match=match):
            broken.validate()


def test_unknown_variant_raises():
    with pytest.raises(ValueError, match="unknown schedule variant"):
        build_schedule(get_params("hera-128a"), "diagonal")


@pytest.mark.parametrize("name", ["rubato-128l", "pasta-128s", "pasta-128l"])
def test_rc_storage_perm_is_slicewise_involution(name):
    """The FIFO reorder permutes only within transposed constant slices
    (ARK for HERA/Rubato, affine MRMC for PASTA — per branch), so the
    producer's constant *count* accounting is untouched."""
    sched = build_schedule(get_params(name), "alternating")
    perm = sched.rc_storage_perm()
    assert perm is not None
    assert sorted(perm) == list(range(sched.n_round_constants))
    np.testing.assert_array_equal(perm[perm], np.arange(len(perm)))
    assert build_schedule(get_params(name)).rc_storage_perm() is None


def test_pasta_storage_perm_never_crosses_branches():
    """A transposed affine slice permutes within each branch's half —
    PASTA's branches are independent (v, v) matrices, so the RNG FIFO
    reorder must never move a constant across the branch boundary."""
    sched = build_schedule(get_params("pasta-128s"), "alternating")
    perm = sched.rc_storage_perm()
    n, t = sched.n, sched.n // 2
    for op in sched.ops:
        if isinstance(op, S.MRMC) and op.has_rc:
            a, _ = op.rc_slice
            first = perm[a : a + t] - a
            second = perm[a + t : a + n] - a
            assert (first < t).all(), "branch L slice leaked into branch R"
            assert (second >= t).all(), "branch R slice leaked into branch L"


def test_describe_listing():
    text = build_schedule(get_params("hera-128a"), "alternating").describe()
    assert "MRMC[N->T]" in text and "CUBE[T]" in text
    assert "rc[80:96]" in text  # final ARK slice — the 96-constant FIFO
    ptext = build_schedule(get_params("pasta-128l"), "alternating").describe()
    assert "2 branches" in ptext and "init=key" in ptext
    assert "+rc[384:512]" in ptext and "mix" in ptext
