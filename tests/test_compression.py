"""int8 error-feedback gradient compression (cross-pod all-reduce)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.train.compression import (
    compressed_pod_reduce, init_error_buffers, _q8,
)


def test_q8_roundtrip_error_bounded(rng):
    x = jnp.asarray(rng.normal(0, 3, (16, 64)), jnp.float32)
    q, s = _q8(x)
    back = q.astype(jnp.float32) * s
    # per-row absmax quantization: error < scale = amax/127
    amax = np.abs(np.array(x)).max(axis=-1, keepdims=True)
    assert (np.abs(np.array(back - x)) <= amax / 127 + 1e-7).all()


def test_compressed_reduce_matches_mean_with_error_feedback(rng):
    # single-device "pod" axis of size 1: compressed reduce == dequant(own)
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    g = {"w": jnp.asarray(rng.normal(0, 1, (8, 32)), jnp.float32)}
    err = init_error_buffers(g)
    total_est = jnp.zeros_like(g["w"])
    total_true = jnp.zeros_like(g["w"])
    # over steps, error feedback makes the *accumulated* estimate unbiased
    for step in range(30):
        gs = {"w": g["w"] * (1.0 + 0.1 * step)}
        red, err = compressed_pod_reduce(gs, err, mesh, axis="pod")
        total_est = total_est + red["w"]
        total_true = total_true + gs["w"]
    rel = float(jnp.abs(total_est - total_true).max()
                / jnp.abs(total_true).max())
    assert rel < 0.01, rel


def test_error_buffer_carries_residual(rng):
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    g = {"w": jnp.asarray(rng.normal(0, 1, (4, 16)), jnp.float32)}
    err0 = init_error_buffers(g)
    red, err1 = compressed_pod_reduce(g, err0, mesh, axis="pod")
    # residual = input - dequantized output (pods=1)
    np.testing.assert_allclose(
        np.array(err1["w"]), np.array(g["w"] - red["w"]), atol=1e-6)
