"""Shared test fixtures.  NOTE: no XLA_FLAGS here by design — smoke tests
and benches must see 1 device (the 512-device override belongs ONLY to
launch/dryrun.py and launch/roofline.py)."""

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running (full-lane interpret-mode Pallas sweeps); "
        "excluded from the fast CI lap (scripts/ci.sh)",
    )


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
