"""StreamPlan autotuner: JSON cache round trip, deterministic reload,
measured selection on the real farm loop, and "auto" resolution
consulting the persisted plan (ISSUE acceptance).
"""

import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import CipherBatch, KeystreamFarm, StreamPlan
from repro.core.engine import resolve_engine
from repro.core.params import get_params
from repro.core.producer import resolve_producer
from repro.core.tuner import (
    PLAN_SCHEMA,
    autotune,
    cache_key,
    candidate_plans,
    host_fingerprint,
    load_plan,
    measure_plan,
    save_plan,
)

TINY = dict(sessions=2, n_windows=2, reps=1)


def _tiny_autotune(cache, **kw):
    args = dict(engines=["jax"], variants=["normal"], windows=[8],
                depths=[2], producers=["aes"], reductions=["lazy"],
                cache_path=cache, **TINY)
    args.update(kw)
    return autotune("rubato-128s", 8, **args)


# ---------------------------------------------------------------------------
# StreamPlan serialization
# ---------------------------------------------------------------------------
def test_stream_plan_json_roundtrip_bit_identical():
    plan = StreamPlan(producer="cached", engine="jax", variant="alternating",
                      window=128, depth=3)
    d = plan.to_json()
    assert StreamPlan.from_json(d) == plan
    # survives an actual JSON encode/decode, and ignores metadata keys
    d2 = json.loads(json.dumps(dict(d, p50_ms=1.23, measured_at=0.0)))
    assert StreamPlan.from_json(d2) == plan


def test_candidate_plans_are_stream_preserving():
    plans = candidate_plans("hera-128a", 16, engines=["jax"])
    assert plans, "empty candidate grid"
    producers = {p.producer for p in plans}
    assert "threefry" not in producers      # would change the keystream
    assert {"aes", "cached"} <= producers
    assert {p.depth for p in plans} == {2, 3}


def test_stream_plan_matrix_depth_roundtrip():
    """matrix_depth rides the plan through JSON bit-identically, and
    legacy entries without the field load as the fused default (1)."""
    plan = StreamPlan("aes", "jax", "normal", 8, 2, 3)
    d = plan.to_json()
    assert d["matrix_depth"] == 3
    assert StreamPlan.from_json(json.loads(json.dumps(d))) == plan
    legacy = {k: v for k, v in d.items() if k != "matrix_depth"}
    assert StreamPlan.from_json(legacy).matrix_depth == 1
    # positional construction keeps matrix_depth last (schema history)
    assert StreamPlan("aes", "jax", "normal", 8, 2) == \
        StreamPlan.from_json(legacy)


def test_candidate_plans_matrix_depth_grid():
    """The grid explores matrix prefetch only where it can matter: PASTA
    (stream-sourced matrices) gets {1, 2}, matrix-free presets stay at 1."""
    pasta = candidate_plans("pasta-128s", 8, engines=["jax"])
    assert {p.matrix_depth for p in pasta} == {1, 2}
    hera = candidate_plans("hera-128a", 8, engines=["jax"])
    assert {p.matrix_depth for p in hera} == {1}


# ---------------------------------------------------------------------------
# Cache persistence + deterministic reload
# ---------------------------------------------------------------------------
def test_autotune_persists_and_reloads_deterministically(tmp_path):
    cache = tmp_path / "plans.json"
    plan = _tiny_autotune(cache, depths=[2, 3])
    assert cache.exists()
    # cache hit: no re-measure, bit-identical result — twice
    for _ in range(2):
        again = _tiny_autotune(cache, depths=[2, 3])
        assert again == plan
    assert load_plan("rubato-128s", 8, cache) == plan
    # the persisted entry round-trips through the file bit-identically
    entry = json.loads(cache.read_text())["plans"][
        cache_key(get_params("rubato-128s"), 8)]
    assert StreamPlan.from_json(entry) == plan


def test_load_plan_nearest_lanes_fallback(tmp_path):
    cache = tmp_path / "plans.json"
    p8 = StreamPlan("aes", "jax", "normal", 8, 2)
    p64 = StreamPlan("cached", "jax", "normal", 64, 3)
    save_plan("rubato-128s", 8, p8, 1.0, cache)
    save_plan("rubato-128s", 64, p64, 1.0, cache)
    assert load_plan("rubato-128s", 8, cache) == p8           # exact
    assert load_plan("rubato-128s", 48, cache) == p64         # nearest
    assert load_plan("rubato-128s", None, cache) == p64       # largest
    assert load_plan("hera-128a", 8, cache) is None           # other preset


def test_load_plan_rejects_invalid_cached_backends(tmp_path):
    """Plans naming gone/unavailable/stream-incompatible backends are
    ignored, not trusted."""
    cache = tmp_path / "plans.json"
    save_plan("hera-128a", 8,
              StreamPlan("threefry", "jax", "normal", 8, 2), 1.0, cache)
    assert load_plan("hera-128a", 8, cache) is None     # wrong stream
    save_plan("hera-128a", 8,
              StreamPlan("aes", "vulkan", "normal", 8, 2), 1.0, cache)
    assert load_plan("hera-128a", 8, cache) is None     # unknown engine
    save_plan("hera-128a", 8,
              StreamPlan("aes", "jax", "diagonal", 8, 2), 1.0, cache)
    assert load_plan("hera-128a", 8, cache) is None     # unknown variant


# ---------------------------------------------------------------------------
# Cache-schema versioning: stale-schema entries are invalidated, not trusted
# ---------------------------------------------------------------------------
def _rewrite_entry_schema(cache, schema):
    """Patch every persisted entry's schema field in place (None = drop
    the field entirely — the PR 4 legacy layout)."""
    data = json.loads(cache.read_text())
    for entry in data["plans"].values():
        if schema is None:
            entry.pop("schema", None)
        else:
            entry["schema"] = schema
    cache.write_text(json.dumps(data))


def test_save_plan_stamps_current_schema(tmp_path):
    cache = tmp_path / "plans.json"
    save_plan("rubato-128s", 8, StreamPlan("aes", "jax", "normal", 8, 2),
              1.0, cache)
    entry = json.loads(cache.read_text())["plans"][
        cache_key(get_params("rubato-128s"), 8)]
    assert entry["schema"] == PLAN_SCHEMA


@pytest.mark.parametrize("stale", [None, PLAN_SCHEMA - 1, PLAN_SCHEMA + 1,
                                   "garbage"])
def test_load_plan_ignores_stale_schema_entries(tmp_path, stale):
    """A plan measured under different backend semantics (schema bump)
    must be ignored on load — including pre-stamp legacy entries (no
    schema field = schema 1) and malformed values."""
    cache = tmp_path / "plans.json"
    plan = StreamPlan("aes", "jax", "normal", 8, 2)
    save_plan("rubato-128s", 8, plan, 1.0, cache)
    assert load_plan("rubato-128s", 8, cache) == plan      # fresh: trusted
    _rewrite_entry_schema(cache, stale)
    assert load_plan("rubato-128s", 8, cache) is None      # stale: ignored


def test_nearest_lanes_fallback_skips_stale_schema(tmp_path):
    cache = tmp_path / "plans.json"
    p8 = StreamPlan("aes", "jax", "normal", 8, 2)
    save_plan("rubato-128s", 8, p8, 1.0, cache)
    _rewrite_entry_schema(cache, PLAN_SCHEMA - 1)
    p64 = StreamPlan("cached", "jax", "normal", 64, 3)
    save_plan("rubato-128s", 64, p64, 1.0, cache)
    # lanes=16 is nearest to the stale 8-lane entry, but only the
    # current-schema 64-lane plan may be served
    assert load_plan("rubato-128s", 16, cache) == p64


def test_autotune_remeasures_over_stale_schema(tmp_path):
    """A cache hit on a stale-schema entry is NOT a hit: autotune must
    re-measure and overwrite the entry under the current schema."""
    cache = tmp_path / "plans.json"
    plan = _tiny_autotune(cache)
    _rewrite_entry_schema(cache, PLAN_SCHEMA - 1)
    again = _tiny_autotune(cache)                 # re-measures, re-persists
    assert again == plan
    entry = json.loads(cache.read_text())["plans"][
        cache_key(get_params("rubato-128s"), 8)]
    assert entry["schema"] == PLAN_SCHEMA


def test_cache_key_is_host_scoped():
    k = cache_key(get_params("rubato-128l"), 32)
    assert k.startswith("rubato-128l|lanes=32|noise=60|host=")
    assert k.endswith(host_fingerprint())


# ---------------------------------------------------------------------------
# Measured selection + "auto" resolution
# ---------------------------------------------------------------------------
def test_measure_plan_runs_real_farm_loop():
    p50 = measure_plan("rubato-128s",
                       StreamPlan("aes", "jax", "normal", 8, 3), 8, **TINY)
    assert p50 > 0


def test_autotune_winner_comes_from_the_grid(tmp_path):
    cache = tmp_path / "plans.json"
    plan = _tiny_autotune(cache, producers=["aes", "cached"], depths=[2, 3])
    assert plan.producer in ("aes", "cached")
    assert plan.engine == "jax" and plan.variant == "normal"
    assert plan.window == 8 and plan.depth in (2, 3)


def test_auto_resolution_consults_persisted_plan(tmp_path, monkeypatch):
    cache = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_TUNER_CACHE", str(cache))
    p = get_params("rubato-128s")
    # no cache -> static fallbacks
    assert resolve_engine("auto", params=p) == resolve_engine("auto")
    assert resolve_producer("auto", p) == p.xof
    save_plan(p, 8, StreamPlan("cached", "jax", "normal", 8, 2), 1.0)
    assert resolve_engine("auto", params=p) == "jax"
    assert resolve_producer("auto", p) == "cached"
    # pool-level: CipherBatch(producer="auto") binds the tuned producer
    cb = CipherBatch(p, seed=1, producer="auto")
    assert cb.producer.name == "cached"


def test_farm_applies_stream_plan():
    """KeystreamFarm(plan=...) applies producer, engine, variant, depth in
    one shot — and stays bit-exact with the default pipeline."""
    plan = StreamPlan("cached", "jax", "alternating", 4, 3)
    cb = CipherBatch("rubato-128s", seed=21)
    cb.add_sessions(2)
    farm = KeystreamFarm(cb, plan=plan)
    assert cb.producer.name == "cached"
    assert farm.engine.name == "jax" and farm.engine.variant == "alternating"
    assert farm.depth == 3 and farm.window == 4
    sids = np.array([0, 1, 0, 1, 1, 0])
    ctrs = np.array([0, 0, 1, 1, 2, 2])
    z = np.array(farm.keystream(sids, ctrs))    # windowed by plan.window
    base = CipherBatch("rubato-128s", seed=21)
    base.add_sessions(2)
    ref = KeystreamFarm(base, engine="ref")
    np.testing.assert_array_equal(z, np.array(ref.keystream(sids, ctrs)))


def test_save_load_plan_preserves_matrix_depth(tmp_path):
    """Persisted plans carry matrix_depth through the cache round trip
    (the PLAN_SCHEMA=3 field)."""
    cache = tmp_path / "plans.json"
    plan = StreamPlan("aes", "jax", "normal", 8, 2, 2)
    save_plan("pasta-128s", 8, plan, 1.0, cache)
    got = load_plan("pasta-128s", 8, cache)
    assert got == plan and got.matrix_depth == 2


def test_farm_applies_plan_matrix_depth():
    """A plan carrying matrix_depth>=2 switches the farm onto the split
    plane pipeline — and stays bit-exact with the reference farm."""
    plan = StreamPlan("aes", "jax", "normal", 4, 2, 2)
    cb = CipherBatch("pasta-128s", seed=24)
    cb.add_sessions(2)
    farm = KeystreamFarm(cb, plan=plan)
    assert farm.matrix_depth == 2 and farm._splits_planes
    sids = np.array([0, 1, 0, 1, 1, 0, 0, 1])
    ctrs = np.array([0, 0, 1, 1, 2, 2, 3, 3])
    z = np.array(farm.keystream(sids, ctrs))    # windowed by plan.window
    base = CipherBatch("pasta-128s", seed=24)
    base.add_sessions(2)
    ref = KeystreamFarm(base, engine="ref")
    np.testing.assert_array_equal(z, np.array(ref.keystream(sids, ctrs)))
    # explicit argument still overrides the plan's knob
    farm1 = KeystreamFarm(CipherBatch("pasta-128s", seed=25),
                          matrix_depth=1, plan=plan)
    assert farm1.matrix_depth == 1 and not farm1._splits_planes


def test_farm_explicit_args_override_plan():
    plan = StreamPlan("aes", "jax", "alternating", 4, 3)
    cb = CipherBatch("rubato-128s", seed=22)
    cb.add_session()
    farm = KeystreamFarm(cb, engine="ref", variant="normal", depth=2,
                         plan=plan)
    assert farm.engine.name == "ref"
    assert farm.engine.variant == "normal" and farm.depth == 2


def test_hhe_server_and_encrypted_source_accept_plan():
    from repro.serve.hhe_loop import HHERequest, HHEServer

    plan = StreamPlan("cached", "jax", "normal", 4, 3)
    cb = CipherBatch("rubato-128s", seed=23)
    srv = HHEServer(cb, plan=plan)
    assert srv.window == 4 and srv.farm.depth == 3
    assert cb.producer.name == "cached"
    s = srv.open_session()
    srv.submit(HHERequest(session_id=s.index, op="keystream", blocks=6))
    (resp,) = srv.flush()
    want = np.array(cb.session_cipher(s.index).keystream(
        jnp.asarray(resp.block_ctrs, jnp.uint32)))
    np.testing.assert_array_equal(resp.result, want)
