"""ConstantsProducer registry: capability reporting, the memoizing
`cached` backend, and the cross-(producer × engine × variant)
bit-exactness matrix (ISSUE acceptance: keystreams identical regardless
of which stream-compatible plan materializes the constants).
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    CipherBatch,
    compatible_producers,
    make_cipher,
    make_producer,
    producer_caps,
    registered_producers,
    resolve_producer,
)
from repro.core.params import get_params
from repro.core.producer import CachedProducer

LANES = 3


def _threefry_params(base="rubato-128s"):
    p = get_params(base)
    return dataclasses.replace(p, name=f"{base}-tf", xof="threefry")


# ---------------------------------------------------------------------------
# Registry + capability reporting
# ---------------------------------------------------------------------------
def test_registry_contents():
    assert set(registered_producers()) >= {"aes", "threefry", "cached"}
    assert len(registered_producers()) >= 3


def test_producer_caps_report():
    caps = producer_caps()
    assert set(caps) == set(registered_producers())
    for c in caps.values():
        assert c.available or c.reason
    assert caps["aes"].stream == "aes"
    assert caps["threefry"].stream == "threefry"
    # the wrapper follows params.xof and declares its memoization
    assert caps["cached"].stream is None
    assert caps["cached"].memoizes and not caps["aes"].memoizes


def test_compatible_producers_preserve_stream():
    """The tuner's candidate set: swapping within it never changes a
    keystream bit, so 'threefry' must NOT be offered for an aes preset."""
    comp_aes = compatible_producers(get_params("hera-128a"))
    assert "aes" in comp_aes and "cached" in comp_aes
    assert "threefry" not in comp_aes
    comp_tf = compatible_producers(_threefry_params())
    assert "threefry" in comp_tf and "cached" in comp_tf
    assert "aes" not in comp_tf


def test_resolve_producer_defaults_to_preset_stream():
    assert resolve_producer(None, get_params("hera-128a")) == "aes"
    assert resolve_producer(None, _threefry_params()) == "threefry"
    assert resolve_producer("cached", get_params("hera-128a")) == "cached"


def test_unknown_producer_raises_listing_registry():
    with pytest.raises(ValueError, match="registered producers"):
        resolve_producer("chacha", get_params("hera-128a"))
    with pytest.raises(ValueError, match="registered producers"):
        CipherBatch("hera-128a", producer="chacha")


def test_make_producer_passes_instances_through():
    p = get_params("hera-128a")
    prod = make_producer("aes", p)
    assert make_producer(prod, p) is prod


def test_make_producer_rejects_mismatched_params():
    """A producer sampling for different (q, constant-count) would emit
    constants no engine of this pool can consume — must fail loudly."""
    prod = make_producer("aes", get_params("hera-128a"))
    with pytest.raises(ValueError, match="different params"):
        make_producer(prod, get_params("rubato-128l"))


def test_cached_cannot_wrap_itself():
    with pytest.raises(ValueError, match="wrap itself"):
        CachedProducer(get_params("hera-128a"), inner="cached")


def test_describe_table_lists_all():
    from repro.core.producer import describe

    text = describe()
    for name in registered_producers():
        assert name in text


# ---------------------------------------------------------------------------
# The matrix: keystream identical regardless of (producer, engine, variant)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["hera-128a", "rubato-128s", "pasta-128s"])
@pytest.mark.parametrize("engine", ["ref", "jax", "pallas-interpret"])
@pytest.mark.parametrize("variant", ["normal", "alternating"])
def test_plan_matrix_bit_exact(name, engine, variant):
    """Every stream-compatible producer × engine × variant combination
    must produce the SAME keystream — a tuned StreamPlan can change
    latency, never a bit."""
    rng = np.random.default_rng(3)
    sids = rng.integers(0, 3, 8)
    ctrs = rng.integers(0, 2**16, 8)
    want = None
    for producer in compatible_producers(get_params(name)):
        cb = CipherBatch(name, seed=11, producer=producer)
        cb.add_sessions(3)
        eng = cb.make_engine(engine, variant=variant)
        consts = cb.round_constant_stream(sids, ctrs)
        z = np.array(eng(consts))
        if want is None:
            want = z
        else:
            np.testing.assert_array_equal(z, want)
    assert want is not None


@pytest.mark.parametrize("producer", ["threefry", "cached"])
def test_threefry_stream_matrix(producer):
    """Same matrix property on a threefry-stream preset."""
    p = _threefry_params()
    cb = CipherBatch(p, seed=7, producer=producer)
    cb.add_sessions(2)
    sids = np.array([0, 1, 1, 0])
    ctrs = np.array([0, 0, 3, 9])
    z = np.array(cb.keystream(sids, ctrs))
    base = CipherBatch(p, seed=7)    # defaults to the threefry stream
    base.add_sessions(2)
    np.testing.assert_array_equal(z, np.array(base.keystream(sids, ctrs)))


def test_single_stream_cipher_matches_batched_producer():
    """Cipher (single-nonce path) and CipherBatch (table-gather path) run
    the same producer backend and must agree bit-for-bit."""
    cb = CipherBatch("rubato-128l", seed=4, producer="cached")
    s = cb.add_session()
    ctrs = np.arange(5)
    z_batch = np.array(cb.keystream(np.zeros(5, np.int64), ctrs))
    ci = cb.session_cipher(s.index)
    assert ci.producer == "cached"    # oracle runs the pool's backend
    z_single = np.array(ci.keystream(jnp.asarray(ctrs, jnp.uint32)))
    np.testing.assert_array_equal(z_batch, z_single)


# ---------------------------------------------------------------------------
# Constants-plane splitting (the farm's matrix-prefetch producer half)
# ---------------------------------------------------------------------------
def test_producer_plane_split_bit_exact():
    """Producing the vector and matrix planes separately must yield exactly
    the planes a fused "all" pass materializes — the stream is one stream,
    the split is pure scheduling."""
    cb = CipherBatch("pasta-128s", seed=40)
    cb.add_sessions(2)
    sids = np.array([0, 1, 1, 0])
    ctrs = np.array([0, 0, 5, 9])
    tables = cb.xof_tables()
    full = cb.producer.produce(tables, sids, ctrs, "all")
    vec = cb.producer.produce(tables, sids, ctrs, "vector")
    mat = cb.producer.produce(tables, sids, ctrs, "matrix")
    assert set(vec) == {"rc", "noise"} and set(mat) == {"mats"}
    np.testing.assert_array_equal(np.array(vec["rc"]), np.array(full["rc"]))
    assert vec["noise"] is None is full["noise"]      # PASTA: no noise plane
    assert mat["mats"].shape == (
        4, cb.params.n_matrix_constants)
    np.testing.assert_array_equal(np.array(mat["mats"]),
                                  np.array(full["mats"]))


def test_producer_unknown_plane_rejected():
    cb = CipherBatch("pasta-128s", seed=40)
    cb.add_session()
    with pytest.raises(ValueError, match="unknown constants plane"):
        cb.producer.produce(cb.xof_tables(), np.zeros(1, np.int64),
                            np.zeros(1, np.uint32), "diagonal")


# ---------------------------------------------------------------------------
# The cached producer's memoization semantics
# ---------------------------------------------------------------------------
def test_cached_producer_hits_on_repeat_window():
    cb = CipherBatch("rubato-128s", seed=9, producer="cached")
    cb.add_sessions(2)
    sids, ctrs = np.array([0, 1, 0, 1]), np.array([0, 0, 1, 1])
    z1 = np.array(cb.keystream(sids, ctrs))
    stats1 = cb.producer.cache_stats()
    assert stats1["misses"] == 1 and stats1["hits"] == 0
    z2 = np.array(cb.keystream(sids, ctrs))          # the re-keying shape
    stats2 = cb.producer.cache_stats()
    assert stats2["hits"] == 1
    np.testing.assert_array_equal(z1, z2)


def test_cached_producer_invalidates_on_rotation():
    """Rotation replaces the nonce — the cache key — so a repeated
    (session, ctr) window after rotation must MISS and produce the new
    generation's stream, never a stale plane."""
    cb = CipherBatch("rubato-128s", seed=10, producer="cached")
    s = cb.add_session()
    ctrs = np.arange(4)
    z_old = np.array(cb.keystream(np.zeros(4, np.int64), ctrs))
    cb.rotate_session(s.index)
    z_new = np.array(cb.keystream(np.zeros(4, np.int64), ctrs))
    assert not np.array_equal(z_old, z_new)
    np.testing.assert_array_equal(
        z_new,
        np.array(cb.session_cipher(s.index).keystream(
            jnp.asarray(ctrs, jnp.uint32))))
    assert cb.producer.cache_stats()["misses"] == 2   # no stale hit


def test_cached_producer_keys_on_plane_kind():
    """Plane kind is part of the cache identity: a vector-plane request and
    a matrix-plane request for the SAME (nonces, ctrs) window are distinct
    entries — a shared cache must never serve one where the other is
    expected."""
    p = get_params("pasta-128s")
    prod = CachedProducer(p)
    cb = CipherBatch(p, seed=41, producer=prod)
    cb.add_session()
    sids, ctrs = np.zeros(2, np.int64), np.arange(2)
    tables = cb.xof_tables()
    prod.produce(tables, sids, ctrs, "vector")
    m1 = prod.produce(tables, sids, ctrs, "matrix")
    stats = prod.cache_stats()
    assert stats["misses"] == 2 and stats["hits"] == 0    # no cross-plane hit
    assert stats["entries"] == 2
    v2 = prod.produce(tables, sids, ctrs, "vector")
    m2 = prod.produce(tables, sids, ctrs, "matrix")
    assert prod.cache_stats()["hits"] == 2                # repeats DO hit
    assert "mats" not in v2 and set(m2) == {"mats"}
    np.testing.assert_array_equal(np.array(m2["mats"]), np.array(m1["mats"]))


def test_cached_matrix_plane_invalidates_on_rotation():
    """Rotation replaces the nonce — the cache key — so a repeated
    matrix-plane window after rotation must MISS and produce the new
    generation's matrices, never a stale plane."""
    p = get_params("pasta-128s")
    prod = CachedProducer(p)
    cb = CipherBatch(p, seed=42, producer=prod)
    s = cb.add_session()
    sids, ctrs = np.zeros(2, np.int64), np.arange(2)
    m_old = np.array(
        prod.produce(cb.xof_tables(), sids, ctrs, "matrix")["mats"])
    cb.rotate_session(s.index)
    m_new = np.array(
        prod.produce(cb.xof_tables(), sids, ctrs, "matrix")["mats"])
    assert prod.cache_stats()["misses"] == 2              # no stale hit
    assert not np.array_equal(m_old, m_new)
    # the post-rotation plane is the fused pass's plane for the new nonce
    np.testing.assert_array_equal(
        m_new,
        np.array(prod.produce(cb.xof_tables(), sids, ctrs, "all")["mats"]))


def test_cached_producer_lru_eviction():
    p = get_params("hera-128a")
    prod = CachedProducer(p, max_entries=2)
    cb = CipherBatch(p, seed=12, producer=prod)
    cb.add_session()
    for base in (0, 4, 8):
        cb.keystream(np.zeros(2, np.int64), np.array([base, base + 1]))
    stats = prod.cache_stats()
    assert stats["entries"] == 2 and stats["misses"] == 3
    # the oldest window (base=0) was evicted: re-requesting it misses
    cb.keystream(np.zeros(2, np.int64), np.array([0, 1]))
    assert prod.cache_stats()["misses"] == 4


def test_cached_producer_traces_through_coupled_path():
    """Under jax.jit tracing (keystream_coupled) there is no host identity
    to key on — the cache must be bypassed, not crash."""
    import jax

    ci = make_cipher("rubato-128s", seed=2, producer="cached")
    ctrs = jnp.arange(3, dtype=jnp.uint32)
    z = np.array(jax.jit(ci.keystream_coupled)(ctrs))
    np.testing.assert_array_equal(z, np.array(ci.keystream(ctrs)))


def test_set_producer_rejects_cross_stream_swap():
    """Swapping a LIVE pool onto a different XOF stream would make the
    same (nonce, ctr) pairs yield different keystream — clients' earlier
    ciphertexts would decrypt to garbage silently.  set_producer (the
    plan-application path) must refuse; a different stream is a
    construction-time choice."""
    cb = CipherBatch("hera-128a", seed=1)
    cb.add_session()
    with pytest.raises(ValueError, match="stream"):
        cb.set_producer("threefry")
    assert cb.producer.name == "aes"          # pool untouched
    # construction-time choice remains available
    assert CipherBatch("hera-128a", producer="threefry").producer.name == \
        "threefry"


def test_cached_instance_shared_across_pools_keys_on_tables():
    """Cache identity rides on the ProducerTables a produce call uses, not
    on producer-instance state: one cached instance shared between a pool
    and a single-stream Cipher under a different nonce must never serve
    the wrong nonce's constants plane."""
    p = get_params("rubato-128s")
    prod = CachedProducer(p)
    cb = CipherBatch(p, seed=30, producer=prod)
    cb.add_session()
    ctrs = np.arange(3)
    sids = np.zeros(3, np.int64)
    z_pool = np.array(cb.keystream(sids, ctrs))
    # same instance, different nonce, same counters — fills the cache
    from repro.core.cipher import Cipher

    other_nonce = np.arange(16, dtype=np.uint8)
    ci = Cipher(p, cb.key, other_nonce, producer=prod)
    z_other = np.array(ci.keystream(jnp.asarray(ctrs, jnp.uint32)))
    assert not np.array_equal(z_other, z_pool)
    # the pool's repeat request must hit ITS OWN plane, not the Cipher's
    np.testing.assert_array_equal(np.array(cb.keystream(sids, ctrs)),
                                  z_pool)
    # and vice versa
    np.testing.assert_array_equal(
        np.array(ci.keystream(jnp.asarray(ctrs, jnp.uint32))), z_other)


def test_set_producer_swaps_in_place_bit_exact():
    """Applying a tuned plan rebinds the pool's producer; a
    stream-compatible swap changes no keystream bit and keeps live
    sessions' counter spaces."""
    cb = CipherBatch("rubato-128s", seed=13)
    s = cb.add_session()
    s.take_window(6)
    sids, ctrs = np.zeros(4, np.int64), np.arange(4)
    z_aes = np.array(cb.keystream(sids, ctrs))
    cb.set_producer("cached")
    assert cb.producer.name == "cached"
    assert cb.sessions[0].next_ctr == 6        # cursor survives the swap
    np.testing.assert_array_equal(np.array(cb.keystream(sids, ctrs)), z_aes)
