"""Optimizer, checkpoint, data pipeline, compression, elastic tests."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.data.encrypted import EncryptedSource, make_decryptor
from repro.data.pipeline import SyntheticLM
from repro.core.cipher import make_cipher
from repro.launch.elastic import StragglerWatchdog, plan_mesh
from repro.train import checkpoint as ckpt
from repro.train.optimizer import (
    OptConfig, adamw_update, init_opt_state, lr_at,
)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_matches_reference_step(rng):
    opt = OptConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                    grad_clip=1e9, warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.asarray(rng.normal(0, 1, (4, 8)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(0, 1, (4, 8)), jnp.float32)}
    s = init_opt_state(p, opt)
    new_p, new_s, m = adamw_update(p, g, s, jnp.asarray(0, jnp.int32), opt)
    # reference
    lr = float(lr_at(opt, jnp.asarray(0, jnp.int32)))
    mm = 0.1 * np.array(g["w"])
    vv = 0.01 * np.array(g["w"]) ** 2
    upd = (mm / (1 - 0.9)) / (np.sqrt(vv / (1 - 0.99)) + 1e-8)
    want = np.array(p["w"]) - lr * upd
    np.testing.assert_allclose(np.array(new_p["w"]), want, rtol=1e-5)


def test_adamw_8bit_tracks_f32(rng):
    """8-bit moments must track the f32 optimizer closely over steps."""
    opt32 = OptConfig(lr=1e-2, weight_decay=0.0, grad_clip=1e9,
                      warmup_steps=0, total_steps=10**9)
    opt8 = OptConfig(lr=1e-2, weight_decay=0.0, grad_clip=1e9,
                     warmup_steps=0, total_steps=10**9, eightbit=True)
    p32 = {"w": jnp.asarray(rng.normal(0, 1, (64, 128)), jnp.float32)}
    p8 = jax.tree.map(jnp.copy, p32)
    s32, s8 = init_opt_state(p32, opt32), init_opt_state(p8, opt8)
    assert "m_q" in s8["w"] and s8["w"]["m_q"].dtype == jnp.int8
    for step in range(10):
        g = {"w": jnp.asarray(rng.normal(0, 1, (64, 128)), jnp.float32)}
        p32, s32, _ = adamw_update(p32, g, s32, jnp.asarray(step), opt32)
        p8, s8, _ = adamw_update(p8, g, s8, jnp.asarray(step), opt8)
    diff = float(jnp.abs(p32["w"] - p8["w"]).max())
    scale = float(jnp.abs(p32["w"]).max())
    assert diff < 0.05 * scale, (diff, scale)


def test_grad_clip_engages():
    opt = OptConfig(lr=1.0, grad_clip=0.1, weight_decay=0.0,
                    warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0, jnp.float32)}
    s = init_opt_state(p, opt)
    _, _, m = adamw_update(p, g, s, jnp.asarray(0), opt)
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-4)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_gc(tmp_path, rng):
    tree = {
        "a": jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.float32),
        "b": [jnp.arange(5, dtype=jnp.int32),
              {"c": jnp.asarray(rng.normal(0, 1, (3,)), jnp.bfloat16)}],
    }
    d = str(tmp_path / "ck")
    for step in (10, 20, 30, 40):
        ckpt.save(d, step, tree, extra={"data_step": step}, keep_last=2)
    assert ckpt.latest_step(d) == 40
    dirs = sorted(os.listdir(d))
    assert len([x for x in dirs if x.startswith("step_")]) == 2  # GC worked
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out, step, extra = ckpt.restore(d, like)
    assert step == 40 and extra["data_step"] == 40
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.zeros((4,), jnp.float32)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, tree)
    bad = {"a": jax.ShapeDtypeStruct((5,), jnp.float32)}
    with pytest.raises(ValueError):
        ckpt.restore(d, bad)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_deterministic_and_resumable():
    cfg = get_config("granite-3-8b", smoke=True)
    s1 = SyntheticLM(cfg, 4, 32, seed=7)
    s2 = SyntheticLM(cfg, 4, 32, seed=7)
    b1, b2 = s1.batch_at(5), s2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch_at(5)["tokens"],
                              s1.batch_at(6)["tokens"])
    # labels are next-token shifted
    assert (b1["tokens"] < cfg.vocab).all()


def test_encrypted_source_decrypts_to_plaintext():
    cfg = get_config("granite-3-8b", smoke=True)
    src = SyntheticLM(cfg, 2, 40, seed=3)
    cipher = make_cipher("rubato-128l", seed=9)
    enc = EncryptedSource(src, cipher)
    dec = make_decryptor(cipher)
    step = 4
    plain = src.batch_at(step)
    got = dec(jax.tree.map(jnp.asarray, enc.batch_at(step)))
    np.testing.assert_array_equal(np.array(got["tokens"]), plain["tokens"])
    # labels: shifted tokens, last masked
    np.testing.assert_array_equal(np.array(got["labels"][:, :-1]),
                                  plain["tokens"][:, 1:])
    assert (np.array(got["labels"][:, -1]) == -1).all()


def test_encrypted_ciphertext_hides_plaintext():
    cfg = get_config("granite-3-8b", smoke=True)
    src = SyntheticLM(cfg, 2, 40, seed=3)
    cipher = make_cipher("hera-128a", seed=9)
    enc = EncryptedSource(src, cipher)
    ct = np.array(enc.batch_at(0)["ct"], dtype=np.uint64)
    toks = src.batch_at(0)["tokens"]
    # ciphertext must look uniform over Z_q, not like small token ids
    assert ct.mean() > 0.2 * cipher.params.mod.q
    assert (ct.astype(np.int64) != toks).mean() > 0.99


# ---------------------------------------------------------------------------
# elastic / straggler
# ---------------------------------------------------------------------------
def test_plan_mesh_shrinks_data_axis():
    p = plan_mesh(256, model=16)
    assert p.mesh_shape == (16, 16) and p.dropped == 0
    p = plan_mesh(250, model=16)           # lost 6 chips -> dp 8
    assert p.mesh_shape == (8, 16) and p.dropped == 250 - 128
    p = plan_mesh(512, model=16, multi_pod=True)
    assert p.mesh_shape == (2, 16, 16)
    with pytest.raises(RuntimeError):
        plan_mesh(8, model=16)


def test_straggler_watchdog_fires_on_sustained_slowdown():
    w = StragglerWatchdog(patience=3, warmup=2)
    fired = []
    for step in range(30):
        t = 1.0 if step < 20 else 5.0
        if w.observe(step, t):
            fired.append(step)
    assert fired and fired[0] >= 22
    assert w.events[0]["action"] == "checkpoint+evict+restart"


def test_watchdog_tolerates_single_spike():
    w = StragglerWatchdog(patience=3, warmup=2)
    fired = [w.observe(s, 5.0 if s == 15 else 1.0) for s in range(30)]
    assert not any(fired)
