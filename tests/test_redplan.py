"""Reduction-scheduling pass (src/repro/core/redplan.py, DESIGN.md §14).

Claims under test:

  * the PLAN derivation is deterministic, cached, and shaped by the
    shipped policy: defer-out ARKs feeding static MRMCs, lazy-accumulate
    static mixes, lazy-dense + fold-mix streamed PASTA affine layers,
    everything else eager — and every plan passes its own validate();
  * BIT-EXACTNESS: lazy ≡ eager keystream across presets x variants x
    noise x engines — the pass moves reduces, it never moves residues
    (this is why the golden digests do not change);
  * the TERMINAL-REDUCTION LAW is two-sided on over-deferred plans
    (tests/broken_schedules.py BROKEN_PLANS): ``ReductionPlan.validate``
    REFUSES, ``lint(sched, plan=...)`` DIAGNOSES (SA111), and the
    overflow prover leaves the terminal obligation undischarged;
  * the RELAXED modmath primitives (deferred-output mul, lazy shift-add
    matvec, lazy dense matvec) land on the same canonical residues as
    the legacy eager ones;
  * the COST model records a strictly positive saving for every preset
    (the delta the analysis snapshot gates on).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from broken_schedules import BROKEN_PLANS
from repro.analysis.bounds import prove_overflow_safety
from repro.analysis.cost import reduction_report
from repro.analysis.lint import ERROR as LINT_ERROR
from repro.analysis.lint import lint as run_lint
from repro.core import redplan as RP
from repro.core import schedule as S
from repro.core.cipher import make_cipher
from repro.core.engine import make_engine
from repro.core.params import REGISTRY, get_params
from repro.core.schedule import VARIANTS
from repro.kernels.keystream.ops import keystream_kernel_apply
from repro.kernels.keystream.ref import keystream_ref

PRESETS = sorted(REGISTRY)
MATRIX = [(n, v) for n in PRESETS for v in VARIANTS]


def _plan(name, variant="normal", mode="lazy"):
    p = get_params(name)
    sched = p.schedule(variant)
    return p, sched, RP.plan_reductions(p, sched, mode)


# ==========================================================================
# Plan derivation
# ==========================================================================
@pytest.mark.parametrize("mode", RP.REDUCTION_MODES)
@pytest.mark.parametrize("name,variant", MATRIX)
def test_plans_validate_everywhere(name, variant, mode):
    p, sched, plan = _plan(name, variant, mode)
    assert plan.validate(sched) is plan
    assert len(plan.ops) == len(sched.ops)
    # terminal-reduction law holds by construction on shipped plans
    assert all(b <= plan.q for _, _, b in plan.terminal_sites(sched))


def test_plans_are_cached_and_deterministic():
    p, sched, plan = _plan("pasta-128l")
    assert RP.plan_reductions(p, sched, "lazy") is plan


def test_unknown_mode_rejected():
    p = get_params("hera-128a")
    with pytest.raises(ValueError, match="unknown reduction mode"):
        RP.plan_reductions(p, p.schedule("normal"), "sometimes")


@pytest.mark.parametrize("name,variant", MATRIX)
def test_eager_plan_is_the_identity_schedule(name, variant):
    _, sched, plan = _plan(name, variant, "eager")
    assert all(o.in_bound == plan.q and o.out_bound == plan.q
               and not o.flags for o in plan.ops)


@pytest.mark.parametrize("name", ["hera-128a", "rubato-128s", "rubato-128l"])
def test_lazy_plan_shape_static_matrix(name):
    """HERA/Rubato: every static MRMC lazy-accumulates; every ARK feeding
    one defers its output reduce (and only those ARKs run relaxed)."""
    _, sched, plan = _plan(name)
    deferred = False
    for i, op in enumerate(sched.ops):
        o = plan.op(i)
        if isinstance(op, S.MRMC) and not op.streams_matrix:
            assert o.has(RP.LAZY_ACCUMULATE), o
        elif isinstance(op, S.ARK):
            nxt = sched.ops[i + 1] if i + 1 < len(sched.ops) else None
            if isinstance(nxt, S.MRMC) and not nxt.streams_matrix:
                assert o.has(RP.DEFER_OUT) and o.out_bound == 2 * plan.q
                deferred = True
            else:
                assert o.out_bound == plan.q
        else:
            assert not o.flags, o
    assert deferred, "no ARK ever deferred — the pass did nothing"


@pytest.mark.parametrize("name", ["pasta-128s", "pasta-128l"])
def test_lazy_plan_shape_pasta(name):
    """PASTA: every streamed affine layer runs lazy-dense, and the
    branch-mixing ones fold the rc add + mix into one terminal reduce."""
    _, sched, plan = _plan(name)
    streams = [(i, op) for i, op in enumerate(sched.ops)
               if isinstance(op, S.MRMC) and op.streams_matrix]
    assert streams
    for i, op in streams:
        o = plan.op(i)
        assert o.has(RP.LAZY_DENSE), o
        assert o.has(RP.FOLD_MIX) == bool(op.mix_branches), o
        assert o.out_bound == plan.q  # dense path terminal-reduces inside


# ==========================================================================
# Bit-exactness: lazy == eager everywhere
# ==========================================================================
def _constants(name, lanes, with_noise):
    ci = make_cipher(name, seed=23)
    consts = ci.round_constant_stream(jnp.arange(lanes, dtype=jnp.uint32))
    noise = consts["noise"] if with_noise else None
    return ci, consts["rc"], noise, consts.get("mats")


@pytest.mark.parametrize("with_noise", [False, True])
@pytest.mark.parametrize("name,variant", MATRIX)
def test_ref_lazy_matches_eager(name, variant, with_noise):
    p = get_params(name)
    if with_noise and not p.n_noise:
        pytest.skip("preset has no AGN noise (HERA)")
    ci, rc, noise, mats = _constants(name, 6, with_noise)
    eager = np.array(keystream_ref(p, ci.key, rc, noise, variant=variant,
                                   mats=mats, reduction="eager"))
    lazy = np.array(keystream_ref(p, ci.key, rc, noise, variant=variant,
                                  mats=mats, reduction="lazy"))
    np.testing.assert_array_equal(lazy, eager)
    assert lazy.max() < p.mod.q


@pytest.mark.parametrize("engine", ["ref", "jax"])
@pytest.mark.parametrize("name", PRESETS)
def test_engine_lazy_matches_eager(engine, name):
    p = get_params(name)
    ci, rc, noise, mats = _constants(name, 8, bool(p.n_noise))
    outs = {}
    for mode in RP.REDUCTION_MODES:
        eng = make_engine(engine, p, ci.key, reduction=mode)
        assert eng.reduction == mode
        outs[mode] = np.array(eng.keystream_from_constants(rc, noise, mats))
    np.testing.assert_array_equal(outs["lazy"], outs["eager"])


@pytest.mark.parametrize("name", ["hera-128a", "rubato-128s", "pasta-128s"])
def test_pallas_interpret_lazy_matches_eager(name):
    p = get_params(name)
    ci, rc, noise, mats = _constants(name, 4, bool(p.n_noise))
    eager = np.array(keystream_kernel_apply(
        p, ci.key, rc, noise, interpret=True, mats=mats, reduction="eager"))
    lazy = np.array(keystream_kernel_apply(
        p, ci.key, rc, noise, interpret=True, mats=mats, reduction="lazy"))
    np.testing.assert_array_equal(lazy, eager)


@pytest.mark.slow
@pytest.mark.parametrize("name", PRESETS)
@pytest.mark.parametrize("variant", VARIANTS)
def test_pallas_interpret_lazy_matches_eager_full(name, variant):
    p = get_params(name)
    ci, rc, noise, mats = _constants(name, 8, bool(p.n_noise))
    eager = np.array(keystream_kernel_apply(
        p, ci.key, rc, noise, interpret=True, variant=variant, mats=mats,
        reduction="eager"))
    lazy = np.array(keystream_kernel_apply(
        p, ci.key, rc, noise, interpret=True, variant=variant, mats=mats,
        reduction="lazy"))
    np.testing.assert_array_equal(lazy, eager)


# ==========================================================================
# Terminal-reduction law: the can-fail cases (two-sided + prover)
# ==========================================================================
@pytest.mark.parametrize(
    "build", [b for b, _ in BROKEN_PLANS], ids=[n for _, n in BROKEN_PLANS])
def test_over_deferred_plan_is_refused_and_diagnosed(build):
    sched, bad, code, match = build()
    with pytest.raises(ValueError, match=match):
        bad.validate(sched)
    findings = [f for f in run_lint(sched, plan=bad)
                if f.severity == LINT_ERROR]
    assert code in {f.code for f in findings}, [f.render() for f in findings]
    # without a plan the plan-aware rule must stay silent on a clean program
    assert not [f for f in run_lint(sched) if f.code == code]


@pytest.mark.parametrize(
    "build", [b for b, _ in BROKEN_PLANS], ids=[n for _, n in BROKEN_PLANS])
def test_prover_leaves_over_deferred_obligation_undischarged(build):
    sched, bad, _, _ = build()
    proof = prove_overflow_safety(get_params("pasta-128s"), sched, plan=bad)
    assert not proof.proved
    assert any("terminal-reduction" in c.provenance for c in proof.failures())


# ==========================================================================
# Relaxed modmath primitives land on the same residues
# ==========================================================================
def test_mul_deferred_output_reduces_to_canonical(rng):
    mod = get_params("pasta-128s").mod
    x = jnp.asarray(rng.integers(0, mod.q, 256, dtype=np.uint32))
    y = jnp.asarray(rng.integers(0, mod.q, 256, dtype=np.uint32))
    raw = mod.mul(x, y, reduce_out=False)
    np.testing.assert_array_equal(
        np.array(mod.reduce(raw, 3 * mod.q)), np.array(mod.mul(x, y)))


def test_mul_relaxed_input_bound(rng):
    mod = get_params("pasta-128s").mod
    x = jnp.asarray(rng.integers(0, mod.q, 256, dtype=np.uint32))
    y = jnp.asarray(rng.integers(0, mod.q, 256, dtype=np.uint32))
    assert mod.mul_fits(2 * mod.q, mod.q)
    got = mod.mul(x + jnp.uint32(mod.q), y, x_bound=2 * mod.q)
    np.testing.assert_array_equal(np.array(got), np.array(mod.mul(x, y)))


def test_matvec_small_lazy_matches_eager(rng):
    p = get_params("hera-128a")
    mat = p.mix_matrix()
    x = jnp.asarray(rng.integers(0, p.mod.q, (8, mat.shape[0]),
                                 dtype=np.uint32))
    np.testing.assert_array_equal(
        np.array(p.mod.matvec_small(mat, x, lazy=True)),
        np.array(p.mod.matvec_small(mat, x)))


@pytest.mark.parametrize("t", [16, 64])
def test_matvec_dense_lazy_matches_eager(t, rng):
    """t=16 is the single-chunk path; t=64 exercises the multi-chunk
    reshape + partial-sum fold (pasta-128l's shape)."""
    mod = get_params("pasta-128s").mod
    mat = jnp.asarray(rng.integers(0, mod.q, (t, t), dtype=np.uint32))
    x = jnp.asarray(rng.integers(0, mod.q, t, dtype=np.uint32))
    np.testing.assert_array_equal(
        np.array(mod.matvec_dense(mat, x, lazy=True)),
        np.array(mod.matvec_dense(mat, x)))
    # deferred products shrink the chunk cap: the lazy policy's constant
    assert mod.dense_chunk(3 * mod.q) < mod.dense_chunk()


def test_dense_chunk_schedule_divisor_policy():
    """The chunk is the largest DIVISOR of t under the uint32 cap — the
    reshape form that keeps the chunk sums fused (DESIGN.md §14); eager
    t=64 stays one whole-row pass, graph-identical to the pre-pass
    datapath."""
    mod = get_params("pasta-128l").mod
    assert mod.dense_chunk_schedule(64) == (64, 1)              # eager
    assert mod.dense_chunk_schedule(16, 3 * mod.q) == (16, 1)   # 128s lazy
    assert mod.dense_chunk_schedule(64, 3 * mod.q) == (16, 4)   # 128l lazy
    for t, pb in ((64, 3 * mod.q), (16, None)):
        ch, nch = mod.dense_chunk_schedule(t, pb)
        assert ch * nch == t and ch <= mod.dense_chunk(pb)


# ==========================================================================
# The static win the snapshot gates on
# ==========================================================================
@pytest.mark.parametrize("name", PRESETS)
def test_reduction_report_saves_steps(name):
    rep = reduction_report(get_params(name))
    assert rep.lazy_steps < rep.eager_steps
    assert rep.saved_steps == rep.eager_steps - rep.lazy_steps
    assert 0.0 < rep.saved_pct < 100.0


def test_tuner_plan_carries_reduction_mode():
    from repro.core.tuner import StreamPlan

    plan = StreamPlan(producer="counter", engine="jax", variant="normal",
                      window=64, depth=2, reduction="eager")
    assert StreamPlan.from_json(plan.to_json()) == plan
    # pre-pass cache entries (schema < 4) default to the shipped mode
    legacy = dict(plan.to_json())
    legacy.pop("reduction")
    assert StreamPlan.from_json(legacy).reduction == "lazy"
