"""Sampler statistical + structural tests."""

import numpy as np
import jax.numpy as jnp

from repro.crypto.modmath import Q_RUBATO
from repro.crypto.sampler import (
    DGaussTable, OVERDRAW, STREAM_PAD, discrete_gaussian, uniform_mod_q,
    uniform_mod_q_stream,
)
from repro.crypto.xof import aes_xof_words, threefry_xof_words


def test_uniform_overdraw_in_range_and_uniform(rng):
    nonce = np.arange(16, dtype=np.uint8)
    w = aes_xof_words(nonce, np.arange(128), 64 * OVERDRAW)
    w = jnp.asarray(np.array(w).reshape(128, 64, OVERDRAW))
    u = np.array(uniform_mod_q(w, Q_RUBATO)).ravel()
    assert (u < Q_RUBATO.q).all()
    # chi^2-ish: 16 buckets, ~512 each
    hist, _ = np.histogram(u, bins=16, range=(0, Q_RUBATO.q))
    expected = len(u) / 16
    chi2 = ((hist - expected) ** 2 / expected).sum()
    assert chi2 < 60, chi2   # df=15, very loose bound


def test_uniform_stream_compaction_prefers_accepted():
    # craft words: rejects (>= q under mask) must be skipped in order
    q = Q_RUBATO.q
    bad = np.uint32((1 << Q_RUBATO.bits) - 1)   # masked value >= q
    words = np.array([5, bad, 7, 11, bad, 13] + [17] * STREAM_PAD,
                     dtype=np.uint32)
    out = np.array(uniform_mod_q_stream(jnp.asarray(words), 4, Q_RUBATO))
    np.testing.assert_array_equal(out, [5, 7, 11, 13])


def test_dgauss_moments_and_support():
    t = DGaussTable.build(1.6)
    nonce = np.arange(16, dtype=np.uint8)
    hi = np.array(aes_xof_words(nonce, np.arange(200), 64))
    lo = np.array(aes_xof_words(nonce, np.arange(200) + 999, 64))
    e = np.array(discrete_gaussian(jnp.asarray(hi), jnp.asarray(lo), t)).ravel()
    assert (np.abs(e) <= t.tail).all()
    assert abs(e.mean()) < 0.05
    assert abs(e.std() - 1.6) < 0.05


def test_xof_backends_deterministic_and_distinct():
    nonce = np.arange(16, dtype=np.uint8)
    a1 = np.array(aes_xof_words(nonce, np.arange(4), 16))
    a2 = np.array(aes_xof_words(nonce, np.arange(4), 16))
    th = np.array(threefry_xof_words(nonce, np.arange(4), 16))
    np.testing.assert_array_equal(a1, a2)
    assert a1.shape == th.shape == (4, 16)
    assert not np.array_equal(a1, th)
    # different lanes differ
    assert not np.array_equal(a1[0], a1[1])
