"""AES-128 conformance (FIPS-197) and CTR keystream tests."""

import numpy as np
import jax.numpy as jnp

from repro.crypto.aes import (
    _SBOX_NP, aes128_encrypt_blocks, aes128_key_expand, aes_ctr_keystream,
)


def test_sbox_known_entries():
    assert _SBOX_NP[0x00] == 0x63
    assert _SBOX_NP[0x01] == 0x7C
    assert _SBOX_NP[0x53] == 0xED
    assert _SBOX_NP[0xFF] == 0x16
    # S-box is a permutation
    assert len(set(_SBOX_NP.tolist())) == 256


def test_fips197_c1():
    key = np.arange(16, dtype=np.uint8)
    pt = np.frombuffer(bytes.fromhex("00112233445566778899aabbccddeeff"),
                       np.uint8)
    rk = aes128_key_expand(key)
    ct = np.array(aes128_encrypt_blocks(jnp.asarray(pt)[None],
                                        jnp.asarray(rk)))[0]
    assert ct.tobytes().hex() == "69c4e0d86a7b0430d8cdb78070b4c55a"


def test_fips197_appendix_b():
    key = np.frombuffer(bytes.fromhex("2b7e151628aed2a6abf7158809cf4f3c"),
                        np.uint8)
    pt = np.frombuffer(bytes.fromhex("3243f6a8885a308d313198a2e0370734"),
                       np.uint8)
    rk = aes128_key_expand(key)
    ct = np.array(aes128_encrypt_blocks(jnp.asarray(pt)[None],
                                        jnp.asarray(rk)))[0]
    assert ct.tobytes().hex() == "3925841d02dc09fbdc118597196a0b32"


def test_key_expand_fips197_last_word():
    # FIPS-197 A.1: last round key word for the appendix-B key is b6630ca6
    key = np.frombuffer(bytes.fromhex("2b7e151628aed2a6abf7158809cf4f3c"),
                        np.uint8)
    rk = aes128_key_expand(key)
    assert rk[10, 12:16].tobytes().hex() == "b6630ca6"


def test_ctr_keystream_batched_matches_single(rng):
    key = rng.integers(0, 256, 16, dtype=np.uint8)
    rk = aes128_key_expand(key)
    nonce = rng.integers(0, 256, 12, dtype=np.uint8)
    ks = np.array(aes_ctr_keystream(rk, nonce, 5, 8))
    # block i equals encrypting nonce||ctr=5+i
    for i in range(8):
        ctr = 5 + i
        blk = np.concatenate([
            nonce,
            np.array([(ctr >> 24) & 255, (ctr >> 16) & 255,
                      (ctr >> 8) & 255, ctr & 255], np.uint8),
        ])
        want = np.array(aes128_encrypt_blocks(jnp.asarray(blk)[None],
                                              jnp.asarray(rk)))[0]
        np.testing.assert_array_equal(ks[i], want)
