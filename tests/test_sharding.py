"""Sharding-policy invariants: every assigned arch must produce divisible
shardings on the production mesh axes (GSPMD rejects non-divisible)."""

import pytest

from repro.configs.base import get_config, list_archs
from repro.launch.cells import SHAPES, cell_applicable
from repro.models.sharding import _largest_div

MODEL_AXIS = 16
DP = 16


def _policy_numbers(cfg):
    heads = cfg.num_heads or cfg.ssm_heads
    tp = _largest_div(heads, MODEL_AXIS)
    import math
    tp_a = math.gcd(cfg.kv_heads, tp) if cfg.kv_heads else tp
    while tp % tp_a:
        tp_a //= 2
    tp_b = tp // tp_a
    sp = MODEL_AXIS // tp
    return tp, tp_a, tp_b, sp


@pytest.mark.parametrize("arch", list_archs())
def test_divisibility_invariants(arch):
    cfg = get_config(arch)
    tp, tp_a, tp_b, sp = _policy_numbers(cfg)
    assert tp_a * tp_b * sp == MODEL_AXIS
    if cfg.num_heads:
        assert cfg.num_heads % (tp_a * tp_b) == 0, "q heads shard over tp"
        assert cfg.kv_heads % tp_a == 0, "kv heads shard over tp_a"
        g = cfg.num_heads // cfg.kv_heads
        assert g % tp_b == 0, "query groups shard over tp_b"
    if cfg.d_ff:
        assert cfg.d_ff % MODEL_AXIS == 0, "FFN features shard over model"
    assert cfg.vocab_padded % 128 == 0
    assert cfg.vocab_padded % MODEL_AXIS == 0
    if cfg.ssm_state:
        assert cfg.d_inner % MODEL_AXIS == 0
        assert cfg.ssm_heads % MODEL_AXIS == 0
    if cfg.num_experts:
        # experts x features must cover the model axis
        e = cfg.num_experts
        covered = 1
        for size in (tp_a, tp_b, sp):
            if size > 1 and e % (covered * size) == 0:
                covered *= size
        rem = MODEL_AXIS // covered
        assert cfg.d_ff % rem == 0, "leftover axes shard expert FFN features"


@pytest.mark.parametrize("arch", list_archs())
def test_batch_shardability(arch):
    for sname, shape in SHAPES.items():
        ok, _ = cell_applicable(arch, sname)
        if not ok:
            continue
        gb = shape.global_batch
        # either batch shards over dp, or batch==1 and we sequence-shard
        assert gb % DP == 0 or gb == 1, (arch, sname, gb)
        if gb == 1:
            assert shape.seq_len % DP == 0


def test_expected_tp_assignments():
    expect = {
        "internlm2-20b": (16, 8, 2, 1),
        "granite-3-8b": (16, 8, 2, 1),
        "deepseek-7b": (16, 16, 1, 1),
        "gemma2-9b": (16, 8, 2, 1),
        "qwen2-vl-7b": (4, 4, 1, 4),
        "hubert-xlarge": (16, 16, 1, 1),
        "mamba2-2.7b": (16, 16, 1, 1),
        "mixtral-8x7b": (16, 8, 2, 1),
        "arctic-480b": (8, 8, 1, 2),
        "jamba-1.5-large": (16, 8, 2, 1),
    }
    for arch, want in expect.items():
        got = _policy_numbers(get_config(arch))
        assert got == want, (arch, got, want)


def test_cell_skips_are_exactly_as_designed():
    skips = [(a, s) for a in list_archs() for s in SHAPES
             if not cell_applicable(a, s)[0]]
    want = {
        ("hubert-xlarge", "decode_32k"), ("hubert-xlarge", "long_500k"),
        ("internlm2-20b", "long_500k"), ("granite-3-8b", "long_500k"),
        ("deepseek-7b", "long_500k"), ("gemma2-9b", "long_500k"),
        ("qwen2-vl-7b", "long_500k"), ("mixtral-8x7b", "long_500k"),
        ("arctic-480b", "long_500k"),
    }
    assert set(skips) == want
    # 40 cells - 9 skips = 31 runnable
    assert 4 * len(list_archs()) - len(skips) == 31
