"""Blockwise attention vs naive reference; MoE capacity semantics; Mamba2
SSD vs naive recurrence."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.models.attention import blockwise_attention, decode_attention
from repro.models.mamba2 import ssd_chunked, ssd_decode, causal_conv, conv_decode


def naive_attention(q, k, v, causal, window=0, softcap=0.0):
    B, T, K, G, hd = q.shape
    S = k.shape[1]
    s = np.einsum("btkgd,bskd->btkgs", q, k) / np.sqrt(hd)
    if softcap:
        s = np.tanh(s / softcap) * softcap
    mask = np.ones((T, S), bool)
    if causal:
        mask &= np.tril(np.ones((T, S), bool))
    if window:
        qpos = np.arange(T)[:, None]
        kpos = np.arange(S)[None, :]
        mask &= kpos >= qpos - window
    s = np.where(mask[None, :, None, None, :], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("btkgs,bskd->btkgd", p, v)


@pytest.mark.parametrize("causal,window,softcap,qc,kc", [
    (True, 0, 0.0, 8, 8),
    (True, 0, 0.0, 16, 4),
    (True, 12, 0.0, 8, 8),
    (True, 0, 30.0, 8, 8),
    (False, 0, 0.0, 8, 8),
    (True, 5, 50.0, 4, 4),
])
def test_blockwise_matches_naive(causal, window, softcap, qc, kc, rng):
    B, T, K, G, hd = 2, 32, 2, 3, 16
    q = rng.normal(0, 1, (B, T, K, G, hd)).astype(np.float32)
    k = rng.normal(0, 1, (B, T, K, hd)).astype(np.float32)
    v = rng.normal(0, 1, (B, T, K, hd)).astype(np.float32)
    got = np.array(blockwise_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=causal, window=window, softcap=softcap, q_chunk=qc, k_chunk=kc))
    want = naive_attention(q, k, v, causal, window, softcap)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_decode_attention_matches_last_row(rng):
    B, T, K, G, hd = 2, 24, 2, 2, 16
    q = rng.normal(0, 1, (B, T, K, G, hd)).astype(np.float32)
    k = rng.normal(0, 1, (B, T, K, hd)).astype(np.float32)
    v = rng.normal(0, 1, (B, T, K, hd)).astype(np.float32)
    full = naive_attention(q, k, v, causal=True)
    # cache longer than cur_len, garbage in the tail
    pad = 8
    kc = np.concatenate([k, rng.normal(5, 3, (B, pad, K, hd))], 1).astype(np.float32)
    vc = np.concatenate([v, rng.normal(5, 3, (B, pad, K, hd))], 1).astype(np.float32)
    got = np.array(decode_attention(
        jnp.asarray(q[:, -1:]), jnp.asarray(kc), jnp.asarray(vc),
        jnp.asarray(T)))
    np.testing.assert_allclose(got[:, 0], full[:, -1], rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------
def naive_ssd(x, dt, A, Bm, Cm):
    B, T, H, P = x.shape
    S = Bm.shape[-1]
    h = np.zeros((B, H, P, S))
    ys = []
    for t in range(T):
        decay = np.exp(dt[:, t] * A)                      # (B,H)
        inc = np.einsum("bh,bs,bhp->bhps", dt[:, t], Bm[:, t], x[:, t])
        h = h * decay[:, :, None, None] + inc
        ys.append(np.einsum("bs,bhps->bhp", Cm[:, t], h))
    return np.stack(ys, 1), h


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_recurrence(chunk, rng):
    B, T, H, P, S = 2, 16, 3, 4, 5
    x = rng.normal(0, 1, (B, T, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (B, T, H)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (H,)).astype(np.float32)
    Bm = rng.normal(0, 1, (B, T, S)).astype(np.float32)
    Cm = rng.normal(0, 1, (B, T, S)).astype(np.float32)
    y, hf = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                        jnp.asarray(Bm), jnp.asarray(Cm), chunk)
    want_y, want_h = naive_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.array(y), want_y, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.array(hf), want_h, rtol=2e-4, atol=2e-4)


def test_ssd_decode_continues_chunked(rng):
    B, T, H, P, S = 1, 8, 2, 3, 4
    x = rng.normal(0, 1, (B, T + 1, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (B, T + 1, H)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (H,)).astype(np.float32)
    Bm = rng.normal(0, 1, (B, T + 1, S)).astype(np.float32)
    Cm = rng.normal(0, 1, (B, T + 1, S)).astype(np.float32)
    y_full, _ = naive_ssd(x, dt, A, Bm, Cm)
    _, h = ssd_chunked(jnp.asarray(x[:, :T]), jnp.asarray(dt[:, :T]),
                       jnp.asarray(A), jnp.asarray(Bm[:, :T]),
                       jnp.asarray(Cm[:, :T]), 4)
    y1, _ = ssd_decode(jnp.asarray(x[:, T]), jnp.asarray(dt[:, T]),
                       jnp.asarray(A), jnp.asarray(Bm[:, T]),
                       jnp.asarray(Cm[:, T]), h)
    np.testing.assert_allclose(np.array(y1), y_full[:, T], rtol=2e-4,
                               atol=2e-4)


def test_causal_conv_matches_decode_path(rng):
    B, T, Cn, W = 2, 10, 6, 4
    u = rng.normal(0, 1, (B, T, Cn)).astype(np.float32)
    w = rng.normal(0, 1, (W, Cn)).astype(np.float32)
    full = np.array(causal_conv(jnp.asarray(u), jnp.asarray(w)))
    # step-by-step decode must match
    state = jnp.zeros((B, W - 1, Cn))
    for t in range(T):
        y, state = conv_decode(jnp.asarray(u[:, t]), state, jnp.asarray(w))
        np.testing.assert_allclose(np.array(y), full[:, t], rtol=1e-5,
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def test_moe_matches_dense_mixture_when_capacity_ample(rng):
    from repro.configs.base import LayerSpec, ModelConfig
    from repro.models.moe import moe_ffn

    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        kv_heads=1, d_ff=32, vocab=64, group=(LayerSpec(moe=True),),
        num_experts=4, top_k=2, capacity_factor=8.0,  # nothing dropped
    )
    B, T, D, E, F = 2, 8, 16, 4, 32
    x = rng.normal(0, 0.5, (B, T, D)).astype(np.float32)
    router = rng.normal(0, 0.5, (D, E)).astype(np.float32)
    wi_g = rng.normal(0, 0.5, (E, D, F)).astype(np.float32)
    wi_u = rng.normal(0, 0.5, (E, D, F)).astype(np.float32)
    wo = rng.normal(0, 0.5, (E, F, D)).astype(np.float32)

    y, aux = moe_ffn(cfg, jnp.asarray(x), jnp.asarray(router),
                     jnp.asarray(wi_g), jnp.asarray(wi_u), jnp.asarray(wo))

    # naive per-token top-2 mixture
    def silu(a):
        return a / (1 + np.exp(-a))
    logits = x.reshape(-1, D) @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    want = np.zeros((B * T, D), np.float32)
    for n in range(B * T):
        top = np.argsort(-probs[n])[:2]
        g = probs[n][top] / probs[n][top].sum()
        for gi, e in zip(g, top):
            h = silu(x.reshape(-1, D)[n] @ wi_g[e]) * (x.reshape(-1, D)[n] @ wi_u[e])
            want[n] += gi * (h @ wo[e])
    np.testing.assert_allclose(np.array(y).reshape(-1, D), want,
                               rtol=2e-3, atol=2e-3)
    assert 0.5 < float(aux) < 4.0  # load-balance loss near 1 for random router


def test_moe_capacity_drops_tokens(rng):
    from repro.configs.base import LayerSpec, ModelConfig
    from repro.models.moe import moe_ffn

    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=8, num_heads=2,
        kv_heads=1, d_ff=16, vocab=64, group=(LayerSpec(moe=True),),
        num_experts=2, top_k=1, capacity_factor=0.25,  # aggressive drop
    )
    x = rng.normal(0, 1, (1, 16, 8)).astype(np.float32)
    router = rng.normal(0, 1, (8, 2)).astype(np.float32)
    wi_g = rng.normal(0, 1, (2, 8, 16)).astype(np.float32)
    wi_u = rng.normal(0, 1, (2, 8, 16)).astype(np.float32)
    wo = rng.normal(0, 1, (2, 16, 8)).astype(np.float32)
    y, _ = moe_ffn(cfg, jnp.asarray(x), jnp.asarray(router),
                   jnp.asarray(wi_g), jnp.asarray(wi_u), jnp.asarray(wo))
    # some rows must be exactly zero (dropped tokens)
    norms = np.linalg.norm(np.array(y).reshape(16, 8), axis=-1)
    assert (norms == 0).any() and (norms > 0).any()
