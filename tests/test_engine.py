"""KeystreamEngine registry: capability reporting, single-place "auto"
resolution, and the cross-backend bit-exactness matrix (ISSUE acceptance:
every registered engine produces identical keystream for HERA, Rubato,
AND PASTA across all CipherParams presets, with and without AGN noise,
under both schedule-orientation variants).

scripts/ci.sh runs this file in its engine-matrix stage so backend drift
fails fast.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    CipherBatch,
    KeystreamFarm,
    engine_caps,
    make_cipher,
    make_engine,
    registered_engines,
    resolve_engine,
)
from repro.core.engine import PallasInterpretEngine
from repro.core.params import get_params
from repro.kernels.keystream.ref import keystream_ref

# every preset in core/params.py REGISTRY; every engine that can run on any
# backend (compiled "pallas" and "sharded" need TPU / a mesh — covered
# separately below); both schedule-orientation variants (core/schedule.py)
PRESETS = ["hera-128a", "rubato-128s", "rubato-128m", "rubato-128l",
           "pasta-128s", "pasta-128l"]
PORTABLE_ENGINES = ["ref", "jax", "pallas-interpret"]
VARIANTS = ["normal", "alternating"]
LANES = 3


def _constants(name, with_noise):
    ci = make_cipher(name, seed=17)
    consts = ci.round_constant_stream(jnp.arange(LANES, dtype=jnp.uint32))
    noise = consts["noise"] if with_noise else None
    return ci, consts["rc"], noise, consts.get("mats")


# ---------------------------------------------------------------------------
# The engine matrix: bit-exactness across backends and schedule variants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("with_noise", [False, True])
@pytest.mark.parametrize("name", PRESETS)
@pytest.mark.parametrize("engine", PORTABLE_ENGINES)
def test_engine_matrix_bit_exact(engine, name, with_noise, variant):
    p = get_params(name)
    if with_noise and not p.n_noise:
        pytest.skip("preset has no AGN noise (HERA)")
    ci, rc, noise, mats = _constants(name, with_noise)
    want = np.array(keystream_ref(p, ci.key, rc, noise, mats=mats))
    eng = make_engine(engine, p, ci.key, variant=variant)
    assert eng.variant == variant
    got = np.array(eng.keystream_from_constants(rc, noise, mats))
    np.testing.assert_array_equal(got, want)
    assert got.shape == (LANES, p.l)


def test_sharded_engine_matches_ref_on_host_mesh():
    """'sharded' needs a mesh; on a 1-wide axis it must equal the oracle."""
    ci = make_cipher("hera-128a", seed=17)
    mesh = jax.make_mesh((1,), ("data",))
    eng = make_engine("sharded", ci.params, ci.key, mesh=mesh)
    rc = ci.round_constant_stream(jnp.arange(LANES, dtype=jnp.uint32))["rc"]
    np.testing.assert_array_equal(
        np.array(eng.keystream_from_constants(rc)),
        np.array(keystream_ref(ci.params, ci.key, rc, None)))


def test_engines_consume_constants_dict():
    ci, rc, noise, _ = _constants("rubato-128s", True)
    eng = make_engine("jax", ci.params, ci.key)
    np.testing.assert_array_equal(
        np.array(eng({"rc": rc, "noise": noise})),
        np.array(keystream_ref(ci.params, ci.key, rc, noise)))


# ---------------------------------------------------------------------------
# Registry + capability reporting
# ---------------------------------------------------------------------------
def test_registry_contents():
    assert set(registered_engines()) >= {
        "ref", "jax", "pallas", "pallas-interpret", "sharded"}


def test_engine_caps_report():
    caps = engine_caps()
    assert set(caps) == set(registered_engines())
    assert caps["ref"].available and caps["jax"].available
    assert caps["pallas-interpret"].available
    assert caps["pallas-interpret"].max_lanes is not None
    # sharded without a mesh is unavailable, with a reason
    assert not caps["sharded"].available and caps["sharded"].reason
    assert engine_caps(mesh=jax.make_mesh((1,), ("data",)))[
        "sharded"].available
    if jax.default_backend() != "tpu":
        assert not caps["pallas"].available
        assert "pallas-interpret" in caps["pallas"].reason
    # schedule-variant reporting: every backend executes both orientation
    # plans; the unrolled kernel prefers the bubble-free alternating one
    for c in caps.values():
        assert set(c.schedule_variants) == {"normal", "alternating"}
        assert c.preferred_variant in c.schedule_variants
    assert caps["pallas"].preferred_variant == "alternating"
    assert caps["ref"].preferred_variant == "normal"


def test_engine_variant_auto_and_validation():
    ci = make_cipher("hera-128a", seed=1)
    eng = make_engine("pallas-interpret", ci.params, ci.key, variant="auto")
    assert eng.variant == "alternating"
    assert eng.schedule.name == "hera-128a/alternating"
    assert make_engine("jax", ci.params, ci.key, variant="auto").variant == \
        "normal"
    with pytest.raises(ValueError, match="schedule variant"):
        make_engine("ref", ci.params, ci.key, variant="diagonal")


def test_make_engine_instance_variant_contract():
    """A pre-bound engine passes through with its own plan (variant
    unspecified or matching), but an explicit contradicting variant must
    raise rather than be silently ignored."""
    ci = make_cipher("hera-128a", seed=1)
    eng = make_engine("jax", ci.params, ci.key, variant="alternating")
    assert make_engine(eng, ci.params, ci.key) is eng
    assert make_engine(eng, ci.params, ci.key,
                       variant="alternating") is eng
    with pytest.raises(ValueError, match="already executes"):
        make_engine(eng, ci.params, ci.key, variant="normal")
    with pytest.raises(ValueError, match="already executes"):
        KeystreamFarm(_batch_for(ci), engine=eng, variant="normal")


def _batch_for(ci):
    cb = CipherBatch(ci.params, key=np.asarray(ci.key), seed=9)
    cb.add_session()
    return cb


def test_engine_describe_table():
    from repro.core.engine import describe
    text = describe()
    for name in registered_engines():
        assert name in text
    assert "auto resolves to" in text


def test_resolve_auto_matches_backend():
    want = "pallas" if jax.default_backend() == "tpu" else "jax"
    assert resolve_engine("auto") == want


def test_resolve_legacy_kernel_alias():
    assert resolve_engine("kernel", interpret=True) == "pallas-interpret"
    assert resolve_engine("kernel", interpret=False) == "pallas"
    assert resolve_engine("pallas", interpret=True) == "pallas-interpret"
    if jax.default_backend() != "tpu":
        assert resolve_engine("kernel") == "pallas-interpret"
    # legacy "kernel" with a mesh sharded the lane axis; so does the alias
    mesh = jax.make_mesh((1,), ("data",))
    assert resolve_engine("kernel", mesh=mesh) == "sharded"


def test_farm_legacy_kernel_with_mesh_shards_and_matches():
    cb = CipherBatch("hera-128a", seed=6)
    cb.add_session()
    mesh = jax.make_mesh((1,), ("data",))
    farm = KeystreamFarm(cb, consumer="kernel", mesh=mesh, interpret=True)
    assert farm.engine.name == "sharded"
    z = np.array(farm.keystream(np.zeros(4, np.int64), np.arange(4)))
    want = np.array(cb.session_cipher(0).keystream(
        jnp.arange(4, dtype=jnp.uint32)))
    np.testing.assert_array_equal(z, want)


def test_unknown_engine_raises_listing_registry():
    with pytest.raises(ValueError, match="registered engines"):
        resolve_engine("vulkan")


def test_unavailable_engine_raises_with_reason():
    ci = make_cipher("hera-128a", seed=1)
    with pytest.raises(RuntimeError, match="needs a mesh"):
        make_engine("sharded", ci.params, ci.key)
    if jax.default_backend() != "tpu":
        with pytest.raises(RuntimeError, match="unavailable"):
            make_engine("pallas", ci.params, ci.key)


def test_interpret_engine_lane_cap():
    ci = make_cipher("hera-128a", seed=1)
    eng = make_engine("pallas-interpret", ci.params, ci.key)
    too_many = jnp.zeros(
        (PallasInterpretEngine.MAX_LANES + 1, ci.params.n_round_constants),
        jnp.uint32)
    with pytest.raises(ValueError, match="caps lanes"):
        eng.keystream_from_constants(too_many)


def test_make_engine_passes_instances_through():
    ci = make_cipher("hera-128a", seed=1)
    eng = make_engine("ref", ci.params, ci.key)
    assert make_engine(eng, ci.params, ci.key) is eng


def test_make_engine_rejects_mismatched_instance():
    """A pre-bound engine keyed differently from the pool would silently
    emit unmatchable keystream — must fail loudly instead."""
    a = make_cipher("hera-128a", seed=1)
    b = make_cipher("hera-128a", seed=2)
    r = make_cipher("rubato-128s", seed=1)
    eng = make_engine("ref", a.params, a.key)
    with pytest.raises(ValueError, match="different \\(params, key\\)"):
        make_engine(eng, b.params, b.key)      # same params, other key
    with pytest.raises(ValueError, match="different \\(params, key\\)"):
        make_engine(eng, r.params, r.key)      # other cipher entirely
    cb = CipherBatch("hera-128a", seed=9)
    cb.add_session()
    with pytest.raises(ValueError, match="different \\(params, key\\)"):
        KeystreamFarm(cb, engine=eng)


# ---------------------------------------------------------------------------
# Engine-routed call sites
# ---------------------------------------------------------------------------
def test_farm_accepts_engine_instance():
    """The farm consumer is pluggable: a pre-bound engine instance works."""
    cb = CipherBatch("rubato-128s", seed=3)
    cb.add_session()
    eng = cb.make_engine("jax")
    farm = KeystreamFarm(cb, engine=eng)
    assert farm.engine is eng and farm.consumer == "jax"
    sids, ctrs = np.zeros(4, np.int64), np.arange(4)
    z = np.array(farm.keystream(sids, ctrs))
    want = np.array(cb.session_cipher(0).keystream(
        jnp.arange(4, dtype=jnp.uint32)))
    np.testing.assert_array_equal(z, want)


def test_farm_rejects_engine_and_consumer_together():
    cb = CipherBatch("hera-128a", seed=3)
    cb.add_session()
    with pytest.raises(ValueError, match="not both"):
        KeystreamFarm(cb, engine="jax", consumer="jax")


def test_cipher_engine_override_bit_exact():
    ref = make_cipher("rubato-128l", seed=5)
    jit = make_cipher("rubato-128l", seed=5, engine="jax")
    ctrs = jnp.arange(4, dtype=jnp.uint32)
    np.testing.assert_array_equal(np.array(ref.keystream(ctrs)),
                                  np.array(jit.keystream(ctrs)))
