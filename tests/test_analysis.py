"""Static-analysis suite (src/repro/analysis/, docs/DESIGN.md §13).

Four claims under test:

  * the LINTER diagnoses every malformed-program fixture that
    ``Schedule.validate()`` refuses (tests/broken_schedules.py is the
    shared catalog) — with rule codes, provenance, and working
    suppression — and every registered preset x variant lints clean;
  * the OVERFLOW PROOF discharges every uint32-fit and
    reduce-completeness obligation on every preset x variant, and
    actually fails on a genuinely unsafe accumulation;
  * the DEPTH derivation matches the paper laws (HERA 2r, Rubato r,
    PASTA r+1) statically everywhere and the measured FV-circuit depth
    where we spend the compile;
  * the COST model is orientation-invariant, and its predicted
    per-engine ordering matches measured StreamPlan tables
    (tolerance-gated; synthetic tables here, the real cached lap in the
    `analyze` CI stage).
"""

import json
import pathlib

import pytest

from broken_schedules import ALL as BROKEN
from repro.analysis.lint import ERROR as LINT_ERROR
from repro.analysis.lint import lint as run_lint
from repro.analysis.lint import registered_rules
from repro.analysis.bounds import (
    PAPER_DEPTH,
    depth_report,
    prove_overflow_safety,
    static_depth,
)
from repro.analysis.cost import (
    MachineModel,
    analyze_cost,
    predict_engine_times,
    validate_measured_ordering,
)
from repro.core.params import REGISTRY, get_params
from repro.core.schedule import VARIANTS

MATRIX = [(n, v) for n in sorted(REGISTRY) for v in VARIANTS]


def _errors(findings):
    return [f for f in findings if f.severity == LINT_ERROR]


# ==========================================================================
# Linter
# ==========================================================================
@pytest.mark.parametrize("name,variant", MATRIX)
def test_registry_programs_lint_clean(name, variant):
    sched = get_params(name).schedule(variant)
    findings = run_lint(sched)
    assert not _errors(findings), [f.render() for f in findings]


@pytest.mark.parametrize(
    "build", [b for b, _ in BROKEN], ids=[n for _, n in BROKEN])
def test_linter_diagnoses_what_validate_refuses(build):
    broken, code, _ = build()
    findings = _errors(run_lint(broken))
    codes = {f.code for f in findings}
    assert code in codes, (
        f"expected {code} in {sorted(codes)}: "
        + "; ".join(f.render() for f in findings))
    # findings point at the program, not just at a boolean
    assert all(f.provenance or f.op_index is None for f in findings)


@pytest.mark.parametrize(
    "build", [b for b, _ in BROKEN], ids=[n for _, n in BROKEN])
def test_suppression_hides_exactly_the_listed_rule(build):
    import dataclasses

    broken, code, _ = build()
    remaining = {f.code for f in _errors(run_lint(broken, suppress=[code]))}
    assert code not in remaining
    # the schedule's own noqa field works the same way
    marked = dataclasses.replace(broken, suppress=(code,))
    assert code not in {f.code for f in _errors(run_lint(marked))}


def test_unknown_suppression_code_rejected():
    sched = get_params("hera-128a").schedule()
    with pytest.raises(ValueError, match="unknown lint rule code"):
        run_lint(sched, suppress=["SA999"])


def test_rule_catalog_registered():
    codes = {r.code for r in registered_rules()}
    assert {"SA101", "SA102", "SA103", "SA104", "SA105", "SA106",
            "SA107", "SA108", "SA109", "SA110", "SA201"} <= codes


# ==========================================================================
# Overflow proofs
# ==========================================================================
@pytest.mark.parametrize("name,variant", MATRIX)
def test_overflow_proved_everywhere(name, variant):
    params = get_params(name)
    proof = prove_overflow_safety(params, variant=variant)
    assert proof.proved, "\n".join(c.render() for c in proof.failures())
    assert proof.min_margin_bits >= 0
    # the proof is not vacuous: it discharged real per-op obligations
    assert len(proof.checks) > 50
    provs = {c.provenance for c in proof.checks}
    assert any("MRMC" in p for p in provs)
    assert any("NONLINEAR" in p for p in provs)


def test_unsafe_accumulation_actually_fails():
    """A mix coefficient big enough that c*q overflows uint32 must be
    caught — the proof machinery can say no."""
    mod = get_params("hera-128a").mod
    sites = mod.accumulate_sites((2**7, 1), site="synthetic row")
    assert not all(s.ok for s in sites)


def test_reduce_residual_bound_matches_runtime_semantics():
    """The residual walk is exact for the bounds the datapath uses: a
    value bounded by k*q conditional-subtracts down to a canonical
    residue for every k the programs produce."""
    mod = get_params("rubato-128l").mod
    for k in (2, 3, 4, 8):
        assert mod.reduce_residual_bound(k * mod.q) <= mod.q


# ==========================================================================
# Depth
# ==========================================================================
@pytest.mark.parametrize("name,variant", MATRIX)
def test_static_depth_matches_paper_law(name, variant):
    params = get_params(name)
    sched = params.schedule(variant)
    assert static_depth(sched) == PAPER_DEPTH[params.kind](params.rounds)


def test_depth_report_cross_checks_measured_circuit():
    rep = depth_report(get_params("hera-128a"), measure=True)
    assert rep.ok and rep.measured == rep.static == rep.paper == 10


# ==========================================================================
# Cost model
# ==========================================================================
@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_cost_is_orientation_invariant(name):
    """Eq. 2 makes flips free relabelings, so both variants of a preset
    must cost identically — the analytic model encodes that claim."""
    params = get_params(name)
    normal = analyze_cost(params, variant="normal")
    alt = analyze_cost(params, variant="alternating")
    assert normal.to_json() == {**alt.to_json(),
                                "schedule": normal.schedule}
    assert normal.modmul > 0 and normal.bytes_per_lane > 0
    assert normal.call_sites > 0


def test_cost_tracks_program_scale():
    """More rounds / bigger state -> strictly more work."""
    small = analyze_cost(get_params("pasta-128s"))
    large = analyze_cost(get_params("pasta-128l"))
    assert large.modadd > small.modadd
    assert large.bytes_per_lane > small.bytes_per_lane


def test_predicted_ordering_is_stable_on_cpu():
    """jax (fused jit) beats ref (eager per-site dispatch) beats
    pallas-interpret (interpreter) under the cpu machine model — the
    ordering the `analyze` CI stage validates against real measurements."""
    machine = MachineModel.for_backend("cpu")
    preds = predict_engine_times(get_params("rubato-128s"), lanes=8,
                                 engines=["ref", "jax", "pallas-interpret"],
                                 machine=machine)
    assert preds["jax"].seconds < preds["ref"].seconds
    assert preds["ref"].seconds < preds["pallas-interpret"].seconds
    assert preds["ref"].bound_by == "dispatch"


def _rows(jax_ms, ref_ms, window=8):
    return [
        {"producer": "aes", "engine": "jax", "variant": "normal",
         "window": window, "depth": 2, "p50_ms": jax_ms},
        {"producer": "aes", "engine": "ref", "variant": "normal",
         "window": window, "depth": 2, "p50_ms": ref_ms},
    ]


def test_measured_ordering_agreement_and_mismatch():
    params = get_params("rubato-128s")
    machine = MachineModel.for_backend("cpu")
    ok = validate_measured_ordering(params, _rows(0.5, 200.0),
                                    machine=machine)
    assert ok.ok and not ok.skipped
    assert ok.pairs[0].fast == "jax" and ok.pairs[0].agrees
    # the same gap the other way around must FAIL the model
    bad = validate_measured_ordering(params, _rows(200.0, 0.5),
                                     machine=machine)
    assert not bad.ok
    # a gap inside the tolerance is unranked, never a failure
    close = validate_measured_ordering(params, _rows(1.00, 1.05),
                                       machine=machine)
    assert close.ok and close.pairs[0].within_tolerance


def test_measured_ordering_skips_thin_tables():
    params = get_params("rubato-128s")
    rep = validate_measured_ordering(params, _rows(0.5, 200.0)[:1])
    assert rep.skipped and rep.ok is True or rep.pairs == ()


def test_tuner_persists_measurement_tables(tmp_path, monkeypatch):
    """save_plan(measurements=...) -> load_measurements round trip, with
    the nearest-lanes fallback load_plan also uses."""
    monkeypatch.setenv("REPRO_TUNER_CACHE",
                       str(tmp_path / "streamplans.json"))
    from repro.core.tuner import StreamPlan, load_measurements, save_plan

    params = get_params("rubato-128s")
    plan = StreamPlan(producer="aes", engine="jax", variant="normal",
                      window=8, depth=2)
    rows = [{**plan.to_json(), "p50_ms": 0.5},
            {**plan.to_json(), "engine": "ref", "p50_ms": 200.0}]
    save_plan(params, 8, plan, p50_ms=0.5, measurements=rows)
    got = load_measurements(params, lanes=8)
    assert [r["engine"] for r in got] == ["jax", "ref"]
    assert load_measurements(params, lanes=16)  # nearest-lanes fallback
    rep = validate_measured_ordering(
        params, got, machine=MachineModel.for_backend("cpu"))
    assert rep.ok


# ==========================================================================
# CLI + snapshot
# ==========================================================================
def test_cli_single_preset_json(capsys):
    from repro.analysis.__main__ import main

    rc = main(["pasta-128l", "--variant", "normal", "--format", "json",
               "--no-measure"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert out["ok"] and out["results"][0]["overflow"]["proved"]
    assert out["results"][0]["depth"]["static"] == 4  # r+1 @ r=3


def test_checked_in_snapshot_is_current():
    """The committed BENCH snapshot's analytic fields must match a fresh
    analysis exactly (the `analyze` CI stage gates on this too)."""
    from repro.analysis.__main__ import (
        DEFAULT_SNAPSHOT,
        build_snapshot,
        check_snapshot,
    )

    path = pathlib.Path(DEFAULT_SNAPSHOT)
    assert path.exists(), "run: python -m repro.analysis --all --write-snapshot"
    snap = json.loads(path.read_text())
    current = build_snapshot(measure=False, lanes=8)
    problems = check_snapshot(snap, current, strict=False)
    errors = [m for lvl, m in problems if lvl == "error"]
    assert not errors, errors
