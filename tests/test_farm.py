"""Multi-stream keystream farm: batched-session API, double-buffered
pipeline, serving loop, and streaming encrypted data plane.

The headline contract (ISSUE acceptance): the batched path is bit-exact
with the single-stream reference — CipherBatch.keystream equals
per-session Cipher.keystream for every (nonce, counter) pair.
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    CipherBatch,
    KeystreamFarm,
    WindowPlan,
    pack_windows,
    plan_windows,
)
from repro.core.params import get_params
from repro.data.encrypted import (
    FarmEncryptedSource,
    encrypt_tokens,
    make_decryptor,
)
from repro.data.pipeline import SyntheticLM, iterate_batches, make_source
from repro.serve.hhe_loop import HHERequest, HHEServer

FARM_PARAMS = ["hera-128a", "rubato-128s", "rubato-128l", "pasta-128s"]


def _oracle(cb, sids, ctrs):
    """Per-session single-stream Cipher keystream, lane order preserved."""
    sids = np.asarray(sids)
    ctrs = np.asarray(ctrs)
    out = np.empty((len(sids), cb.params.l), np.uint32)
    for s in np.unique(sids):
        m = sids == s
        out[m] = np.array(cb.session_cipher(int(s)).keystream(
            jnp.asarray(ctrs[m], jnp.uint32)))
    return out


# ---------------------------------------------------------------------------
# CipherBatch: the bit-exactness acceptance criterion
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", FARM_PARAMS)
def test_batched_keystream_bit_exact_with_single_stream(name):
    """Randomized cross-check: lanes mixing sessions and counters in
    arbitrary order must equal each session's own Cipher, element for
    element."""
    rng = np.random.default_rng(7)
    cb = CipherBatch(name, seed=5)
    cb.add_sessions(5)
    sids = rng.integers(0, 5, 24)
    ctrs = rng.integers(0, 2**20, 24)
    z = np.array(cb.keystream(sids, ctrs))
    np.testing.assert_array_equal(z, _oracle(cb, sids, ctrs))


def test_batched_keystream_threefry_backend():
    p = dataclasses.replace(
        get_params("rubato-128s"), name="rubato-128s-tf", xof="threefry")
    cb = CipherBatch(p, seed=5)
    cb.add_sessions(3)
    rng = np.random.default_rng(1)
    sids = rng.integers(0, 3, 8)
    ctrs = rng.integers(0, 2**16, 8)
    z = np.array(cb.keystream(sids, ctrs))
    np.testing.assert_array_equal(z, _oracle(cb, sids, ctrs))


def test_batched_encrypt_decrypt_roundtrip():
    cb = CipherBatch("rubato-128l", seed=2)
    cb.add_sessions(3)
    rng = np.random.default_rng(3)
    sids = rng.integers(0, 3, 9)
    ctrs = np.arange(9)
    m = rng.uniform(-8, 8, (9, cb.params.l)).astype(np.float32)
    ct = cb.encrypt(m, sids, ctrs, delta=4096.0)
    back = np.array(cb.decrypt(ct, sids, ctrs, delta=4096.0))
    assert np.abs(back - m).max() < 1 / 4096 + 1e-6


def test_session_windows_are_disjoint():
    cb = CipherBatch("hera-128a", seed=0)
    s = cb.add_session()
    w1, w2 = s.take_window(5), s.take_window(3)
    assert w1.tolist() == [0, 1, 2, 3, 4]
    assert w2.tolist() == [5, 6, 7]
    assert s.next_ctr == 8


def test_session_counter_space_exhaustion_raises():
    """Counters past 2^16 would alias earlier XOF streams (two-time pad);
    the cursor must refuse, not wrap."""
    from repro.core.cipher import SESSION_CTR_LIMIT

    cb = CipherBatch("hera-128a", seed=0)
    s = cb.add_session()
    s.take_window(SESSION_CTR_LIMIT - 1)
    s.take_window(1)                      # exactly at the limit: fine
    assert s.remaining() == 0
    with pytest.raises(RuntimeError, match="counter space exhausted"):
        s.take_window(1)


def test_session_overdraw_leaves_cursor_untouched():
    """An over-drawing take_window must consume NOTHING: a partial grant
    (or a moved cursor on refusal) would desynchronize client and server
    counter reservations."""
    from repro.core.cipher import SESSION_CTR_LIMIT

    cb = CipherBatch("hera-128a", seed=0)
    s = cb.add_session()
    s.take_window(SESSION_CTR_LIMIT - 3)        # 3 counters left
    for n in (4, 10, SESSION_CTR_LIMIT):        # every over-draw size
        with pytest.raises(RuntimeError, match="counter space exhausted"):
            s.take_window(n)
        assert s.remaining() == 3               # cursor never moved
    assert s.take_window(3).tolist() == [
        SESSION_CTR_LIMIT - 3, SESSION_CTR_LIMIT - 2, SESSION_CTR_LIMIT - 1]


def test_rotation_nonces_never_repeat():
    """Repeated rotations must always draw fresh nonces — a repeated nonce
    re-keys into an already-consumed XOF stream (two-time pad)."""
    cb = CipherBatch("rubato-128s", seed=27)
    s = cb.add_session()
    seen = {bytes(s.nonce)}
    for i in range(32):
        s = cb.rotate_session(s.index)
        nb = bytes(s.nonce)
        assert nb not in seen, f"nonce repeated at rotation {i}"
        seen.add(nb)
        assert s.generation == i + 1 and s.next_ctr == 0


def test_farm_plan_referencing_rotated_out_session_serves_new_generation():
    """A WindowPlan captured BEFORE a rotation but produced AFTER it is
    served from the live generation's table row (the old nonce's material
    is gone — rotation is a flush boundary, documented in
    CipherBatch.rotate_session): the output must match the NEW
    generation's oracle, never silently resurrect the old stream."""
    cb = CipherBatch("rubato-128s", seed=28)
    s = cb.add_session()
    farm = KeystreamFarm(cb, engine="jax")
    stale_plan = WindowPlan(np.zeros(4, np.int64), np.arange(4))
    z_old = np.array(farm.consume(farm.produce(stale_plan)))
    cb.rotate_session(s.index)
    z_after = np.array(farm.consume(farm.produce(stale_plan)))
    assert not np.array_equal(z_after, z_old)
    np.testing.assert_array_equal(
        z_after,
        np.array(cb.session_cipher(s.index).keystream(
            jnp.arange(4, dtype=jnp.uint32))))


def test_rotate_session_fresh_nonce_same_index():
    """Rotation retires the (nonce, counter) space: fresh nonce, cursor 0,
    same lane index, generation bumped — and the farm serves the new
    stream bit-exactly (table row rebuilt in place)."""
    cb = CipherBatch("rubato-128s", seed=31)
    s0 = cb.add_session()
    farm = KeystreamFarm(cb, engine="jax")
    s0.take_window(7)
    old_nonce = s0.nonce.copy()
    z_old = np.array(farm.consume(farm.produce(
        WindowPlan(np.zeros(4, np.int64), np.arange(4)))))
    s1 = cb.rotate_session(s0.index)
    assert s1.index == s0.index and s1.generation == 1
    assert s1.next_ctr == 0 and not np.array_equal(s1.nonce, old_nonce)
    z_new = np.array(farm.consume(farm.produce(
        WindowPlan(np.zeros(4, np.int64), np.arange(4)))))
    # same counters, different generation => different keystream ...
    assert not np.array_equal(z_new, z_old)
    # ... and bit-exact with the rotated session's single-stream view
    np.testing.assert_array_equal(
        z_new, np.array(cb.session_cipher(s1.index).keystream(
            jnp.arange(4, dtype=jnp.uint32))))


def test_session_pool_growth_after_first_dispatch():
    """Adding sessions after a jit'd dispatch must not serve stale tables."""
    cb = CipherBatch("hera-128a", seed=4)
    cb.add_session()
    farm = KeystreamFarm(cb, consumer="jax")
    plan = WindowPlan(np.zeros(4, np.int64), np.arange(4))
    _ = np.array(farm.consume(farm.produce(plan)))
    late = cb.add_session()
    plan2 = WindowPlan(np.full(4, late.index, np.int64), np.arange(4))
    z = np.array(farm.consume(farm.produce(plan2)))
    want = np.array(
        cb.session_cipher(late.index).keystream(
            jnp.arange(4, dtype=jnp.uint32)))
    np.testing.assert_array_equal(z, want)


# ---------------------------------------------------------------------------
# Farm pipeline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("interleave", [True, False])
def test_plan_windows_covers_all_pairs(interleave):
    cb = CipherBatch("hera-128a", seed=1)
    sess = cb.add_sessions(3)
    plans = plan_windows(sess, blocks_per_session=4, window=6,
                         interleave=interleave)
    assert [p.lanes for p in plans] == [6, 6]
    pairs = {
        (int(s), int(c))
        for p in plans
        for s, c in zip(p.session_ids, p.block_ctrs)
    }
    assert pairs == {(s, c) for s in range(3) for c in range(4)}


def test_pack_windows_pads_ragged_tail_shape_stable():
    """THE window slicer: a non-dividing total pads the tail by repeating
    the last real lane (never fresh counters), so every window has the
    same shape — no per-tail-size recompile."""
    sids = np.array([0, 1, 2, 0, 1])
    ctrs = np.array([7, 8, 9, 10, 11])
    plans = pack_windows(sids, ctrs, window=3)
    assert [p.lanes for p in plans] == [3, 3]       # shape-stable
    assert [p.valid for p in plans] == [3, 2]
    # the pad repeats the last REAL lane of the tail
    assert plans[1].session_ids.tolist() == [0, 1, 1]
    assert plans[1].block_ctrs.tolist() == [10, 11, 11]


def test_pack_windows_rejects_bad_args():
    with pytest.raises(ValueError, match="positive"):
        pack_windows(np.zeros(2), np.zeros(2), 0)
    with pytest.raises(ValueError, match="mismatch"):
        pack_windows(np.zeros(2), np.zeros(3), 2)


@pytest.mark.parametrize("interleave", [True, False])
def test_plan_windows_ragged_tail_padded(interleave):
    """3 sessions x 3 blocks = 9 lanes into window=4: 3 shape-stable
    windows, tail valid=1, and the padded lanes still cover exactly the
    reserved (session, ctr) pairs."""
    cb = CipherBatch("hera-128a", seed=2)
    sess = cb.add_sessions(3)
    plans = plan_windows(sess, blocks_per_session=3, window=4,
                         interleave=interleave)
    assert [p.lanes for p in plans] == [4, 4, 4]
    assert [p.valid for p in plans] == [4, 4, 1]
    pairs = {
        (int(s), int(c))
        for p in plans
        for s, c in zip(p.session_ids[: p.valid], p.block_ctrs[: p.valid])
    }
    assert pairs == {(s, c) for s in range(3) for c in range(3)}


def test_farm_keystream_ragged_window_trims_and_matches():
    """keystream() with a non-dividing window must pad+trim (same idiom as
    keystream_pallas ragged lanes) and stay bit-exact, lane for lane."""
    cb = CipherBatch("rubato-128s", seed=19)
    cb.add_sessions(2)
    sids = np.array([0, 1, 0, 1, 1, 0, 1])      # 7 lanes, window 3
    ctrs = np.array([0, 0, 1, 1, 2, 2, 3])
    farm = KeystreamFarm(cb, engine="jax")
    z = np.array(farm.keystream(sids, ctrs, window=3))
    assert z.shape == (7, cb.params.l)
    np.testing.assert_array_equal(z, _oracle(cb, sids, ctrs))


@pytest.mark.parametrize("depth", [1, 2, 3, 4])
def test_farm_depth_bit_exact(depth):
    """Pipeline depth is pure scheduling: every FIFO depth (serialized
    through deep buffering) yields identical keystream in order."""
    cb = CipherBatch("rubato-128s", seed=20)
    sess = cb.add_sessions(3)
    farm = KeystreamFarm(cb, engine="jax", depth=depth)
    assert farm.depth == depth
    plans = plan_windows(sess, blocks_per_session=4, window=6)
    seen = 0
    for plan, z in farm.run(plans):
        np.testing.assert_array_equal(
            np.array(z), _oracle(cb, plan.session_ids, plan.block_ctrs))
        seen += plan.lanes
    assert seen == 12


def test_farm_depth_validation():
    cb = CipherBatch("hera-128a", seed=1)
    cb.add_session()
    with pytest.raises(ValueError, match="depth"):
        KeystreamFarm(cb, engine="jax", depth=0)


def test_farm_depth3_overlaps_more_windows_in_flight():
    """Behavioral check on the FIFO: with depth=d, the first consume must
    not happen before d windows were produced (producers run ahead)."""
    cb = CipherBatch("hera-128a", seed=3)
    cb.add_session()
    farm = KeystreamFarm(cb, engine="jax", depth=3)
    events = []
    orig_produce, orig_consume = farm.produce, farm.consume
    farm.produce = lambda p: (events.append("p"), orig_produce(p))[1]
    farm.consume = lambda c: (events.append("c"), orig_consume(c))[1]
    plans = [WindowPlan(np.zeros(2, np.int64), np.arange(2) + 2 * i)
             for i in range(5)]
    list(farm.run(plans))
    assert events[:4] == ["p", "p", "p", "c"]     # 3 produced before 1st c
    assert events.count("p") == 5 and events.count("c") == 5


@pytest.mark.parametrize("mdepth", [1, 2, 3, 4])
def test_farm_matrix_depth_bit_exact(mdepth):
    """The matrix-plane prefetch FIFO is pure scheduling: every
    matrix_depth (split pipeline or fused produce) yields keystream
    bit-identical to the per-session oracle, in order."""
    cb = CipherBatch("pasta-128s", seed=23)
    sess = cb.add_sessions(3)
    farm = KeystreamFarm(cb, engine="jax", depth=2, matrix_depth=mdepth)
    assert farm.matrix_depth == mdepth
    assert farm._splits_planes == (mdepth > 1)
    plans = plan_windows(sess, blocks_per_session=4, window=6)
    seen = 0
    for plan, z in farm.run(plans):
        np.testing.assert_array_equal(
            np.array(z), _oracle(cb, plan.session_ids, plan.block_ctrs))
        seen += plan.lanes
    assert seen == 12


def test_farm_matrix_depth_validation():
    cb = CipherBatch("pasta-128s", seed=1)
    cb.add_session()
    with pytest.raises(ValueError, match="matrix prefetch depth"):
        KeystreamFarm(cb, engine="jax", matrix_depth=0)


def test_farm_matrix_fifo_runs_ahead_of_vector_pipeline():
    """Behavioral check on the split pipeline: with matrix_depth=m, the
    heavy matrix plane for m windows is dispatched before the FIRST
    vector-plane produce, and the vector FIFO still buffers ``depth``
    windows before the first consume — the two FIFOs are decoupled."""
    cb = CipherBatch("pasta-128s", seed=24)
    cb.add_session()
    farm = KeystreamFarm(cb, engine="jax", depth=2, matrix_depth=3)
    events = []
    om, op, oc = farm.produce_matrix, farm.produce, farm.consume
    farm.produce_matrix = lambda p: (events.append("m"), om(p))[1]
    farm.produce = lambda p, plane="all": (
        events.append(plane[0]), op(p, plane))[1]
    farm.consume = lambda c: (events.append("c"), oc(c))[1]
    plans = [WindowPlan(np.zeros(2, np.int64), np.arange(2) + 2 * i)
             for i in range(5)]
    list(farm.run(plans))
    # 3 matrix planes in flight before any vector produce; first consume
    # only after 2 vector windows (depth=2) are buffered
    assert events[:7] == ["m", "m", "m", "v", "m", "v", "c"]
    assert events.count("m") == 5
    assert events.count("v") == 5 and events.count("c") == 5


def test_farm_matrix_depth_noop_without_matrix_planes():
    """Presets without stream-sourced matrices (HERA) ignore the knob:
    no split pipeline, no matrix-plane dispatches, same keystream."""
    cb = CipherBatch("hera-128a", seed=2)
    cb.add_session()
    farm = KeystreamFarm(cb, engine="jax", matrix_depth=4)
    assert not farm._splits_planes
    calls = []
    om = farm.produce_matrix
    farm.produce_matrix = lambda p: (calls.append(p), om(p))[1]
    plan = WindowPlan(np.zeros(4, np.int64), np.arange(4))
    [(p, z)] = list(farm.run([plan]))
    assert not calls
    np.testing.assert_array_equal(
        np.array(z), _oracle(cb, plan.session_ids, plan.block_ctrs))


def test_farm_run_double_buffered_bit_exact():
    cb = CipherBatch("rubato-128s", seed=9)
    sess = cb.add_sessions(4)
    farm = KeystreamFarm(cb, consumer="jax")
    plans = plan_windows(sess, blocks_per_session=6, window=8)
    seen = 0
    for plan, z in farm.run(plans):
        np.testing.assert_array_equal(
            np.array(z), _oracle(cb, plan.session_ids, plan.block_ctrs))
        seen += plan.lanes
    assert seen == 24


def test_farm_kernel_consumer_matches_jax_consumer():
    cb = CipherBatch("hera-128a", seed=6)
    cb.add_sessions(2)
    plan = WindowPlan(np.array([0, 1, 1, 0]), np.array([0, 0, 1, 9]))
    jax_farm = KeystreamFarm(cb, consumer="jax")
    kern_farm = KeystreamFarm(cb, consumer="kernel", interpret=True)
    zj = np.array(jax_farm.consume(jax_farm.produce(plan)))
    zk = np.array(kern_farm.consume(kern_farm.produce(plan)))
    np.testing.assert_array_equal(zj, zk)


def test_farm_unknown_consumer_lists_registered_engines():
    """The old farm silently accepted unknown consumer strings; now both
    spellings fail fast with the registry listed."""
    cb = CipherBatch("hera-128a", seed=1)
    cb.add_session()
    with pytest.raises(ValueError, match="registered engines"):
        KeystreamFarm(cb, consumer="cuda")
    with pytest.raises(ValueError, match="registered engines"):
        KeystreamFarm(cb, engine="cuda")


def test_farm_keystream_windowed_equals_single_window():
    cb = CipherBatch("rubato-128s", seed=8)
    cb.add_sessions(2)
    sids = np.array([0, 1, 0, 1, 1, 0])
    ctrs = np.array([0, 0, 1, 1, 2, 2])
    farm = KeystreamFarm(cb, consumer="jax")
    whole = np.array(farm.keystream(sids, ctrs))
    chunked = np.array(farm.keystream(sids, ctrs, window=2))
    np.testing.assert_array_equal(whole, chunked)


# ---------------------------------------------------------------------------
# Serving loop
# ---------------------------------------------------------------------------
def test_hhe_server_mixed_ragged_traffic():
    cb = CipherBatch("rubato-128s", seed=12)
    srv = HHEServer(cb, window=8, consumer="jax")
    s0, s1 = srv.open_session(), srv.open_session()
    rng = np.random.default_rng(0)
    l = cb.params.l
    m0 = rng.uniform(-5, 5, (11, l)).astype(np.float32)
    srv.submit(HHERequest(session_id=s0.index, op="encrypt", payload=m0))
    srv.submit(HHERequest(session_id=s1.index, op="keystream", blocks=3))
    resp = srv.flush()
    assert len(resp) == 2

    # encrypt result decrypts with the session's own single-stream cipher
    ci = cb.session_cipher(s0.index)
    back = np.array(ci.decrypt(
        jnp.asarray(resp[0].result),
        jnp.asarray(resp[0].block_ctrs, jnp.uint32)))
    assert np.abs(back - m0).max() < 0.1

    # keystream result is the oracle keystream
    ci1 = cb.session_cipher(s1.index)
    want = np.array(ci1.keystream(
        jnp.asarray(resp[1].block_ctrs, jnp.uint32)))
    np.testing.assert_array_equal(resp[1].result, want)

    stats = srv.latency_stats()
    assert stats["count"] == 2 and stats["p99_ms"] >= stats["p50_ms"]


def test_hhe_server_decrypt_roundtrip():
    cb = CipherBatch("rubato-128s", seed=13)
    srv = HHEServer(cb, window=4, consumer="jax")
    s = srv.open_session()
    rng = np.random.default_rng(2)
    m = rng.uniform(-3, 3, (6, cb.params.l)).astype(np.float32)
    # client encrypts with the session cipher on counters [0, 6)
    ci = cb.session_cipher(s.index)
    ct = np.array(ci.encrypt(m, jnp.arange(6, dtype=jnp.uint32)))
    # server-side decrypt must consume the SAME counters: fresh session
    # cursor starts at 0, so a 6-block decrypt request lines up
    srv.submit(HHERequest(session_id=s.index, op="decrypt", payload=ct))
    (resp,) = srv.flush()
    assert np.abs(resp.result - m).max() < 0.1


def test_hhe_server_counter_reservation():
    cb = CipherBatch("hera-128a", seed=14)
    srv = HHEServer(cb, window=4, consumer="jax")
    s = srv.open_session()
    c1 = srv.submit(HHERequest(session_id=s.index, blocks=5))
    c2 = srv.submit(HHERequest(session_id=s.index, blocks=2))
    assert c1.tolist() == [0, 1, 2, 3, 4] and c2.tolist() == [5, 6]
    resp = srv.flush()
    assert [r.result.shape[0] for r in resp] == [5, 2]


def test_hhe_server_rejects_unknown_session():
    srv = HHEServer(CipherBatch("hera-128a", seed=15), window=4,
                    consumer="jax")
    with pytest.raises(KeyError, match="unknown session"):
        srv.submit(HHERequest(session_id=0, blocks=1))


def test_hhe_server_token_ops_roundtrip_exact():
    """encrypt_tokens/decrypt_tokens are exact Z_q (no fixed-point): the
    launch/serve.py --encrypted prompt/response path."""
    cb = CipherBatch("rubato-128s", seed=18)
    srv = HHEServer(cb, window=4, consumer="jax")
    s = srv.open_session()
    l = cb.params.l
    rng = np.random.default_rng(5)
    toks = rng.integers(0, 50000, (3, l)).astype(np.uint32)
    srv.submit(HHERequest(session_id=s.index, op="encrypt_tokens",
                          payload=toks))
    (enc,) = srv.flush()
    # ciphertext decrypts exactly with the session's single-stream view
    ci = cb.session_cipher(s.index)
    z = ci.keystream(jnp.asarray(enc.block_ctrs, jnp.uint32))
    np.testing.assert_array_equal(
        np.array(cb.params.mod.sub(jnp.asarray(enc.result), z)), toks)
    # and with a server-side decrypt_tokens request on fresh counters:
    # re-encrypt client-side at the next window, then ask the server
    ctrs2 = jnp.asarray(cb.sessions[s.index].next_ctr
                        + np.arange(3), jnp.uint32)
    ct2 = np.array(cb.params.mod.add(jnp.asarray(toks),
                                     ci.keystream(ctrs2)))
    srv.submit(HHERequest(session_id=s.index, op="decrypt_tokens",
                          payload=ct2))
    (dec,) = srv.flush()
    np.testing.assert_array_equal(dec.result, toks.astype(np.int32))


def test_hhe_loop_survives_session_rotation(monkeypatch):
    """A long-running serving loop must outlive the 2^16-block counter
    space: the server rotates the session (fresh nonce) instead of dying,
    and no (nonce, counter) pair is ever consumed twice."""
    import repro.core.cipher as cipher_mod

    monkeypatch.setattr(cipher_mod, "SESSION_CTR_LIMIT", 8)
    cb = CipherBatch("hera-128a", seed=30)
    srv = HHEServer(cb, window=3, consumer="jax")
    s = srv.open_session()
    seen_pairs = set()
    for step in range(10):
        srv.submit(HHERequest(session_id=s.index, op="keystream", blocks=3))
        (resp,) = srv.flush()
        nonce = bytes(cb.sessions[s.index].nonce)  # nonce for these ctrs
        for c in resp.block_ctrs:
            pair = (nonce, int(c))
            assert pair not in seen_pairs, "keystream reuse across rotation"
            seen_pairs.add(pair)
        # every response stays bit-exact with the live generation's oracle
        want = np.array(cb.session_cipher(s.index).keystream(
            jnp.asarray(resp.block_ctrs, jnp.uint32)))
        np.testing.assert_array_equal(resp.result, want)
    assert cb.sessions[s.index].generation >= 3   # rotations happened
    assert len(seen_pairs) == 30


def test_hhe_no_auto_rotation_for_decrypt_ops(monkeypatch):
    """Decrypt payloads are bound to the client's (nonce, counter) space:
    rotating under them would silently return garbage, so the server must
    refuse loudly instead."""
    import repro.core.cipher as cipher_mod

    monkeypatch.setattr(cipher_mod, "SESSION_CTR_LIMIT", 8)
    cb = CipherBatch("rubato-128s", seed=34)
    srv = HHEServer(cb, window=2, consumer="jax")
    s = srv.open_session()
    s.take_window(6)                      # 2 counters left
    ct = np.zeros((4, cb.params.l), np.uint32)
    for op in ("decrypt", "decrypt_tokens"):
        with pytest.raises(RuntimeError, match="counter space exhausted"):
            srv.submit(HHERequest(session_id=s.index, op=op, payload=ct))
    assert cb.sessions[s.index].generation == 0   # never rotated


def test_hhe_rotation_flushes_pending_old_nonce_lanes(monkeypatch):
    """Requests queued before a rotation must materialize under the OLD
    nonce — rotation is a flush boundary, not silent re-keying."""
    import repro.core.cipher as cipher_mod

    monkeypatch.setattr(cipher_mod, "SESSION_CTR_LIMIT", 8)
    cb = CipherBatch("hera-128a", seed=33)
    srv = HHEServer(cb, window=2, consumer="jax")
    s = srv.open_session()
    srv.submit(HHERequest(session_id=s.index, op="keystream", blocks=6))
    want_old = np.array(cb.session_cipher(s.index).keystream(
        jnp.arange(6, dtype=jnp.uint32)))
    # this submit cannot fit (6+6 > 8): server flushes the pending request
    # against the old nonce, then rotates
    srv.submit(HHERequest(session_id=s.index, op="keystream", blocks=6))
    assert cb.sessions[s.index].generation == 1
    resp_old, resp_new = srv.flush()         # submission order preserved
    np.testing.assert_array_equal(resp_old.result, want_old)
    np.testing.assert_array_equal(
        resp_new.result,
        np.array(cb.session_cipher(s.index).keystream(
            jnp.arange(6, dtype=jnp.uint32))))
    assert not np.array_equal(resp_new.result, want_old)


def test_farm_encrypt_decrypt_stream_roundtrip():
    cb = CipherBatch("rubato-128s", seed=16)
    sess = cb.add_sessions(2)
    farm = KeystreamFarm(cb, consumer="jax")
    rng = np.random.default_rng(4)
    enc_plans = plan_windows(sess, blocks_per_session=3, window=6)
    msgs = [rng.uniform(-4, 4, (p.lanes, cb.params.l)).astype(np.float32)
            for p in enc_plans]
    cts = [ct for _, ct in farm.encrypt_stream(zip(enc_plans, msgs))]
    # decrypt over the SAME (session, ctr) plans
    backs = [b for _, b in farm.decrypt_stream(zip(enc_plans, cts))]
    for m, b in zip(msgs, backs):
        assert np.abs(np.array(b) - m).max() < 0.1


# ---------------------------------------------------------------------------
# Encrypted data plane
# ---------------------------------------------------------------------------
def _tiny_cfg():
    from repro.configs.base import get_config
    return get_config("deepseek-7b", smoke=True)


def test_farm_encrypted_source_matches_encrypt_tokens():
    cfg = _tiny_cfg()
    src = SyntheticLM(cfg, batch=2, seq_len=16, seed=0)
    cb = CipherBatch("rubato-128l", seed=21)
    fsrc = FarmEncryptedSource(src, cb, consumer="jax")
    for step in (0, 3):
        got = fsrc.batch_at(step)
        want = encrypt_tokens(
            fsrc.cipher, src.batch_at(step)["tokens"],
            step * fsrc.blocks_per_batch())
        np.testing.assert_array_equal(np.array(got["ct"]),
                                      np.array(want["ct"]))
        assert int(got["base_ctr"]) == int(want["base_ctr"])


def test_farm_encrypted_source_stream_decrypts():
    cfg = _tiny_cfg()
    src = SyntheticLM(cfg, batch=2, seq_len=16, seed=0)
    cb = CipherBatch("rubato-128l", seed=22)
    fsrc = FarmEncryptedSource(src, cb, consumer="jax")
    dec = make_decryptor(fsrc.cipher)
    for step, enc in enumerate(iterate_batches(fsrc, n_steps=3)):
        out = dec(enc)
        np.testing.assert_array_equal(
            np.array(out["tokens"]), src.batch_at(step)["tokens"])


def test_iterate_batches_plain_source_fallback():
    cfg = _tiny_cfg()
    src = make_source(cfg, batch=2, seq_len=8, seed=1)
    got = list(iterate_batches(src, start_step=2, n_steps=2))
    np.testing.assert_array_equal(got[0]["tokens"],
                                  src.batch_at(2)["tokens"])
    np.testing.assert_array_equal(got[1]["tokens"],
                                  src.batch_at(3)["tokens"])
