"""Malformed-schedule fixtures shared by test_schedule and test_analysis.

Each builder perturbs a real preset program into one specific illegal
shape.  The contract under test is two-sided: ``Schedule.validate()``
must REFUSE the program (ValueError matching ``validate_match``) and the
static linter must DIAGNOSE it (a finding with ``lint_code``) — the
executor gate and the reviewer gate agree on what a well-formed program
is.
"""

import dataclasses

from repro.core import build_schedule
from repro.core import schedule as S
from repro.core.params import get_params


def _replace_op(sched, index, **fields):
    ops = list(sched.ops)
    ops[index] = dataclasses.replace(ops[index], **fields)
    return dataclasses.replace(sched, ops=tuple(ops))


def rc_slice_gap():
    """First ARK's constants start at 16, leaving rc[0:16] unconsumed."""
    sched = build_schedule(get_params("hera-128a"))
    i = next(i for i, op in enumerate(sched.ops) if isinstance(op, S.ARK))
    a, b = sched.ops[i].rc_slice
    broken = _replace_op(sched, i, rc_slice=(a + 16, b + 16))
    return broken, "SA101", "inconsistent"


def rc_slice_overlap():
    """Final ARK re-reads the previous ARK's constants."""
    sched = build_schedule(get_params("hera-128a"))
    i = max(i for i, op in enumerate(sched.ops) if isinstance(op, S.ARK))
    a, b = sched.ops[i].rc_slice
    broken = _replace_op(sched, i, rc_slice=(a - 16, b - 16))
    return broken, "SA101", "inconsistent"


def rc_slice_wrong_width():
    """ARK slice narrower than its key_len / the state width."""
    sched = build_schedule(get_params("hera-128a"))
    i = next(i for i, op in enumerate(sched.ops) if isinstance(op, S.ARK))
    a, b = sched.ops[i].rc_slice
    broken = _replace_op(sched, i, rc_slice=(a, b - 4))
    return broken, "SA102", "inconsistent"


def affine_rc_wrong_width():
    """PASTA affine layer consuming half a state's worth of constants."""
    sched = build_schedule(get_params("pasta-128s"))
    i = next(i for i, op in enumerate(sched.ops)
             if isinstance(op, S.MRMC) and op.has_rc)
    a, b = sched.ops[i].rc_slice
    broken = _replace_op(sched, i, rc_slice=(a, a + (b - a) // 2))
    return broken, "SA102", "affine MRMC .* inconsistent"


def orientation_chain_break():
    """Final ARK claims transposed state without an MRMC flip before it."""
    sched = build_schedule(get_params("hera-128a"), "alternating")
    broken = _replace_op(sched, len(sched.ops) - 1,
                         orientation=S.TRANSPOSED)
    return broken, "SA103", "expects transposed"


def ends_transposed():
    """A trailing flip that nothing undoes: the program ends transposed."""
    sched = build_schedule(get_params("hera-128a"))
    ops = sched.ops + (S.MRMC(out_orientation=S.TRANSPOSED),)
    broken = dataclasses.replace(sched, ops=ops)
    return broken, "SA104", "must end normal"


def truncate_transposed():
    """TRUNCATE applied to a transposed state (row-major slice would cut
    across logical columns)."""
    sched = build_schedule(get_params("hera-128a"))
    ops = sched.ops + (
        S.MRMC(out_orientation=S.TRANSPOSED),
        S.TRUNCATE(orientation=S.TRANSPOSED, keep=sched.l),
    )
    broken = dataclasses.replace(sched, ops=ops)
    return broken, "SA105", "TRUNCATE needs normal"


def branch_mix_without_branches():
    """mix_branches on a single-branch (HERA) program."""
    sched = build_schedule(get_params("hera-128a"))
    i = next(i for i, op in enumerate(sched.ops) if isinstance(op, S.MRMC))
    broken = _replace_op(sched, i, mix_branches=True)
    return broken, "SA107", "mixes branches"


def mat_slice_gap():
    """First stream-matrix layer skips the start of the matrix plane."""
    sched = build_schedule(get_params("pasta-128s"))
    i = next(i for i, op in enumerate(sched.ops)
             if isinstance(op, S.MRMC) and op.streams_matrix)
    a, b = sched.ops[i].mat_slice
    broken = _replace_op(sched, i, mat_slice=(a + 16, b + 16))
    return broken, "SA110", "mat_slice .* inconsistent"


def static_op_with_mat_slice():
    """A static-matrix (HERA) op claiming a streamed matrix-plane slice."""
    sched = build_schedule(get_params("hera-128a"))
    i = next(i for i, op in enumerate(sched.ops) if isinstance(op, S.MRMC))
    broken = _replace_op(sched, i, mat_slice=(0, 16))
    return broken, "SA110", "carries mat_slice"


def unknown_init():
    """init must be 'ic' (public constant) or 'key' (PASTA)."""
    sched = build_schedule(get_params("pasta-128s"))
    broken = dataclasses.replace(sched, init="nonce")
    return broken, "SA107", "unknown init"


def _over_defer(preset, break_at):
    """Clone the preset's lazy ReductionPlan with one bound pushed past q
    at a terminal site — `break_at` maps an op list to the index whose
    plan entry to corrupt (None = the program-output bound)."""
    from repro.core import redplan as RP

    p = get_params(preset)
    sched = build_schedule(p)
    base = RP.plan_reductions(p, sched, "lazy")
    ops = list(base.ops)
    i = break_at(sched.ops)
    if i is None:
        last = len(ops) - 1
        ops[last] = dataclasses.replace(ops[last], out_bound=3 * base.q)
    else:
        ops[i] = dataclasses.replace(ops[i], in_bound=2 * base.q)
    bad = dataclasses.replace(base, ops=tuple(ops))
    return sched, bad


def plan_unreduced_output():
    """A plan deferring the final op's reduce past program end: output
    would leave as raw (< 3q) values, not canonical residues."""
    sched, bad = _over_defer("pasta-128s", lambda ops: None)
    return sched, bad, "SA111", "terminal-reduction law violated"


def plan_unreduced_truncate():
    """A plan feeding TRUNCATE an unreduced (< 2q) state — the kept slice
    would carry non-canonical residues into the keystream."""
    sched, bad = _over_defer(
        "pasta-128s",
        lambda ops: next(i for i, op in enumerate(ops)
                         if isinstance(op, S.TRUNCATE)))
    return sched, bad, "SA111", "terminal-reduction law violated"


#: over-deferred ReductionPlan fixtures: (builder, name) where the builder
#: returns (schedule, bad_plan, lint_code, validate_match) — the two-sided
#: contract is `ReductionPlan.validate()` REFUSES and `lint(sched,
#: plan=...)` DIAGNOSES (tests/test_redplan.py parametrizes over these)
BROKEN_PLANS = [
    (plan_unreduced_output, "plan-unreduced-output"),
    (plan_unreduced_truncate, "plan-unreduced-truncate"),
]

#: (builder, name) in one place so both suites parametrize identically
ALL = [
    (rc_slice_gap, "rc-slice-gap"),
    (rc_slice_overlap, "rc-slice-overlap"),
    (rc_slice_wrong_width, "rc-slice-wrong-width"),
    (affine_rc_wrong_width, "affine-rc-wrong-width"),
    (orientation_chain_break, "orientation-chain-break"),
    (ends_transposed, "ends-transposed"),
    (truncate_transposed, "truncate-transposed"),
    (branch_mix_without_branches, "branch-mix-without-branches"),
    (mat_slice_gap, "mat-slice-gap"),
    (static_op_with_mat_slice, "static-op-with-mat-slice"),
    (unknown_init, "unknown-init"),
]
