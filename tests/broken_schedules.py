"""Malformed-schedule fixtures shared by test_schedule and test_analysis.

Each builder perturbs a real preset program into one specific illegal
shape.  The contract under test is two-sided: ``Schedule.validate()``
must REFUSE the program (ValueError matching ``validate_match``) and the
static linter must DIAGNOSE it (a finding with ``lint_code``) — the
executor gate and the reviewer gate agree on what a well-formed program
is.
"""

import dataclasses

from repro.core import build_schedule
from repro.core import schedule as S
from repro.core.params import get_params


def _replace_op(sched, index, **fields):
    ops = list(sched.ops)
    ops[index] = dataclasses.replace(ops[index], **fields)
    return dataclasses.replace(sched, ops=tuple(ops))


def rc_slice_gap():
    """First ARK's constants start at 16, leaving rc[0:16] unconsumed."""
    sched = build_schedule(get_params("hera-128a"))
    i = next(i for i, op in enumerate(sched.ops) if isinstance(op, S.ARK))
    a, b = sched.ops[i].rc_slice
    broken = _replace_op(sched, i, rc_slice=(a + 16, b + 16))
    return broken, "SA101", "inconsistent"


def rc_slice_overlap():
    """Final ARK re-reads the previous ARK's constants."""
    sched = build_schedule(get_params("hera-128a"))
    i = max(i for i, op in enumerate(sched.ops) if isinstance(op, S.ARK))
    a, b = sched.ops[i].rc_slice
    broken = _replace_op(sched, i, rc_slice=(a - 16, b - 16))
    return broken, "SA101", "inconsistent"


def rc_slice_wrong_width():
    """ARK slice narrower than its key_len / the state width."""
    sched = build_schedule(get_params("hera-128a"))
    i = next(i for i, op in enumerate(sched.ops) if isinstance(op, S.ARK))
    a, b = sched.ops[i].rc_slice
    broken = _replace_op(sched, i, rc_slice=(a, b - 4))
    return broken, "SA102", "inconsistent"


def affine_rc_wrong_width():
    """PASTA affine layer consuming half a state's worth of constants."""
    sched = build_schedule(get_params("pasta-128s"))
    i = next(i for i, op in enumerate(sched.ops)
             if isinstance(op, S.MRMC) and op.has_rc)
    a, b = sched.ops[i].rc_slice
    broken = _replace_op(sched, i, rc_slice=(a, a + (b - a) // 2))
    return broken, "SA102", "affine MRMC .* inconsistent"


def orientation_chain_break():
    """Final ARK claims transposed state without an MRMC flip before it."""
    sched = build_schedule(get_params("hera-128a"), "alternating")
    broken = _replace_op(sched, len(sched.ops) - 1,
                         orientation=S.TRANSPOSED)
    return broken, "SA103", "expects transposed"


def ends_transposed():
    """A trailing flip that nothing undoes: the program ends transposed."""
    sched = build_schedule(get_params("hera-128a"))
    ops = sched.ops + (S.MRMC(out_orientation=S.TRANSPOSED),)
    broken = dataclasses.replace(sched, ops=ops)
    return broken, "SA104", "must end normal"


def truncate_transposed():
    """TRUNCATE applied to a transposed state (row-major slice would cut
    across logical columns)."""
    sched = build_schedule(get_params("hera-128a"))
    ops = sched.ops + (
        S.MRMC(out_orientation=S.TRANSPOSED),
        S.TRUNCATE(orientation=S.TRANSPOSED, keep=sched.l),
    )
    broken = dataclasses.replace(sched, ops=ops)
    return broken, "SA105", "TRUNCATE needs normal"


def branch_mix_without_branches():
    """mix_branches on a single-branch (HERA) program."""
    sched = build_schedule(get_params("hera-128a"))
    i = next(i for i, op in enumerate(sched.ops) if isinstance(op, S.MRMC))
    broken = _replace_op(sched, i, mix_branches=True)
    return broken, "SA107", "mixes branches"


def mat_slice_gap():
    """First stream-matrix layer skips the start of the matrix plane."""
    sched = build_schedule(get_params("pasta-128s"))
    i = next(i for i, op in enumerate(sched.ops)
             if isinstance(op, S.MRMC) and op.streams_matrix)
    a, b = sched.ops[i].mat_slice
    broken = _replace_op(sched, i, mat_slice=(a + 16, b + 16))
    return broken, "SA110", "mat_slice .* inconsistent"


def static_op_with_mat_slice():
    """A static-matrix (HERA) op claiming a streamed matrix-plane slice."""
    sched = build_schedule(get_params("hera-128a"))
    i = next(i for i, op in enumerate(sched.ops) if isinstance(op, S.MRMC))
    broken = _replace_op(sched, i, mat_slice=(0, 16))
    return broken, "SA110", "carries mat_slice"


def unknown_init():
    """init must be 'ic' (public constant) or 'key' (PASTA)."""
    sched = build_schedule(get_params("pasta-128s"))
    broken = dataclasses.replace(sched, init="nonce")
    return broken, "SA107", "unknown init"


#: (builder, name) in one place so both suites parametrize identically
ALL = [
    (rc_slice_gap, "rc-slice-gap"),
    (rc_slice_overlap, "rc-slice-overlap"),
    (rc_slice_wrong_width, "rc-slice-wrong-width"),
    (affine_rc_wrong_width, "affine-rc-wrong-width"),
    (orientation_chain_break, "orientation-chain-break"),
    (ends_transposed, "ends-transposed"),
    (truncate_transposed, "truncate-transposed"),
    (branch_mix_without_branches, "branch-mix-without-branches"),
    (mat_slice_gap, "mat-slice-gap"),
    (static_op_with_mat_slice, "static-op-with-mat-slice"),
    (unknown_init, "unknown-init"),
]
