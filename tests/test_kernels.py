"""Pallas kernels vs pure-jnp oracles (interpret=True): sweep shapes and
cipher parameter sets per the deliverable spec."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.cipher import make_cipher
from repro.core.params import get_params
from repro.crypto.aes import aes128_key_expand
from repro.kernels.aes.ops import aes_ctr_kernel_apply
from repro.kernels.aes.ref import aes_ctr_ref
from repro.kernels.keystream.ops import keystream_kernel_apply, presto_keystream
from repro.kernels.keystream.ref import keystream_ref
from repro.kernels.mrmc.ops import mrmc_kernel_apply
from repro.kernels.mrmc.ref import mrmc_ref

PARAMS = ["hera-128a", "rubato-128s", "rubato-128m", "rubato-128l"]
LANES = [1, 8, 128, 300]


@pytest.mark.parametrize("name", PARAMS)
@pytest.mark.parametrize("lanes", LANES)
def test_mrmc_kernel_matches_ref(name, lanes, rng):
    p = get_params(name)
    x = jnp.asarray(rng.integers(0, p.mod.q, (lanes, p.n), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.array(mrmc_kernel_apply(p, x, interpret=True)),
        np.array(mrmc_ref(p, x)))


@pytest.mark.parametrize("name", PARAMS)
@pytest.mark.parametrize("lanes", [1, 128, 300])
def test_keystream_kernel_matches_ref(name, lanes):
    ci = make_cipher(name, seed=11)
    p = ci.params
    ctrs = jnp.arange(lanes, dtype=jnp.uint32)
    consts = ci.round_constant_stream(ctrs)
    got = np.array(keystream_kernel_apply(
        p, ci.key, consts["rc"], consts["noise"], interpret=True))
    want = np.array(keystream_ref(p, ci.key, consts["rc"], consts["noise"]))
    np.testing.assert_array_equal(got, want)
    assert got.shape == (lanes, p.l)


@pytest.mark.parametrize("name", ["hera-128a", "rubato-128l"])
def test_full_pipeline_equals_core(name):
    ci = make_cipher(name, seed=2)
    ctrs = jnp.arange(64, dtype=jnp.uint32)
    np.testing.assert_array_equal(
        np.array(presto_keystream(ci, ctrs, interpret=True)),
        np.array(ci.keystream(ctrs)))


@pytest.mark.parametrize("lanes", [1, 128, 257])
def test_aes_kernel_matches_ref(lanes, rng):
    key = rng.integers(0, 256, 16, dtype=np.uint8)
    rk = aes128_key_expand(key)
    nonce = rng.integers(0, 256, 12, dtype=np.uint8)
    ctrs = jnp.arange(lanes, dtype=jnp.uint32) * jnp.uint32(65536)
    np.testing.assert_array_equal(
        np.array(aes_ctr_kernel_apply(rk, nonce, ctrs, interpret=True)),
        np.array(aes_ctr_ref(rk, nonce, ctrs)))


def test_keystream_kernel_without_noise():
    # HERA path has no AGN; make sure the 2-input kernel variant works
    ci = make_cipher("hera-128a", seed=4)
    ctrs = jnp.arange(5, dtype=jnp.uint32)
    consts = ci.round_constant_stream(ctrs)
    assert consts["noise"] is None
    got = keystream_kernel_apply(ci.params, ci.key, consts["rc"], None,
                                 interpret=True)
    assert got.shape == (5, 16)
