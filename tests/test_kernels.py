"""Pallas kernels vs pure-jnp oracles (interpret=True): sweep shapes and
cipher parameter sets per the deliverable spec.

Interpret-mode execution of the fused keystream kernel costs seconds per
(param set, BLK grid step), so the full-lane sweeps carry the ``slow``
marker; the fast lap keeps one tiny lane count per parameter set plus the
ragged (lanes % BLK != 0) padding/transpose parity cases.  scripts/ci.sh
runs both laps.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.cipher import make_cipher
from repro.core.params import get_params
from repro.crypto.aes import aes128_key_expand
from repro.kernels.aes.ops import aes_ctr_kernel_apply
from repro.kernels.aes.ref import aes_ctr_ref
from repro.kernels.keystream.keystream import BLK
from repro.kernels.keystream.ops import (
    keystream_kernel_apply,
    keystream_kernel_sharded,
    presto_keystream,
)
from repro.kernels.keystream.ref import keystream_ref
from repro.kernels.mrmc.ops import mrmc_kernel_apply
from repro.kernels.mrmc.ref import mrmc_ref

PARAMS = ["hera-128a", "rubato-128s", "rubato-128m", "rubato-128l",
          "pasta-128s", "pasta-128l"]
LANES = [1, 8, 128, 300]


@pytest.mark.parametrize("name", PARAMS)
@pytest.mark.parametrize("lanes", LANES)
def test_mrmc_kernel_matches_ref(name, lanes, rng):
    p = get_params(name)
    x = jnp.asarray(rng.integers(0, p.mod.q, (lanes, p.n), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.array(mrmc_kernel_apply(p, x, interpret=True)),
        np.array(mrmc_ref(p, x)))


def _check_keystream_parity(name, lanes):
    ci = make_cipher(name, seed=11)
    p = ci.params
    ctrs = jnp.arange(lanes, dtype=jnp.uint32)
    consts = ci.round_constant_stream(ctrs)
    got = np.array(keystream_kernel_apply(
        p, ci.key, consts["rc"], consts["noise"], interpret=True,
        mats=consts.get("mats")))
    want = np.array(keystream_ref(p, ci.key, consts["rc"], consts["noise"],
                                  mats=consts.get("mats")))
    np.testing.assert_array_equal(got, want)
    assert got.shape == (lanes, p.l)


@pytest.mark.parametrize("name", PARAMS)
def test_keystream_kernel_matches_ref(name):
    _check_keystream_parity(name, lanes=4)


@pytest.mark.slow
@pytest.mark.parametrize("name", PARAMS)
@pytest.mark.parametrize("lanes", [128, 300])
def test_keystream_kernel_matches_ref_full_lanes(name, lanes):
    _check_keystream_parity(name, lanes)


@pytest.mark.parametrize("name", ["hera-128a", "rubato-128s", "pasta-128s"])
@pytest.mark.parametrize("lanes", [5, 130])
def test_keystream_kernel_ragged_lanes(name, lanes):
    """Padding/transpose path parity: lanes % BLK != 0 (pad-to-BLK,
    lane-major transpose in, strip on the way out)."""
    assert lanes % BLK != 0
    _check_keystream_parity(name, lanes)


@pytest.mark.parametrize("lanes", [5, 130])
def test_keystream_kernel_ragged_lanes_no_noise(lanes):
    """Ragged lanes with noise explicitly dropped: exercises the 2-input
    kernel variant's padding path (rubato sans AGN)."""
    ci = make_cipher("rubato-128s", seed=11)
    p = ci.params
    ctrs = jnp.arange(lanes, dtype=jnp.uint32)
    consts = ci.round_constant_stream(ctrs)
    got = np.array(keystream_kernel_apply(
        p, ci.key, consts["rc"], None, interpret=True))
    want = np.array(keystream_ref(p, ci.key, consts["rc"], None))
    np.testing.assert_array_equal(got, want)
    assert got.shape == (lanes, p.l)


def test_keystream_pallas_direct_ragged_lanes():
    """keystream_pallas itself (lane-major entry) pads ragged lane counts
    to a BLK multiple and trims the output — no `lanes % BLK` assert left
    for farm windows to trip."""
    from repro.kernels.keystream.keystream import keystream_pallas

    ci = make_cipher("hera-128a", seed=11)
    p = ci.params
    lanes = 5
    consts = ci.round_constant_stream(jnp.arange(lanes, dtype=jnp.uint32))
    got = np.array(keystream_pallas(
        p, ci.key[:, None], consts["rc"].T, None, interpret=True))
    want = np.array(keystream_ref(p, ci.key, consts["rc"], None)).T
    assert got.shape == (p.l, lanes)
    np.testing.assert_array_equal(got, want)


def test_keystream_kernel_sharded_single_device():
    """1-device mesh: the shard_map path must reduce to the plain apply."""
    ci = make_cipher("hera-128a", seed=11)
    mesh = jax.make_mesh((1,), ("data",))
    ctrs = jnp.arange(6, dtype=jnp.uint32)
    consts = ci.round_constant_stream(ctrs)
    got = np.array(keystream_kernel_sharded(
        ci.params, ci.key, consts["rc"], consts["noise"], mesh=mesh,
        interpret=True))
    want = np.array(keystream_ref(ci.params, ci.key, consts["rc"],
                                  consts["noise"]))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("name", ["hera-128a", "rubato-128l"])
def test_full_pipeline_equals_core(name):
    ci = make_cipher(name, seed=2)
    ctrs = jnp.arange(16, dtype=jnp.uint32)
    np.testing.assert_array_equal(
        np.array(presto_keystream(ci, ctrs, interpret=True)),
        np.array(ci.keystream(ctrs)))


@pytest.mark.parametrize("lanes", [1, 128, 257])
def test_aes_kernel_matches_ref(lanes, rng):
    key = rng.integers(0, 256, 16, dtype=np.uint8)
    rk = aes128_key_expand(key)
    nonce = rng.integers(0, 256, 12, dtype=np.uint8)
    ctrs = jnp.arange(lanes, dtype=jnp.uint32) * jnp.uint32(65536)
    np.testing.assert_array_equal(
        np.array(aes_ctr_kernel_apply(rk, nonce, ctrs, interpret=True)),
        np.array(aes_ctr_ref(rk, nonce, ctrs)))


def test_keystream_kernel_without_noise():
    # HERA path has no AGN; make sure the 2-input kernel variant works
    ci = make_cipher("hera-128a", seed=4)
    ctrs = jnp.arange(5, dtype=jnp.uint32)
    consts = ci.round_constant_stream(ctrs)
    assert consts["noise"] is None
    got = keystream_kernel_apply(ci.params, ci.key, consts["rc"], None,
                                 interpret=True)
    assert got.shape == (5, 16)
