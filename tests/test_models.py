"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU, asserting output shapes and no NaNs — plus decode consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, list_archs
from repro.models import model as M
from repro.models.sharding import make_policy
from repro.launch.mesh import make_host_mesh
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_loop import make_train_step

ARCHS = list_archs()


def make_batch(cfg, B, T, rng, train=True):
    batch = {}
    if cfg.frontend == "none":
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    else:
        batch["embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, T, cfg.frontend_dim)), jnp.float32)
        if cfg.rope_kind == "mrope":
            p = np.broadcast_to(np.arange(T)[None, :, None], (B, T, 3)).copy()
            batch["positions"] = jnp.asarray(p, jnp.int32)
    if train:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, 2, 32, rng)
    logits, aux = M.forward_train(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    loss, (ce, aux) = M.loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    assert 0 < float(ce) < 2 * np.log(cfg.vocab_padded) + 5


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_one_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    mesh = make_host_mesh()
    policy = make_policy(mesh, cfg, batch=2, train=True)
    opt = OptConfig(lr=1e-3, eightbit=cfg.opt_8bit, total_steps=10,
                    warmup_steps=1)
    step, _ = make_train_step(cfg, policy, opt, donate=False)
    params = M.init_params(cfg, jax.random.key(0))
    state = init_opt_state(params, opt)
    batch = make_batch(cfg, 2, 32, rng)
    new_params, new_state, metrics = step(
        params, state, batch, jnp.asarray(0, jnp.int32))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    moved = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a, smoke=True).causal
                                  and get_config(a, smoke=True).frontend == "none"])
def test_decode_matches_teacher_forcing(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.key(1))
    B, T, MAXLEN = 2, 16, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T + 4)), jnp.int32)
    logits_full, _ = M.forward_train(cfg, params, {"tokens": toks})
    logits_p, cache, cur = M.prefill(cfg, params, {"tokens": toks[:, :T]},
                                     MAXLEN)
    errs = [float(jnp.abs(logits_p[:, 0] - logits_full[:, T - 1]).max())]
    for i in range(3):
        cur = cur + 1
        lg, cache = M.decode_step(cfg, params, cache,
                                  toks[:, T + i : T + i + 1], cur)
        errs.append(float(jnp.abs(lg[:, 0] - logits_full[:, T + i]).max()))
    # MoE archs: token-choice capacity differs between batched prefill and
    # single-token decode (a real semantic effect), so tolerance is looser
    tol = 6e-2 if cfg.num_experts else 2e-2
    assert max(errs) < tol, errs


def test_param_count_analytic_matches_actual():
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        params = M.init_params(cfg, jax.random.key(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        # analytic ignores a few tiny vectors; must agree within 2%
        assert abs(actual - analytic) / actual < 0.02, (
            arch, actual, analytic)


def test_encoder_arch_is_bidirectional(rng):
    """hubert: flipping future frames must change past outputs."""
    cfg = get_config("hubert-xlarge", smoke=True)
    params = M.init_params(cfg, jax.random.key(0))
    e = rng.normal(0, 1, (1, 16, cfg.frontend_dim)).astype(np.float32)
    l1, _ = M.forward_train(cfg, params, {"embeds": jnp.asarray(e)})
    e2 = e.copy()
    e2[:, -1] += 10.0
    l2, _ = M.forward_train(cfg, params, {"embeds": jnp.asarray(e2)})
    # output at position 0 changes => bidirectional attention
    assert float(jnp.abs(l1[:, 0] - l2[:, 0]).max()) > 1e-4


def test_causal_arch_ignores_future(rng):
    cfg = get_config("deepseek-7b", smoke=True)
    params = M.init_params(cfg, jax.random.key(0))
    t1 = rng.integers(0, cfg.vocab, (1, 16)).astype(np.int32)
    t2 = t1.copy()
    t2[:, -1] = (t2[:, -1] + 7) % cfg.vocab
    l1, _ = M.forward_train(cfg, params, {"tokens": jnp.asarray(t1)})
    l2, _ = M.forward_train(cfg, params, {"tokens": jnp.asarray(t2)})
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]),
                               atol=1e-5)


def test_gemma2_sliding_window_limits_reach(rng):
    """gemma2 smoke: window=16 on even layers; with T far beyond the window
    plus all-global layers removed this is hard to test directly, so check
    the attention primitive instead."""
    from repro.models.attention import blockwise_attention
    B, T, K, G, hd = 1, 64, 1, 1, 8
    q = jnp.asarray(rng.normal(0, 1, (B, T, K, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, T, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, T, K, hd)), jnp.float32)
    out_w = blockwise_attention(q, k, v, causal=True, window=8,
                                q_chunk=16, k_chunk=16)
    # perturb a key far outside the window of the last query
    k2 = k.at[:, 0].add(100.0)
    v2 = v.at[:, 0].add(100.0)
    out_w2 = blockwise_attention(q, k2, v2, causal=True, window=8,
                                 q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(out_w[:, -1]),
                               np.asarray(out_w2[:, -1]), atol=1e-5)
    # ...but it does affect early positions
    assert float(jnp.abs(out_w[:, 1] - out_w2[:, 1]).max()) > 1e-3
