"""Serving plane: event-driven scheduler edges, admission control, tenant
registry safety, rotation under concurrency, and the TCP front end.

The headline contract (ISSUE 10 acceptance): the event-driven scheduler
serves byte-identical ciphertext to a direct `CipherBatch` carve of the
same (session, counter) lanes, for every cipher kind — firing windows on
fill/deadline edges changes WHEN lanes materialize, never WHAT they hold.
"""

import asyncio
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cipher import Cipher, CipherBatch
from repro.serve.hhe_loop import (
    HHERequest,
    HHEServer,
    HHEServerSaturated,
)
from repro.serve.tenants import TenantRegistry, derive_tenant_key

KINDS = ["hera-80", "rubato-128s", "pasta-128s"]   # one preset per cipher


# ---------------------------------------------------------------------------
# Event-driven scheduler edges
# ---------------------------------------------------------------------------
def test_deadline_fires_part_full_window():
    """A part-full window must fire once the oldest lane crosses
    deadline_s — tail requests are never parked behind an unfilled
    window."""
    cb = CipherBatch("hera-80", seed=1)
    srv = HHEServer(cb, window=8, engine="jax", deadline_s=0.05)
    s = srv.open_session()
    srv.submit(HHERequest(session_id=s.index, op="keystream", blocks=3))
    # young lanes: the deadline has not tripped, nothing materializes
    assert srv.service(now=time.perf_counter()) == []
    assert srv.pending_lanes() == 3 and srv.windows_served == 0
    assert srv.next_due() is not None
    # the timer edge: well past the deadline, the partial window fires
    (resp,) = srv.service(now=time.perf_counter() + 1.0)
    assert resp.result.shape == (3, cb.params.l)
    stats = srv.latency_stats()
    assert stats["deadline_fires"] == 1 and stats["windows_served"] == 1
    assert srv.pending_lanes() == 0


def test_fill_fires_inside_submit():
    """fire_on_fill: the submit that fills a window dispatches it — no
    flush() needed for full windows."""
    cb = CipherBatch("hera-80", seed=2)
    srv = HHEServer(cb, window=4, engine="jax", depth=1)
    s = srv.open_session()
    srv.submit(HHERequest(session_id=s.index, op="keystream", blocks=4))
    # depth=1: the fill-fired window was pushed AND consumed synchronously
    assert srv.latency_stats()["fill_fires"] == 1
    assert srv.windows_served == 1
    (resp,) = srv.pop_completed()
    assert resp.result.shape == (4, cb.params.l)


def test_flush_short_circuits_when_idle():
    """The satellite bugfix: a drained server never dispatches an empty
    window, and latency_stats is fully populated before any traffic."""
    cb = CipherBatch("hera-80", seed=3)
    srv = HHEServer(cb, window=4, engine="jax")
    stats = srv.latency_stats()
    assert stats == {
        "count": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0,
        "queue_depth_lanes": 0, "inflight_lanes": 0, "windows_served": 0,
        "fill_fires": 0, "deadline_fires": 0, "shed": 0, "rejected": 0,
    }
    assert srv.flush() == []
    assert srv.windows_served == 0          # no empty-window dispatch
    assert not srv.busy()


# ---------------------------------------------------------------------------
# Admission control / backpressure
# ---------------------------------------------------------------------------
def test_backpressure_reject_at_bound():
    cb = CipherBatch("hera-80", seed=4)
    srv = HHEServer(cb, window=4, engine="jax", fire_on_fill=False,
                    max_pending_lanes=8, overload="reject")
    s = srv.open_session()
    srv.submit(HHERequest(session_id=s.index, op="keystream", blocks=8))
    ctr_before = cb.sessions[s.index].next_ctr
    with pytest.raises(HHEServerSaturated, match="max_pending_lanes"):
        srv.submit(HHERequest(session_id=s.index, op="keystream", blocks=1))
    # a rejected request leaves NO trace in the counter space
    assert cb.sessions[s.index].next_ctr == ctr_before
    assert srv.latency_stats()["rejected"] == 1
    # draining reopens admission
    assert len(srv.flush()) == 1
    assert srv.submit(
        HHERequest(session_id=s.index, op="keystream", blocks=1)) is not None


def test_backpressure_shed_at_bound():
    cb = CipherBatch("hera-80", seed=5)
    srv = HHEServer(cb, window=4, engine="jax", fire_on_fill=False,
                    max_pending_lanes=8, overload="shed")
    s = srv.open_session()
    srv.submit(HHERequest(session_id=s.index, op="keystream", blocks=8))
    ctr_before = cb.sessions[s.index].next_ctr
    assert srv.submit(
        HHERequest(session_id=s.index, op="keystream", blocks=2)) is None
    assert cb.sessions[s.index].next_ctr == ctr_before
    stats = srv.latency_stats()
    assert stats["shed"] == 1 and stats["queue_depth_lanes"] == 8
    # the buffered work still serves exactly
    (resp,) = srv.flush()
    assert resp.result.shape[0] == 8


def test_pending_bound_validation():
    cb = CipherBatch("hera-80", seed=6)
    with pytest.raises(ValueError, match="below one window"):
        HHEServer(cb, window=8, engine="jax", max_pending_lanes=4)
    with pytest.raises(ValueError, match="overload policy"):
        HHEServer(cb, window=4, engine="jax", overload="drop-newest")


# ---------------------------------------------------------------------------
# Tenant registry
# ---------------------------------------------------------------------------
def test_tenant_keys_distinct_and_deterministic():
    k1 = derive_tenant_key("hera-80", "alice", seed=0)
    k2 = derive_tenant_key("hera-80", "bob", seed=0)
    assert not np.array_equal(k1, k2)
    np.testing.assert_array_equal(
        k1, derive_tenant_key("hera-80", "alice", seed=0))
    reg = TenantRegistry("hera-80", capacity=4, window=4, engine="jax")
    np.testing.assert_array_equal(np.asarray(reg.get("alice").batch.key), k1)


def test_eviction_never_drops_in_flight_tenants():
    """The LRU bound must not corrupt live streams: busy tenants are
    skipped, and when everyone is busy the registry grows instead."""
    reg = TenantRegistry("hera-80", capacity=2, window=4, engine="jax",
                         fire_on_fill=False)
    t1, t2 = reg.get("t1"), reg.get("t2")
    for t in (t1, t2):
        s = t.server.open_session()
        t.server.submit(HHERequest(session_id=s.index, blocks=2))
    # both over-capacity candidates are busy -> grow, never evict
    reg.get("t3")
    assert len(reg) == 3 and reg.evictions == 0 and reg.busy_overflows == 1
    assert "t1" in reg and "t2" in reg
    # explicit eviction refuses busy tenants too
    with pytest.raises(RuntimeError, match="in-flight"):
        reg.evict("t1")
    # drained + collected -> t1 is the LRU idle candidate and goes first
    t1.server.flush()
    assert not t1.server.busy()
    reg.get("t4")
    assert "t1" not in reg and reg.evictions == 1
    assert "t2" in reg and "t3" in reg and "t4" in reg


def test_evicted_tenant_reattaches_with_fresh_generation():
    reg = TenantRegistry("hera-80", capacity=2, window=4, engine="jax")
    g0 = reg.get("a").generation
    assert reg.evict("a") is True
    assert reg.get("a").generation == g0 + 1


# ---------------------------------------------------------------------------
# Rotation under concurrency: the (nonce, counter) uniqueness invariant
# ---------------------------------------------------------------------------
def test_rotation_under_concurrent_submits_no_pair_reuse():
    """Submitter threads hammer one session while another thread live-
    rotates it: across every served response, no (nonce, counter) pair
    may repeat, and every response must be bit-exact with a single-stream
    Cipher keyed by the nonce its counters were reserved under — i.e. a
    rotation never re-keys lanes buffered before it."""
    reg = TenantRegistry("hera-80", capacity=2, window=4, engine="jax",
                         seed=7)
    tenant = reg.get("spinner")
    srv = tenant.server
    sess = srv.open_session()
    entries, stop = [], threading.Event()
    elock = threading.Lock()

    def submitter(seed):
        rng = np.random.default_rng(seed)
        for _ in range(12):
            e = srv.submit_entry(HHERequest(
                session_id=sess.index, op="keystream",
                blocks=int(rng.integers(1, 4))))
            with elock:
                entries.append(e)
            time.sleep(0.001)

    def rotator():
        while not stop.is_set():
            time.sleep(0.01)
            reg.rotate_session("spinner", sess.index)

    threads = [threading.Thread(target=submitter, args=(50 + i,))
               for i in range(3)]
    rot = threading.Thread(target=rotator)
    rot.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    rot.join()
    responses = {r.seq: r for r in srv.flush()}
    assert len(responses) == len(entries) == 36

    seen = set()
    for e in entries:
        for c in e.ctrs:
            pair = (e.nonce, int(c))
            assert pair not in seen, "keystream (nonce, counter) reuse"
            seen.add(pair)
        # bit-exact under the nonce recorded at submit time
        want = np.asarray(Cipher(
            tenant.batch.params, tenant.batch.key,
            np.frombuffer(e.nonce, np.uint8)
        ).keystream(jnp.asarray(e.ctrs, jnp.uint32)))
        np.testing.assert_array_equal(responses[e.seq].result, want)
    # the rotator actually rotated mid-traffic
    assert len({e.nonce for e in entries}) > 1


# ---------------------------------------------------------------------------
# Served-bytes parity: event-driven scheduler vs direct CipherBatch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", KINDS)
def test_served_ciphertext_parity_with_direct_batch(name):
    """Ciphertext served through the event-driven loop (ragged submits,
    fill fires, a deadline fire on the tail) equals a direct CipherBatch
    keystream carve of the same lanes — for every cipher kind."""
    cb = CipherBatch(name, seed=21)
    srv = HHEServer(cb, window=4, engine="jax", deadline_s=10.0)
    s0, s1 = srv.open_session(), srv.open_session()
    rng = np.random.default_rng(9)
    l = cb.params.l
    toks = [rng.integers(0, cb.params.mod.q, size=(b, l), dtype=np.uint32)
            for b in (3, 5, 2)]
    for t, sid in zip(toks, (s0, s1, s0)):
        srv.submit(HHERequest(session_id=sid.index, op="encrypt_tokens",
                              payload=t))
    # tail lanes land via the deadline edge, not flush
    resp = srv.service(now=time.perf_counter() + 60.0)
    assert len(resp) == 3 and srv.latency_stats()["deadline_fires"] == 1

    # direct path: a second CipherBatch, same key, sessions pinned to the
    # SAME nonces — its batched keystream is the independent oracle
    direct = CipherBatch(cb.params, key=np.asarray(cb.key))
    for sess in cb.sessions:
        direct.add_session(nonce=sess.nonce)
    sids = np.concatenate([np.full(t.shape[0], sid.index)
                           for t, sid in zip(toks, (s0, s1, s0))])
    ctrs = np.concatenate([r.block_ctrs for r in resp])
    z = np.asarray(direct.keystream(sids, ctrs))
    want = np.asarray(cb.params.mod.add(
        jnp.asarray(np.concatenate(toks)), jnp.asarray(z)))
    got = np.concatenate([r.result for r in resp])
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# TCP front end
# ---------------------------------------------------------------------------
def test_socket_round_trip_both_codecs():
    """Two clients on one plane — one JSON, one auto (msgpack when
    importable) — both directions exact, plus a live rotation and
    scheduler stats over the wire."""
    from repro.serve.server import CODEC_JSON, ServeClient, ServePlane

    async def main():
        reg = TenantRegistry("hera-80", capacity=2, window=4,
                             engine="jax", deadline_s=0.01)
        plane = ServePlane(reg, port=0, tick_s=0.002)
        host, port = await plane.start()
        cj = ServeClient(host, port, "json-tenant", codec=CODEC_JSON)
        cm = ServeClient(host, port, "auto-tenant")
        try:
            await cj.connect()
            await cm.connect()
            rng = np.random.default_rng(11)
            q, l = cj.params.mod.q, cj.params.l
            for c in (cj, cm):
                s = await c.open_session()
                toks = rng.integers(0, q, (3, l), dtype=np.uint32)
                r = await c.encrypt_to_server(s, toks)
                assert r["ok"], r
                np.testing.assert_array_equal(
                    np.asarray(r["result"], np.uint32), toks)
                await c.rotate(s)           # live rotation over the wire
                toks = rng.integers(0, q, (2, l), dtype=np.uint32)
                r, back = await c.decrypt_from_server(s, toks)
                assert r["ok"], r
                np.testing.assert_array_equal(back, toks)
            stats = await cj.stats()
            assert stats["count"] >= 2
            ping = await cm.call({"op": "ping"})
            assert ping["pong"] is True
            # tenant isolation visible at the wire level
            assert not np.array_equal(cj.key, cm.key)
        finally:
            await cj.close()
            await cm.close()
            await plane.stop()

    asyncio.run(main())


def test_socket_error_paths():
    """Wire errors come back as replies, never dropped connections."""
    from repro.serve.server import ServeClient, ServePlane

    async def main():
        reg = TenantRegistry("hera-80", capacity=2, window=4, engine="jax")
        plane = ServePlane(reg, port=0)
        host, port = await plane.start()
        c = ServeClient(host, port, "t")
        try:
            await c.connect()
            r = await c.call({"op": "nope"})
            assert not r["ok"] and "unknown op" in r["error"]
            r = await c.call({"op": "submit", "tenant": "t", "session": 99,
                              "hhe_op": "keystream", "blocks": 1})
            assert not r["ok"] and "unknown session" in r["error"]
            r = await c.call({"op": "hello", "tenant": "t",
                              "cipher": "rubato-128l"})
            assert not r["ok"] and "serves" in r["error"]
            # the connection survived all three errors
            assert (await c.call({"op": "ping"}))["pong"] is True
        finally:
            await c.close()
            await plane.stop()

    asyncio.run(main())
