"""Unit + property tests for uint32 limb modular arithmetic.

hypothesis is an *optional* extra (see requirements.txt) — the image this
repo targets is offline.  Property tests run under hypothesis when it is
installed and are backed by always-on deterministic seeded-array versions
covering the same properties plus the edge cases hypothesis tends to find
(0, 1, q-1, limb boundaries).
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.crypto.modmath import Modulus, Q_HERA, Q_PASTA, Q_RUBATO

MODS = [Q_HERA, Q_RUBATO, Q_PASTA]


@pytest.mark.parametrize("mod", MODS, ids=lambda m: str(m.q))
def test_mul_matches_bignum(mod, rng):
    x = rng.integers(0, mod.q, 5000, dtype=np.uint32)
    y = rng.integers(0, mod.q, 5000, dtype=np.uint32)
    got = np.array(mod.mul(jnp.asarray(x), jnp.asarray(y)))
    want = (x.astype(object) * y.astype(object)) % mod.q
    np.testing.assert_array_equal(got, want.astype(np.uint32))


@pytest.mark.parametrize("mod", MODS, ids=lambda m: str(m.q))
def test_add_sub_neg(mod, rng):
    x = rng.integers(0, mod.q, 2000, dtype=np.uint32)
    y = rng.integers(0, mod.q, 2000, dtype=np.uint32)
    xa, ya = jnp.asarray(x), jnp.asarray(y)
    np.testing.assert_array_equal(
        np.array(mod.add(xa, ya)), (x.astype(np.uint64) + y) % mod.q)
    np.testing.assert_array_equal(
        np.array(mod.sub(xa, ya)), (x.astype(np.int64) - y) % mod.q)
    np.testing.assert_array_equal(
        np.array(mod.add(mod.neg(xa), xa)), np.zeros_like(x))


@pytest.mark.parametrize("mod", MODS, ids=lambda m: str(m.q))
def test_cube_and_square(mod, rng):
    x = rng.integers(0, mod.q, 500, dtype=np.uint32)
    got = np.array(mod.cube(jnp.asarray(x)))
    want = np.array([pow(int(v), 3, mod.q) for v in x], dtype=np.uint32)
    np.testing.assert_array_equal(got, want)
    got = np.array(mod.square(jnp.asarray(x)))
    want = np.array([pow(int(v), 2, mod.q) for v in x], dtype=np.uint32)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("mod", MODS, ids=lambda m: str(m.q))
def test_mul_small_shift_add(mod, rng):
    x = rng.integers(0, mod.q, 1000, dtype=np.uint32)
    for c in (0, 1, 2, 3):
        got = np.array(mod.mul_small(jnp.asarray(x), c))
        np.testing.assert_array_equal(got, (x.astype(np.uint64) * c) % mod.q)


def test_matvec_small_vs_bignum(rng):
    M = np.array([[2, 3, 1, 1], [1, 2, 3, 1], [1, 1, 2, 3], [3, 1, 1, 2]])
    for mod in MODS:
        X = rng.integers(0, mod.q, (64, 4), dtype=np.uint32)
        got = np.array(mod.matvec_small(M, jnp.asarray(X), axis=-1))
        want = (M.astype(object) @ X.T.astype(object) % mod.q).T
        np.testing.assert_array_equal(got, want.astype(np.uint32))


if HAVE_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(
        x=st.integers(0, Q_HERA.q - 1),
        y=st.integers(0, Q_HERA.q - 1),
    )
    def test_mul_property_hera(x, y):
        got = int(Q_HERA.mul(jnp.uint32(x), jnp.uint32(y)))
        assert got == (x * y) % Q_HERA.q

    @settings(max_examples=200, deadline=None)
    @given(
        x=st.integers(0, Q_RUBATO.q - 1),
        y=st.integers(0, Q_RUBATO.q - 1),
    )
    def test_mul_property_rubato(x, y):
        got = int(Q_RUBATO.mul(jnp.uint32(x), jnp.uint32(y)))
        assert got == (x * y) % Q_RUBATO.q


@pytest.mark.parametrize("mod", MODS, ids=lambda m: str(m.q))
def test_mul_property_deterministic(mod):
    """Seeded-array stand-in for the hypothesis mul property: edge values
    (0, 1, small, limb boundaries, q-1) crossed with each other and with a
    seeded random sample."""
    edges = np.array(
        [0, 1, 2, 3, (1 << mod.L) - 1, 1 << mod.L,
         mod.q // 2, mod.q - 2, mod.q - 1],
        dtype=np.uint32,
    )
    rnd = np.random.default_rng(2024).integers(0, mod.q, 64, dtype=np.uint32)
    vals = np.concatenate([edges, rnd])
    x = np.repeat(vals, vals.size)
    y = np.tile(vals, vals.size)
    got = np.array(mod.mul(jnp.asarray(x), jnp.asarray(y)))
    want = (x.astype(object) * y.astype(object)) % mod.q
    np.testing.assert_array_equal(got, want.astype(np.uint32))


def test_rejects_bad_moduli():
    with pytest.raises(ValueError):
        Modulus(2**28)        # not prime
    with pytest.raises(ValueError):
        Modulus(2**29 - 3)    # out of range


def test_reduce_bounds(rng):
    mod = Q_HERA
    for k in (2, 3, 5, 8):
        x = rng.integers(0, k * mod.q, 1000, dtype=np.uint64).astype(np.uint32)
        x = np.minimum(x, np.uint32(k * mod.q - 1)) if k * mod.q < 2**32 else x
        got = np.array(mod.reduce(jnp.asarray(x), k * mod.q))
        np.testing.assert_array_equal(got, x % mod.q)
