"""Cipher system tests: the paper's structural claims + roundtrips.

hypothesis is optional (offline image); its property test has an always-on
deterministic seeded fallback below.
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (
    HERA_128A, RUBATO_128L, make_cipher, transcipher,
)
from repro.core import rounds as R
from repro.core.params import get_params
from repro.core.transcipher import evaluate_decryption_circuit

ALL = ["hera-128a", "rubato-128s", "rubato-128m", "rubato-128l"]


def test_round_constant_accounting_matches_paper():
    # Presto §IV-C: HERA needs 96 round constants, Rubato Par-128L 188
    assert HERA_128A.n_round_constants == 96
    assert RUBATO_128L.n_round_constants == 188
    # Rubato split: 64 + 64 + 60 (truncated final ARK)
    assert RUBATO_128L.rounds * RUBATO_128L.n + RUBATO_128L.l == 188


def test_multiplicative_depth_claims():
    # HERA: 5 Cube layers x depth 2 = 10;  Rubato-128L: 2 Feistel x 1 = 2.
    # This is THE property that makes Rubato cheap to transcipher (§III).
    hera = make_cipher("hera-128a", seed=1)
    _, depth = evaluate_decryption_circuit(hera, jnp.arange(2, dtype=jnp.uint32))
    assert depth == 10
    rub = make_cipher("rubato-128l", seed=1)
    _, depth = evaluate_decryption_circuit(rub, jnp.arange(2, dtype=jnp.uint32))
    assert depth == 2


@pytest.mark.parametrize("name", ALL)
def test_mrmc_transposition_invariance(name, rng):
    """Paper Eq. 2: MRMC(X^T) = (MRMC(X))^T — the property that licenses
    row/column-major alternation."""
    p = get_params(name)
    v = p.v
    x = rng.integers(0, p.mod.q, (7, p.n), dtype=np.uint32)
    X = x.reshape(7, v, v)
    xt = jnp.asarray(np.swapaxes(X, 1, 2).reshape(7, p.n))
    lhs = np.array(R.mrmc(p, xt)).reshape(7, v, v)
    rhs = np.swapaxes(
        np.array(R.mrmc(p, jnp.asarray(x))).reshape(7, v, v), 1, 2)
    np.testing.assert_array_equal(lhs, rhs)


@pytest.mark.parametrize("name", ALL)
def test_mrmc_equals_composition(name, rng):
    p = get_params(name)
    x = jnp.asarray(rng.integers(0, p.mod.q, (5, p.n), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.array(R.mrmc(p, x)),
        np.array(R.mix_rows(p, R.mix_columns(p, x))))


@pytest.mark.parametrize("name", ALL)
def test_encrypt_decrypt_roundtrip(name, rng):
    ci = make_cipher(name, seed=3)
    ctrs = jnp.arange(6, dtype=jnp.uint32)
    m = rng.uniform(-8, 8, (6, ci.params.l)).astype(np.float32)
    ct = ci.encrypt(m, ctrs, delta=4096.0)
    back = np.array(ci.decrypt(ct, ctrs, delta=4096.0))
    assert np.abs(back - m).max() < 1 / 4096 + 1e-6


def test_keystream_coupled_equals_decoupled():
    ci = make_cipher("rubato-128l", seed=5)
    ctrs = jnp.arange(4, dtype=jnp.uint32)
    np.testing.assert_array_equal(
        np.array(ci.keystream(ctrs)), np.array(ci.keystream_coupled(ctrs)))


def test_keystream_depends_on_key_nonce_counter():
    a = make_cipher("hera-128a", seed=1)
    b = make_cipher("hera-128a", seed=2)
    c0 = jnp.arange(2, dtype=jnp.uint32)
    assert not np.array_equal(np.array(a.keystream(c0)),
                              np.array(b.keystream(c0)))
    assert not np.array_equal(np.array(a.keystream(c0)),
                              np.array(a.keystream(c0 + 10)))


def test_feistel_is_parallel_not_chained(rng):
    p = get_params("rubato-128l")
    x = rng.integers(0, p.mod.q, (3, p.n), dtype=np.uint32)
    got = np.array(R.feistel(p, jnp.asarray(x)))
    want = x.copy().astype(object)
    want[:, 1:] = (x[:, 1:].astype(object)
                   + (x[:, :-1].astype(object) ** 2)) % p.mod.q
    np.testing.assert_array_equal(got, want.astype(np.uint32))


def test_transcipher_recovers_slots():
    ci = make_cipher("rubato-128l", seed=7)
    ctrs = jnp.arange(3, dtype=jnp.uint32)
    rng = np.random.default_rng(7)
    m = rng.uniform(-4, 4, (3, ci.params.l)).astype(np.float32)
    ct = ci.encrypt(m, ctrs)
    slots, depth = transcipher(ci, ct, ctrs)
    # server-side recovery is exact up to the cipher's own AGN noise
    assert np.abs(np.array(slots) - m).max() < 10 * 1.6 / 1024 + 1 / 2048
    assert depth == 2


def _roundtrip_hera(seed, ctr):
    ci = make_cipher("hera-128a", seed=seed)
    ctrs = jnp.asarray([ctr], dtype=jnp.uint32)
    rng = np.random.default_rng(seed)
    m = rng.uniform(-2, 2, (1, 16)).astype(np.float32)
    back = np.array(ci.decrypt(ci.encrypt(m, ctrs), ctrs))
    assert np.abs(back - m).max() < 1e-3


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), ctr=st.integers(0, 2**20))
    def test_property_roundtrip_hera(seed, ctr):
        _roundtrip_hera(seed, ctr)


def test_roundtrip_hera_deterministic():
    """Seeded stand-in for the hypothesis roundtrip property: edge and
    random (seed, ctr) pairs."""
    rng = np.random.default_rng(99)
    pairs = [(0, 0), (1, 2**20), (2**31 - 1, 1)] + [
        (int(rng.integers(0, 2**31)), int(rng.integers(0, 2**20)))
        for _ in range(5)
    ]
    for seed, ctr in pairs:
        _roundtrip_hera(seed, ctr)
