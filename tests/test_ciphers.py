"""Cipher system tests: the paper's structural claims + roundtrips.

hypothesis is optional (offline image); its property test has an always-on
deterministic seeded fallback below.
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (
    HERA_128A, PASTA_128L, PASTA_128S, RUBATO_128L, make_cipher, transcipher,
)
from repro.core import rounds as R
from repro.core.params import get_params
from repro.core.transcipher import evaluate_decryption_circuit

ALL = ["hera-128a", "rubato-128s", "rubato-128m", "rubato-128l",
       "pasta-128s", "pasta-128l"]


def test_round_constant_accounting_matches_paper():
    # Presto §IV-C: HERA needs 96 round constants, Rubato Par-128L 188
    assert HERA_128A.n_round_constants == 96
    assert RUBATO_128L.n_round_constants == 188
    # Rubato split: 64 + 64 + 60 (truncated final ARK)
    assert RUBATO_128L.rounds * RUBATO_128L.n + RUBATO_128L.l == 188
    # PASTA: (r+1) affine layers x n additive constants, no ARKs
    assert PASTA_128L.n_round_constants == (3 + 1) * 128 == 512
    assert PASTA_128S.n_round_constants == (4 + 1) * 32 == 160
    assert PASTA_128L.n_arks == 0


def test_multiplicative_depth_claims():
    # HERA: 5 Cube layers x depth 2 = 10;  Rubato-128L: 2 Feistel x 1 = 2;
    # PASTA sits between: (r-1) Feistels + one Cube = r+1 (4 for 128l).
    # This is THE property that makes Rubato cheap to transcipher (§III).
    hera = make_cipher("hera-128a", seed=1)
    _, depth = evaluate_decryption_circuit(hera, jnp.arange(2, dtype=jnp.uint32))
    assert depth == 10
    rub = make_cipher("rubato-128l", seed=1)
    _, depth = evaluate_decryption_circuit(rub, jnp.arange(2, dtype=jnp.uint32))
    assert depth == 2
    pasta = make_cipher("pasta-128l", seed=1)
    _, depth = evaluate_decryption_circuit(pasta, jnp.arange(2, dtype=jnp.uint32))
    assert depth == 4


@pytest.mark.parametrize("name", ALL)
def test_mrmc_transposition_invariance(name, rng):
    """Paper Eq. 2: MRMC(X^T) = (MRMC(X))^T — the property that licenses
    row/column-major alternation.  Per branch for PASTA's two-word state."""
    p = get_params(name)
    v, b = p.v, p.branches
    x = rng.integers(0, p.mod.q, (7, p.n), dtype=np.uint32)
    X = x.reshape(7, b, v, v)
    xt = jnp.asarray(np.swapaxes(X, 2, 3).reshape(7, p.n))
    lhs = np.array(R.mrmc(p, xt)).reshape(7, b, v, v)
    rhs = np.swapaxes(
        np.array(R.mrmc(p, jnp.asarray(x))).reshape(7, b, v, v), 2, 3)
    np.testing.assert_array_equal(lhs, rhs)


@pytest.mark.parametrize("name", ALL)
def test_mrmc_equals_composition(name, rng):
    p = get_params(name)
    x = jnp.asarray(rng.integers(0, p.mod.q, (5, p.n), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.array(R.mrmc(p, x)),
        np.array(R.mix_rows(p, R.mix_columns(p, x))))


@pytest.mark.parametrize("name", ALL)
def test_encrypt_decrypt_roundtrip(name, rng):
    ci = make_cipher(name, seed=3)
    ctrs = jnp.arange(6, dtype=jnp.uint32)
    m = rng.uniform(-8, 8, (6, ci.params.l)).astype(np.float32)
    ct = ci.encrypt(m, ctrs, delta=4096.0)
    back = np.array(ci.decrypt(ct, ctrs, delta=4096.0))
    assert np.abs(back - m).max() < 1 / 4096 + 1e-6


def test_keystream_coupled_equals_decoupled():
    ci = make_cipher("rubato-128l", seed=5)
    ctrs = jnp.arange(4, dtype=jnp.uint32)
    np.testing.assert_array_equal(
        np.array(ci.keystream(ctrs)), np.array(ci.keystream_coupled(ctrs)))


def test_keystream_depends_on_key_nonce_counter():
    a = make_cipher("hera-128a", seed=1)
    b = make_cipher("hera-128a", seed=2)
    c0 = jnp.arange(2, dtype=jnp.uint32)
    assert not np.array_equal(np.array(a.keystream(c0)),
                              np.array(b.keystream(c0)))
    assert not np.array_equal(np.array(a.keystream(c0)),
                              np.array(a.keystream(c0 + 10)))


def test_feistel_is_parallel_not_chained(rng):
    p = get_params("rubato-128l")
    x = rng.integers(0, p.mod.q, (3, p.n), dtype=np.uint32)
    got = np.array(R.feistel(p, jnp.asarray(x)))
    want = x.copy().astype(object)
    want[:, 1:] = (x[:, 1:].astype(object)
                   + (x[:, :-1].astype(object) ** 2)) % p.mod.q
    np.testing.assert_array_equal(got, want.astype(np.uint32))


def test_pasta_feistel_restarts_at_branch_boundary(rng):
    """PASTA's Feistel chain is per branch: element t (the first of branch
    R) passes through unchanged, like element 0 — never coupled to element
    t-1 of branch L."""
    p = get_params("pasta-128s")
    t = p.n // 2
    x = rng.integers(0, p.mod.q, (3, p.n), dtype=np.uint32)
    got = np.array(R.feistel(p, jnp.asarray(x)))
    np.testing.assert_array_equal(got[:, 0], x[:, 0])
    np.testing.assert_array_equal(got[:, t], x[:, t])   # restart, not chained
    want_t1 = (x[:, t + 1].astype(object)
               + x[:, t].astype(object) ** 2) % p.mod.q
    np.testing.assert_array_equal(got[:, t + 1], want_t1.astype(np.uint32))


def test_pasta_branch_mix_matches_definition(rng):
    """(y_L, y_R) <- (2y_L + y_R, y_L + 2y_R) mod q, elementwise."""
    p = get_params("pasta-128s")
    t = p.n // 2
    x = rng.integers(0, p.mod.q, (4, p.n), dtype=np.uint32)
    got = np.array(R.branch_mix(p, jnp.asarray(x))).astype(object)
    L, R_ = x[:, :t].astype(object), x[:, t:].astype(object)
    np.testing.assert_array_equal(got[:, :t], (2 * L + R_) % p.mod.q)
    np.testing.assert_array_equal(got[:, t:], (L + 2 * R_) % p.mod.q)


def test_transcipher_recovers_slots():
    ci = make_cipher("rubato-128l", seed=7)
    ctrs = jnp.arange(3, dtype=jnp.uint32)
    rng = np.random.default_rng(7)
    m = rng.uniform(-4, 4, (3, ci.params.l)).astype(np.float32)
    ct = ci.encrypt(m, ctrs)
    slots, depth = transcipher(ci, ct, ctrs)
    # server-side recovery is exact up to the cipher's own AGN noise
    assert np.abs(np.array(slots) - m).max() < 10 * 1.6 / 1024 + 1 / 2048
    assert depth == 2


def test_transcipher_recovers_slots_pasta():
    """PASTA has no AGN stage, so server-side recovery is exact to the
    fixed-point grid — and the circuit depth is r+1."""
    ci = make_cipher("pasta-128l", seed=7)
    ctrs = jnp.arange(3, dtype=jnp.uint32)
    rng = np.random.default_rng(8)
    m = rng.uniform(-4, 4, (3, ci.params.l)).astype(np.float32)
    ct = ci.encrypt(m, ctrs)
    slots, depth = transcipher(ci, ct, ctrs)
    assert np.abs(np.array(slots) - m).max() < 1 / 2048
    assert depth == ci.params.rounds + 1


def _roundtrip_hera(seed, ctr):
    ci = make_cipher("hera-128a", seed=seed)
    ctrs = jnp.asarray([ctr], dtype=jnp.uint32)
    rng = np.random.default_rng(seed)
    m = rng.uniform(-2, 2, (1, 16)).astype(np.float32)
    back = np.array(ci.decrypt(ci.encrypt(m, ctrs), ctrs))
    assert np.abs(back - m).max() < 1e-3


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), ctr=st.integers(0, 2**20))
    def test_property_roundtrip_hera(seed, ctr):
        _roundtrip_hera(seed, ctr)


def test_roundtrip_hera_deterministic():
    """Seeded stand-in for the hypothesis roundtrip property: edge and
    random (seed, ctr) pairs."""
    rng = np.random.default_rng(99)
    pairs = [(0, 0), (1, 2**20), (2**31 - 1, 1)] + [
        (int(rng.integers(0, 2**31)), int(rng.integers(0, 2**20)))
        for _ in range(5)
    ]
    for seed, ctr in pairs:
        _roundtrip_hera(seed, ctr)
