"""The accelerator itself: a batched keystream farm with the paper's D1/D2/D3
design points, reproducing the ablation structure of Tables I/II.

    PYTHONPATH=src python examples/keystream_farm.py [--lanes 1024]

Shows per-design wall time + derived throughput on this host, the
decoupled-RNG producer/consumer split (keystream for batch t+1 dispatched
while batch t is consumed), and the Rubato-vs-HERA crossover the paper
reports (§V: HERA wins in software, Rubato wins accelerated).
"""

import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CipherBatch, KeystreamFarm, StreamPlan, plan_windows
from repro.core.cipher import make_cipher
from repro.core.tuner import load_plan
from repro.kernels.keystream.ops import keystream_kernel_apply
from repro.serve.hhe_loop import HHERequest, HHEServer


def timed(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lanes", type=int, default=1024)
    args = ap.parse_args()
    lanes = args.lanes

    for name in ("hera-128a", "rubato-128l"):
        ci = make_cipher(name, seed=0)
        ctrs = jnp.arange(lanes, dtype=jnp.uint32)
        l = ci.params.l

        d1 = jax.jit(ci.keystream_coupled)
        t1 = timed(d1, ctrs)

        producer = jax.jit(ci.round_constant_stream)
        consumer = jax.jit(
            lambda rc, nz: ci.keystream_from_constants(rc, nz))

        def d2(c):
            consts = producer(c)          # async-dispatchable producer
            return consumer(consts["rc"], consts["noise"])
        t2 = timed(d2, ctrs)

        def d3(c):
            consts = producer(c)
            return keystream_kernel_apply(
                ci.params, ci.key, consts["rc"], consts["noise"],
                interpret=True)
        t3 = timed(d3, ctrs)

        print(f"\n{name}  ({lanes} lanes x {l} elements)")
        for label, t in (("D1 coupled", t1), ("D2 +decoupled RNG", t2),
                         ("D3 +fused kernel", t3)):
            print(f"  {label:22s} {t*1e3:8.2f} ms  "
                  f"{lanes*l/t/1e6:8.1f} Msps  {t/lanes*1e6:7.2f} us/key")

        # overlap demo: producer for batch t+1 dispatched during batch t
        t0 = time.perf_counter()
        consts = producer(ctrs)
        for step in range(4):
            nxt = producer(ctrs + jnp.uint32((step + 1) * lanes))  # async
            z = consumer(consts["rc"], consts["noise"])
            jax.block_until_ready(z)
            consts = nxt
        dt = (time.perf_counter() - t0) / 4
        print(f"  pipelined producer/consumer: {dt*1e3:8.2f} ms/batch "
              f"(macro RNG-decoupling, docs/DESIGN.md T3)")

        # ---- multi-stream farm: many sessions, one batched dispatch ----
        # the farm's whole pipeline configuration is ONE StreamPlan value
        # (producer x engine x variant x window x depth): a measured plan
        # from the tuner cache when this host has one, else a static
        # double-buffered default.  `python -m repro.core.tuner --autotune`
        # (or serve.py --autotune) populates the cache.
        batch = CipherBatch(name, seed=0)
        sessions = batch.add_sessions(8)
        bps = max(1, lanes // 8)            # blocks per session per pass
        window = bps * 8
        plan = load_plan(name, lanes) or StreamPlan(
            producer=batch.params.xof, engine="auto", variant="auto",
            window=window, depth=2)
        farm = KeystreamFarm(batch, plan=plan)
        print(f"  farm plan: producer={batch.producer.name} "
              f"engine={farm.engine.name} variant={farm.engine.variant} "
              f"depth={farm.depth}")
        plans = plan_windows(sessions, blocks_per_session=bps, window=window)
        for _, z in farm.run(plans):        # warmup/compile
            jax.block_until_ready(z)
        plans = plan_windows(sessions, blocks_per_session=bps, window=window)
        t0 = time.perf_counter()
        last = None
        for _, z in farm.run(plans):
            last = z
        jax.block_until_ready(last)
        dt = time.perf_counter() - t0
        print(f"  farm ({len(sessions)} sessions, window={window}): "
              f"{dt*1e3:8.2f} ms  {window*l/dt/1e6:8.1f} Msps "
              f"(double-buffered windows)")

    # ---- serving shape: ragged requests packed into fixed windows ------
    print("\nHHE request loop (rubato-128l, window=256)")
    srv = HHEServer(CipherBatch("rubato-128l", seed=1), window=256)
    rng = np.random.default_rng(0)
    for _ in range(16):
        srv.open_session()
    srv.warmup()            # compile the two window programs up front
    for s in srv.batch.sessions:
        srv.submit(HHERequest(session_id=s.index, op="keystream",
                              blocks=int(rng.integers(1, 40))))
    n = len(srv.flush())
    print(f"  served {n} ragged requests; latency: {srv.latency_stats()}")


if __name__ == "__main__":
    main()
