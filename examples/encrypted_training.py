"""End-to-end driver: train a language model on HHE-ENCRYPTED data.

The paper's deployment scenario as a framework feature: the client encrypts
examples with Rubato (cheap symmetric stream cipher, low ciphertext
expansion); the pod regenerates stream keys at line rate (the accelerator
this paper builds) and decrypts inside the train step.  Host RAM and the
network only ever see Z_q ciphertext.

Default: a ~10M-param granite-family model for 300 steps on CPU (loss
decreases on the synthetic structured stream).  Scale knobs:
    --layers 24 --d-model 640 --steps 300        (~100M params)

    PYTHONPATH=src python examples/encrypted_training.py [--steps 300]
"""

import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cipher import make_cipher
from repro.data.encrypted import EncryptedSource, make_decryptor
from repro.data.pipeline import SyntheticLM
from repro.launch.elastic import StragglerWatchdog
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.sharding import make_policy
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=320)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--plaintext", action="store_true",
                    help="disable the HHE data plane (ablation)")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="encrypted-demo", family="dense",
        num_layers=args.layers, d_model=args.d_model,
        num_heads=args.d_model // 64, kv_heads=max(args.d_model // 128, 1),
        d_ff=args.d_model * 3, vocab=args.vocab, remat=False,
    )
    n_params = cfg.param_count()
    print(f"model: {args.layers}L d={args.d_model} ~{n_params/1e6:.1f}M params")

    policy = make_policy(make_host_mesh(), cfg, batch=args.batch, train=True)
    opt = OptConfig(lr=1e-3, total_steps=args.steps,
                    warmup_steps=max(args.steps // 20, 5))

    source = SyntheticLM(cfg, args.batch, args.seq, seed=0)
    decryptor = None
    if not args.plaintext:
        cipher = make_cipher("rubato-128l", seed=1234)
        source = EncryptedSource(source, cipher)
        decryptor = make_decryptor(cipher)
        print(f"data plane: Rubato Par-128L encrypted "
              f"({source.blocks_per_batch()} keystream blocks/batch)")

    step_fn, _ = make_train_step(cfg, policy, opt, decryptor=decryptor)
    params = M.init_params(cfg, jax.random.key(0))
    state = init_opt_state(params, opt)

    watchdog = StragglerWatchdog()
    losses = []
    t0 = time.time()
    for step in range(args.steps):
        batch = jax.tree.map(jnp.asarray, source.batch_at(step))
        ts = time.time()
        params, state, metrics = step_fn(
            params, state, batch, jnp.asarray(step, jnp.int32))
        loss = float(metrics["loss"])
        losses.append(loss)
        watchdog.observe(step, time.time() - ts)
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"({(step+1)*args.batch*args.seq/(time.time()-t0):.0f} tok/s)")
        if args.ckpt_dir and (step + 1) % 100 == 0:
            ckpt.save(args.ckpt_dir, step + 1, (params, state),
                      extra={"data_step": step + 1}, async_write=True)

    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"\nloss: first-20 avg {first:.4f} -> last-20 avg {last:.4f} "
          f"({'DECREASED' if last < first - 0.05 else 'no clear decrease'})")
    assert last < first, "training on encrypted data failed to learn"


if __name__ == "__main__":
    main()
