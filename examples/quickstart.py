"""Quickstart: the paper's ciphers in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Build HERA / Rubato / PASTA ciphers, generate stream keys.
2. Encrypt real-valued client data, decrypt, verify roundtrip.
3. Run the fused Pallas accelerator kernel (interpret mode on CPU) and
   check it against the reference.
4. Server-side RtF transciphering with multiplicative-depth accounting —
   the property (depth 10 vs 4 vs 2) that motivates the shallow ciphers.
5. The multi-stream farm: one key, many client sessions, one batched
   dispatch — bit-exact with each session's own single-stream cipher.
"""

import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import CipherBatch, KeystreamFarm, make_cipher, transcipher
from repro.core.transcipher import evaluate_decryption_circuit
from repro.kernels.keystream.ops import presto_keystream


def main():
    rng = np.random.default_rng(0)

    print("=== 1. stream keys =========================================")
    for name in ("hera-128a", "rubato-128l", "pasta-128l"):
        ci = make_cipher(name, seed=42)
        ctrs = jnp.arange(4, dtype=jnp.uint32)
        z = ci.keystream(ctrs)
        print(f"{name}: state n={ci.params.n} rounds={ci.params.rounds} "
              f"q={ci.params.mod.q} keystream block shape={z.shape}")
        print(f"  round constants/key: {ci.params.n_round_constants}")

    print("\n=== 2. encrypt / decrypt ===================================")
    ci = make_cipher("rubato-128l", seed=42)
    ctrs = jnp.arange(8, dtype=jnp.uint32)
    msg = rng.uniform(-10, 10, (8, ci.params.l)).astype(np.float32)
    ct = ci.encrypt(msg, ctrs, delta=4096.0)
    back = np.array(ci.decrypt(ct, ctrs, delta=4096.0))
    print(f"ciphertext dtype={ct.dtype} (Z_q), roundtrip max err "
          f"{np.abs(back - msg).max():.2e}")

    print("\n=== 3. fused accelerator kernel ============================")
    z_kernel = np.array(presto_keystream(ci, ctrs, interpret=True))
    z_ref = np.array(ci.keystream(ctrs))
    print(f"fused Pallas kernel == pure-JAX reference: "
          f"{np.array_equal(z_kernel, z_ref)}")

    print("\n=== 4. RtF transciphering (server side) ====================")
    for name in ("hera-128a", "rubato-128l", "pasta-128l"):
        ci = make_cipher(name, seed=7)
        ctrs = jnp.arange(2, dtype=jnp.uint32)
        m = rng.uniform(-4, 4, (2, ci.params.l)).astype(np.float32)
        ct = ci.encrypt(m, ctrs)
        slots, depth = transcipher(ci, ct, ctrs)
        print(f"{name}: multiplicative depth={depth} "
              f"(HERA=10, PASTA=r+1, Rubato=2 — why shallow ciphers win), "
              f"slot err={np.abs(np.array(slots)-m).max():.1e}")

    print("\n=== 5. multi-stream keystream farm ==========================")
    batch = CipherBatch("rubato-128l", seed=42)     # one key...
    sessions = batch.add_sessions(4)                # ...many client nonces
    farm = KeystreamFarm(batch)                     # double-buffered pipeline
    # lanes mix sessions and counters arbitrarily; one jit'd dispatch
    sids = np.array([s.index for s in sessions] * 2)
    ctrs = np.repeat([0, 1], 4)
    z = np.array(farm.keystream(sids, ctrs))
    ref = np.array(batch.session_cipher(sessions[2].index).keystream(
        jnp.asarray([0], jnp.uint32)))[0]
    print(f"batched keystream {z.shape} across {len(sessions)} sessions; "
          f"bit-exact with per-session cipher: {np.array_equal(z[2], ref)}")


if __name__ == "__main__":
    main()
